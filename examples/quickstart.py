"""Quickstart: generate a scale-12 R-MAT graph with the paper's pipeline,
validate it, and sample random walks from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import validate as V
from repro.core.csr import csr_to_host
from repro.core.pipeline import generate
from repro.core.types import GraphConfig
from repro.data.walks import host_walks

# 1. configure: 2^12 vertices, 16 edges per vertex (Graph500 edge factor)
cfg = GraphConfig(scale=12, edge_factor=16, nb=1, capacity_factor=4.0)

# 2. run the paper's pipeline: shuffle -> edges -> relabel -> redistribute -> CSR
res = generate(cfg)
print(f"generated {cfg.m} edges over {cfg.n} vertices "
      f"(dropped: {int(res.dropped_redistribute)})")

# 3. validate (Graph500-style)
assert V.check_permutation(res.pv), "permutation must be a bijection"
checks = V.check_csr(res.csr, res.owned, cfg)
assert all(checks.values()), checks
stats = V.degree_stats(res.csr, cfg)
print(f"degree: mean={stats['mean_degree']:.1f} max={stats['max_degree']:.0f} "
      f"(heavy tail — it's a scale-free graph)")

# 4. de-biasing check: this is WHY the paper shuffles (paper §I)
skew = V.endpoint_skew(res.src, res.dst, cfg.n)
print(f"relabeled endpoint skew {skew:.4f} (unbiased = {1 / 16:.4f})")

# 5. walk the graph (the training-data pipeline)
offv, adjv = csr_to_host(res.csr, cfg)
walks = host_walks(offv, adjv, np.asarray([0, 1, 2]), 12, seed=0, n=cfg.n)
print("three 12-step walks:")
for w in walks:
    print("  ", w.tolist())
