"""Serve a small LM with the continuous-batching engine: mixed prompt
lengths, slot reuse, greedy + sampled requests.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs.base import get_smoke_config
from repro.models.registry import init_all
from repro.serve import Engine, Request, SamplingParams, generate_reference

cfg = get_smoke_config("internlm2-1.8b")
params, _ = init_all(cfg)
engine = Engine(cfg, params, max_batch=4, max_len=128)

rng = np.random.default_rng(0)
requests = []
for i in range(12):
    plen = int(rng.integers(1, 16))
    requests.append(Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab_size, plen).tolist(),
        max_new_tokens=16,
        sampling=SamplingParams(temperature=0.7 if i % 2 else 0.0,
                                top_k=20, seed=i),
    ))

out = engine.run(requests)
print(f"{len(out)} requests served in {engine.steps} engine steps "
      f"({engine.decode_tokens} decode tokens, "
      f"slot util {engine.decode_tokens / (engine.steps * 4):.2f})")

# spot-check continuous batching == sequential decoding
ref = generate_reference(cfg, params, requests[0], max_len=128)
assert out[0] == ref, "engine must match the single-request oracle"
print("req 0 (greedy):", out[0])
print("req 1 (t=0.7):", out[1])
print("engine == oracle ✓")
