"""End-to-end driver (deliverable b): generate a graph with the paper's
pipeline, stream random-walk token batches from it, and train a ~small LM
for a few hundred steps with checkpointing — then resume once to prove
restartability, and finally train from the OUT-OF-CORE data path (disk-tier
generation + external_walks corpus: the CSR never materializes in RAM).

    PYTHONPATH=src python examples/train_lm_on_graph_walks.py
"""

import tempfile

import numpy as np

from repro.launch.train import main as train_main

with tempfile.TemporaryDirectory() as ck:
    # phase 1: 120 steps, checkpoint every 40
    losses1 = train_main([
        "--arch", "internlm2-1.8b", "--scale", "11",
        "--steps", "120", "--batch", "8", "--seq", "64",
        "--lr", "2e-3", "--ckpt-dir", ck, "--ckpt-every", "40",
    ])
    # phase 2: ask for 200 steps -> resumes at 120, runs the remaining 80
    losses2 = train_main([
        "--arch", "internlm2-1.8b", "--scale", "11",
        "--steps", "200", "--batch", "8", "--seq", "64",
        "--lr", "2e-3", "--ckpt-dir", ck, "--ckpt-every", "40",
    ])

print(f"\nphase-1 loss: {np.mean(losses1[:10]):.3f} -> {np.mean(losses1[-10:]):.3f}")
print(f"phase-2 (resumed) continued to {np.mean(losses2[-10:]):.3f} "
      f"over {len(losses2)} additional steps")
assert len(losses2) < 200, "second run must resume, not restart"
assert np.mean(losses2[-10:]) < np.mean(losses1[:10])
print("end-to-end train + resume OK")

# phase 3: the same training loop fed from the external-memory tier —
# out-of-core generation, walk corpus streamed from a disk memmap
with tempfile.TemporaryDirectory() as wd:
    losses3 = train_main([
        "--arch", "internlm2-1.8b", "--scale", "11",
        "--steps", "60", "--batch", "8", "--seq", "64",
        "--lr", "2e-3", "--data", "external", "--workdir", wd,
    ])
print(f"external-data loss: {np.mean(losses3[:10]):.3f} -> "
      f"{np.mean(losses3[-10:]):.3f}")
assert np.mean(losses3[-10:]) < np.mean(losses3[:10])
print("out-of-core data path train OK")
