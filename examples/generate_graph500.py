"""Graph500 kernel-1 style run: distributed generation across every local
device, both pipeline variants, plus the literal out-of-core path.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/generate_graph500.py --scale 14
"""

import argparse
import tempfile
import time

import jax

from repro.core import validate as V
from repro.core.external import StreamingGenerator
from repro.core.pipeline import generate, generate_baseline_hash
from repro.core.types import GraphConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edge-factor", type=int, default=16)
    args = ap.parse_args()

    nb = len(jax.devices())
    cfg = GraphConfig(scale=args.scale, edge_factor=args.edge_factor,
                      nb=nb, capacity_factor=4.0)
    print(f"scale={args.scale} -> {cfg.n} vertices, {cfg.m} edges, "
          f"{nb} shards ('compute nodes')")

    # paper pipeline (sorted-merge CSR, the §III-B7 fast path)
    t0 = time.time()
    res = generate(cfg)
    jax.block_until_ready(res.csr.offv)
    t_paper = time.time() - t0
    assert int(res.dropped_redistribute) == 0
    assert V.check_permutation(res.pv)
    print(f"[paper pipeline]   {t_paper:.2f}s  "
          f"(TEPS ~ {cfg.m / t_paper:,.0f})")

    # memory-resident hash baseline (what the paper replaces)
    t0 = time.time()
    offv, adjv = generate_baseline_hash(cfg)
    jax.block_until_ready(offv)
    print(f"[hash baseline]    {time.time() - t0:.2f}s (single shard, "
          f"all-in-memory)")

    # literal out-of-core run (bounded host memory, I/O ledger)
    ext_cfg = cfg.with_(nb=min(nb, 2), scale=min(args.scale, 12))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        _, _, ledger = StreamingGenerator(ext_cfg, d).run()
        print(f"[out-of-core]      {time.time() - t0:.2f}s at scale "
              f"{ext_cfg.scale}; I/O ledger: {ledger.as_dict()}")
        assert ledger.rand_reads == 0 and ledger.rand_writes == 0, \
            "sorted path must be sequential-only"

    # fully external run: pv itself lives in disk bucket files (Alg. 2-4 on
    # disk); peak resident rows stay O(chunk_edges) regardless of scale
    xcfg = ext_cfg.with_(shuffle_variant="external")
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        gen = StreamingGenerator(xcfg, d)
        gen.run()
        print(f"[external shuffle] {time.time() - t0:.2f}s; peak resident "
              f"rows {gen.gauge.peak_rows} (n = {xcfg.n}); per-phase:")
        for rec in gen.orchestrator.report():
            print(f"    {rec['phase']:>14s}: {rec['seconds']:7.2f}s  "
                  f"seq r/w {rec['seq_reads']}/{rec['seq_writes']}  "
                  f"rand r/w {rec['rand_reads']}/{rec['rand_writes']}")


if __name__ == "__main__":
    main()
