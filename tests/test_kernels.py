"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import GraphConfig
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# R-MAT edge generation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scale", [4, 10, 16, 20])
@pytest.mark.parametrize("count", [64, 1000, 4096])
def test_rmat_kernel_matches_ref(scale, count):
    cfg = GraphConfig(scale=scale)
    s1, d1 = ops.rmat_edges(cfg, 0, count, mode="xla")
    s2, d2 = ops.rmat_edges(cfg, 0, count, mode="interpret")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    assert int(jnp.max(s1)) < cfg.n and int(jnp.min(s1)) >= 0
    assert int(jnp.max(d1)) < cfg.n and int(jnp.min(d1)) >= 0


@pytest.mark.parametrize("start", [0, 1000, 123457])
def test_rmat_kernel_start_offset_consistency(start):
    """Edges are a pure function of global index: generating [start, start+n)
    in one block equals slicing a bigger block — the property that makes
    regeneration-instead-of-checkpointing possible."""
    cfg = GraphConfig(scale=12)
    n = 512
    s_all, d_all = ops.rmat_edges(cfg, 0, start + n, mode="xla")
    s_blk, d_blk = ops.rmat_edges(cfg, start, n, mode="interpret")
    np.testing.assert_array_equal(np.asarray(s_all)[start:], np.asarray(s_blk))
    np.testing.assert_array_equal(np.asarray(d_all)[start:], np.asarray(d_blk))


def test_rmat_degree_bias_before_relabel():
    """R-MAT with a=0.57 biases small vertex ids to high degree (the reason
    the paper relabels at all)."""
    cfg = GraphConfig(scale=12)
    s, d = ops.rmat_edges(cfg, 0, cfg.m, mode="xla")
    s = np.asarray(s)
    lo = np.sum(s < cfg.n // 4)
    hi = np.sum(s >= 3 * cfg.n // 4)
    assert lo > 2 * hi, (lo, hi)


# ---------------------------------------------------------------------------
# bucket histogram
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [2, 8, 64])
@pytest.mark.parametrize("n", [16, 1000, 8192])
def test_bucket_hist_matches_ref(k, n):
    rng = np.random.default_rng(k * 1000 + n)
    dest = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    h1 = ops.bucket_hist(dest, k, mode="xla")
    h2 = ops.bucket_hist(dest, k, mode="interpret")
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(h1), np.bincount(np.asarray(dest), minlength=k))


# ---------------------------------------------------------------------------
# relabel gather (sort-merge-join kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [128, 1024])
@pytest.mark.parametrize("n_keys", [64, 500, 2048])
def test_relabel_gather_matches_ref(chunk, n_keys):
    rng = np.random.default_rng(chunk + n_keys)
    pv = jnp.asarray(rng.permutation(chunk), jnp.int32)
    keys = jnp.sort(jnp.asarray(rng.integers(0, chunk, n_keys), jnp.int32))
    r1 = ops.relabel_gather(keys, pv, 0, mode="xla")
    r2 = ops.relabel_gather(keys, pv, 0, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(pv)[np.asarray(keys)])


def test_relabel_gather_with_base_offset():
    rng = np.random.default_rng(7)
    chunk, base = 256, 1024
    pv = jnp.asarray(rng.permutation(chunk), jnp.int32)
    keys = jnp.sort(jnp.asarray(rng.integers(base, base + chunk, 512), jnp.int32))
    r1 = ops.relabel_gather(keys, pv, base, mode="xla")
    r2 = ops.relabel_gather(keys, pv, base, mode="interpret")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,S,hd", [(1, 1, 128, 64), (2, 4, 256, 64),
                                      (1, 2, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, S, hd, dtype):
    rng = np.random.default_rng(B * H * S)
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, H, S, hd)), dtype)
    o1 = ops.flash_attention(q, k, v, causal=True, mode="xla")
    o2 = ops.flash_attention(q, k, v, causal=True, mode="interpret")
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=False, mode="xla")
    o2 = ops.flash_attention(q, k, v, causal=False, mode="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


def test_flash_attention_matches_naive_softmax():
    """The XLA ref itself must equal a naive full-softmax implementation."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    logits = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(32)
    mask = np.tril(np.ones((64, 64), bool))
    logits = np.where(mask, logits, -np.inf)
    w = np.exp(logits - logits.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    naive = np.einsum("bhqk,bhkd->bhqd", w, np.asarray(v))
    out = ops.flash_attention(q, k, v, causal=True, mode="xla")
    np.testing.assert_allclose(np.asarray(out), naive, atol=1e-5)
