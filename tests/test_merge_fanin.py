"""Bounded-fan-in cascaded external merge + disk-tier silent-corruption
guards.

The scale regime these tests simulate is num_runs >> max_run rows: the flat
merge's per-cursor block shrinks to max(1, max_run // nruns) rows (per-row
heap pops) and its open-memmap count grows with the store, while the cascade
keeps both bounded by max_fanin.  Bit-identity between the two paths is the
acceptance bar — the cascade is an I/O-shape optimization, never a semantic
change.
"""

import os
import resource

import numpy as np
import pytest

from repro.core.blockstore import (
    BlockStore,
    IOLedger,
    MemoryGauge,
    MonotoneLookup,
    clean_cascade_stores,
    merge_runs,
    partition_runs,
    sort_runs,
)
from repro.core.phases import PhaseOrchestrator, plain_config
from repro.core.types import GraphConfig


def _many_run_store(workdir, nruns, run_rows, seed=0, name="runs",
                    key_lo=0, key_hi=1000):
    """A store of `nruns` sorted runs with heavy key collisions ACROSS runs
    and payloads unique per record, so bit-identity checks catch any
    equal-key stability difference between merge paths."""
    ledger, gauge = IOLedger(), MemoryGauge()
    store = BlockStore(workdir, name, ledger, columns=("k", "p"), gauge=gauge)
    rng = np.random.default_rng(seed)
    for i in range(nruns):
        k = np.sort(rng.integers(key_lo, key_hi, run_rows))
        p = i * run_rows + np.arange(run_rows)
        store.append_run(k, p)
    return store


def _merged_cols(store, **kw):
    blocks = list(merge_runs(store, key=0, **kw))
    if not blocks:
        return tuple(np.zeros(0, np.int64) for _ in range(store.ncols))
    return tuple(np.concatenate([b[c] for b in blocks])
                 for c in range(store.ncols))


# ---------------------------------------------------------------------------
# cascade vs flat: bit-identity
# ---------------------------------------------------------------------------


def test_cascade_bit_identical_across_fanin_sweep(tmp_path):
    """57 runs of 13 rows (nruns >> max_run): every fan-in — including the
    two-level regime max_fanin < nruns < max_fanin**2 and the degenerate
    max_fanin >= nruns — yields the flat merge's exact record stream."""
    store = _many_run_store(str(tmp_path), nruns=57, run_rows=13)
    flat_k, flat_p = _merged_cols(store, max_fanin=0)
    assert flat_k.size == 57 * 13
    np.testing.assert_array_equal(flat_k, np.sort(flat_k))
    for fanin in (2, 3, 7, 8, 16, 56, 57, 64):
        k, p = _merged_cols(store, max_fanin=fanin)
        np.testing.assert_array_equal(k, flat_k)
        np.testing.assert_array_equal(p, flat_p)
        # cascade scratch is destroyed once the generator is exhausted
        assert not [d for d in os.listdir(str(tmp_path)) if "__cas_l" in d]


def test_cascade_bit_identical_with_callable_key_and_blocks(tmp_path):
    """Callable (recomputed) keys and explicit block_rows through a 3-level
    cascade (2 < 37 runs < no bound)."""
    ledger = IOLedger()
    store = BlockStore(str(tmp_path), "hashed", ledger, columns=("v", "p"))
    rng = np.random.default_rng(3)

    def key(v, p):
        return (v * 2654435761) % 977

    for i in range(37):
        v = rng.integers(0, 10_000, 29)
        p = i * 29 + np.arange(29)
        order = np.argsort(key(v, p), kind="stable")
        store.append_run(v[order], p[order])

    def merged(fanin):
        blocks = list(merge_runs(store, key=key, max_fanin=fanin, block_rows=5))
        return tuple(np.concatenate([b[c] for b in blocks]) for c in range(2))

    flat = merged(0)
    # flat merge over stable-sorted runs == one global stable sort
    allc = [np.concatenate([store.read_run(i)[c] for i in range(37)])
            for c in range(2)]
    order = np.argsort(key(*allc), kind="stable")
    for a, b in zip(flat, allc):
        np.testing.assert_array_equal(a, b[order])
    for fanin in (2, 5, 36):
        for a, b in zip(flat, merged(fanin)):
            np.testing.assert_array_equal(a, b)


def test_cascade_empty_and_single_run_edges(tmp_path):
    ledger = IOLedger()
    store = BlockStore(str(tmp_path), "edge", ledger, columns=("k",))
    assert list(merge_runs(store, max_fanin=4)) == []
    store.append_run(np.array([], np.int64))
    store.append_run(np.array([5, 7], np.int64))
    store.append_run(np.array([], np.int64))
    store.append_run(np.array([1, 9], np.int64))
    store.append_run(np.array([2], np.int64))
    (k,) = _merged_cols(store, max_fanin=2)
    np.testing.assert_array_equal(k, [1, 2, 5, 7, 9])


def test_merge_fanin_one_rejected(tmp_path):
    store = _many_run_store(str(tmp_path), nruns=3, run_rows=4)
    with pytest.raises(ValueError, match="max_fanin"):
        list(merge_runs(store, key=0, max_fanin=1))
    with pytest.raises(ValueError, match="merge_fanin"):
        plain_config(GraphConfig(scale=8, merge_fanin=1))


# ---------------------------------------------------------------------------
# cascade: bounded memory + bounded open files
# ---------------------------------------------------------------------------


def test_cascade_peak_rows_stays_o_chunk(tmp_path):
    """With 300 tiny runs the FLAT merge's cursor-buffer gauge grows with
    nruns; the cascade's stays O(max_run) — the measurable form of the
    bounded-buffer claim at high fan-in."""
    run_rows = 8
    # flat contrast kept to 120 runs so it still fits when this suite runs
    # under the CI step's lowered `ulimit -n`
    flat = _many_run_store(str(tmp_path), 120, run_rows, name="flat")
    _merged_cols(flat, max_fanin=0)
    assert flat.gauge.peak_rows >= 120  # block_rows*nruns: grows with store

    cas = _many_run_store(str(tmp_path), 300, run_rows, name="cas")
    cas.gauge.peak_rows = 0  # ignore the build-side appends
    k, p = _merged_cols(cas, max_fanin=8)
    assert k.size == 300 * run_rows
    # cursor buffers (<= max_run) + one flush block (< 2*max_run)
    assert cas.gauge.peak_rows <= 4 * run_rows


def _live_fds():
    return len(os.listdir("/proc/self/fd"))


@pytest.mark.skipif(not os.path.isdir("/proc/self/fd"),
                    reason="needs /proc fd accounting")
def test_cascade_open_files_bounded_under_rlimit(tmp_path):
    """The ulimit failure mode itself: under a lowered RLIMIT_NOFILE a
    200-cursor flat merge dies on open-file exhaustion, while the cascaded
    merge (<= max_fanin runs open at any instant, by construction of the
    one-memmap-per-cursor segment cursor) completes bit-identically."""
    nruns, max_fanin = 200, 8
    store = _many_run_store(str(tmp_path), nruns, run_rows=6, name="lim")
    flat_k, flat_p = _merged_cols(store, max_fanin=0)
    soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
    headroom = 40  # scratch fds: output .npy writes, pytest internals
    limit = _live_fds() + headroom
    assert limit < soft, "test environment already near its fd limit"
    resource.setrlimit(resource.RLIMIT_NOFILE, (limit, hard))
    try:
        with pytest.raises(OSError):
            _merged_cols(store, max_fanin=0)  # 200 memmaps > limit
        k, p = _merged_cols(store, max_fanin=max_fanin)
    finally:
        resource.setrlimit(resource.RLIMIT_NOFILE, (soft, hard))
    np.testing.assert_array_equal(k, flat_k)
    np.testing.assert_array_equal(p, flat_p)


# ---------------------------------------------------------------------------
# silent-corruption guards
# ---------------------------------------------------------------------------


def test_uint64_keys_past_2_63_fully_drained(tmp_path):
    """Callable uint64 hash keys >= 2^63 exceed any int64 bound: the final
    drain must use the no-bound sentinel, not a max int (which under-drains
    and previously live-locked the last cursor)."""
    ledger = IOLedger()
    store = BlockStore(str(tmp_path), "u64", ledger, columns=("v", "p"))

    def key(v, p):
        # strictly above 2^63 for v >= 0 — every key out of int64 range
        return v.astype(np.uint64) + np.uint64(1 << 63)

    rng = np.random.default_rng(11)
    for i in range(5):
        v = np.sort(rng.integers(0, 1 << 40, 50))
        store.append_run(v, i * 50 + np.arange(50))
    for fanin in (0, 2, 3):
        blocks = list(merge_runs(store, key=key, max_fanin=fanin))
        v = np.concatenate([b[0] for b in blocks])
        assert v.size == 5 * 50  # nothing dropped
        np.testing.assert_array_equal(v, np.sort(v))  # key order == v order


def test_monotone_lookup_rejects_regressing_probe(tmp_path):
    ledger = IOLedger()
    table = np.arange(100, 200)
    store = BlockStore(str(tmp_path), "pv", ledger, columns=("v",))
    for lo in range(0, 100, 10):
        store.append_run(table[lo:lo + 10])
    # regression WITHIN one call
    lk = MonotoneLookup([store], block_rows=8)
    with pytest.raises(ValueError, match="regressed within"):
        lk.lookup(np.array([5, 3]))
    # regression ACROSS calls: consumed prefix may never be re-probed
    lk = MonotoneLookup([store], block_rows=8)
    np.testing.assert_array_equal(lk.lookup(np.array([40, 41])), [140, 141])
    with pytest.raises(ValueError, match="regressed"):
        lk.lookup(np.array([2]))
    # probe below `base` (would index _vals negatively and WRAP, not error)
    lk = MonotoneLookup([store], block_rows=8, base=50)
    with pytest.raises(ValueError, match="regressed"):
        lk.lookup(np.array([10]))


def test_partition_runs_rejects_out_of_range_bucket(tmp_path):
    ledger = IOLedger()
    store = BlockStore(str(tmp_path), "src", ledger, columns=("a", "b"))
    store.append_run(np.array([0, 1, 2, 3]), np.array([0, 10, 20, 30]))
    outs = [BlockStore(str(tmp_path), f"out_{d}", ledger, columns=("a", "b"))
            for d in range(2)]
    with pytest.raises(ValueError, match="outside"):
        partition_runs(store, outs, lambda a, b: a)  # buckets 2, 3 invalid
    with pytest.raises(ValueError, match="outside"):
        partition_runs(store, outs, lambda a, b: a - 1)  # bucket -1 invalid
    # in-range still works, and nothing was half-written by the failures
    partition_runs(store, outs, lambda a, b: a % 2)
    assert sum(o.total_rows() for o in outs) == 4


# ---------------------------------------------------------------------------
# orchestration: resume sweeps crashed-cascade scratch; end-to-end parity
# ---------------------------------------------------------------------------


def test_orchestrator_sweeps_stale_cascade_stores(tmp_path):
    stale = tmp_path / "edges_b000__cas_l0_g0000"
    stale.mkdir()
    (stale / "run_000000.npy").write_bytes(b"junk")
    real = tmp_path / "edges_b000"
    real.mkdir()
    PhaseOrchestrator(str(tmp_path), IOLedger(), checkpoint=True)
    assert not stale.exists()
    assert real.exists()  # only cascade scratch is swept
    clean_cascade_stores(str(tmp_path / "nonexistent"))  # no-op, no raise


def test_generator_bit_identical_at_tiny_merge_fanin(tmp_path):
    """End-to-end plumbing: the full external pipeline (shuffle rounds,
    relabel joins, CSR build) at merge_fanin=2 — cascades in every phase —
    produces byte-identical pv AND CSR to the flat-merge pipeline."""
    from repro.core.external import StreamingGenerator

    base = GraphConfig(scale=9, nb=4, chunk_edges=128, edge_factor=4,
                       shuffle_variant="external")
    pv_f, csr_f, _ = StreamingGenerator(
        base.with_(merge_fanin=0), str(tmp_path / "flat")).run()
    gen = StreamingGenerator(base.with_(merge_fanin=2), str(tmp_path / "cas"))
    pv_c, csr_c, _ = gen.run()
    np.testing.assert_array_equal(np.asarray(pv_f), np.asarray(pv_c))
    for (of, af), (oc, ac) in zip(csr_f, csr_c):
        np.testing.assert_array_equal(of, oc)
        np.testing.assert_array_equal(np.asarray(af), np.asarray(ac))
    # a fan-in this small forces cascades yet leaves no scratch behind
    assert not [d for d in os.listdir(str(tmp_path / "cas")) if "__cas_l" in d]


def test_external_walks_bit_identical_at_tiny_merge_fanin(tmp_path):
    """The walk-hop frontier sorts and history gather also ride the cascade:
    same corpus at merge_fanin=2 as at flat fan-in."""
    from repro.core.external import StreamingGenerator
    from repro.data.walks import external_walks

    base = GraphConfig(scale=8, nb=2, chunk_edges=128, edge_factor=4,
                       shuffle_variant="external")
    corpora = {}
    for tag, fanin in (("flat", 0), ("cas", 2)):
        wd = str(tmp_path / tag)
        cfg = base.with_(merge_fanin=fanin)
        StreamingGenerator(cfg, wd).run()
        res = external_walks(cfg, wd, num_walkers=48, length=6, seed=5)
        corpora[tag] = np.asarray(res.walks).copy()
    np.testing.assert_array_equal(corpora["flat"], corpora["cas"])
