"""Hypothesis property tests on the external walk sampler's primitives:
frontier sort -> sort-merge-join -> owner partition round trips (the per-hop
pipeline of data/walks.external_walks, exercised on random inputs)."""

import tempfile

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.blockstore import (  # noqa: E402
    BlockStore, IOLedger, MemoryGauge, MonotoneLookup, NpyColumnStore,
    merge_runs, partition_runs, sort_runs)
from repro.core.hostgen import walk_rand_np, walk_start_np  # noqa: E402

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(
    n_walkers=st.integers(1, 200),
    nb=st.integers(1, 6),
    log_b=st.integers(2, 6),
    chunk=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_frontier_partition_sort_round_trip(n_walkers, nb, log_b, chunk, seed):
    """partition-by-owner -> per-bucket external sort is lossless: the union
    of the sorted buckets is the original (pos, wid) multiset, every row
    lands in its owner bucket, and each bucket streams out pos-sorted."""
    B = 1 << log_b
    rng = np.random.default_rng(seed)
    pos = rng.integers(0, nb * B, n_walkers).astype(np.int64)
    wid = np.arange(n_walkers, dtype=np.int64)
    ledger = IOLedger()
    with tempfile.TemporaryDirectory() as d:
        src = BlockStore(d, "front", ledger, columns=("pos", "wid"))
        for lo in range(0, n_walkers, chunk):
            src.append_run(pos[lo:lo + chunk], wid[lo:lo + chunk])
        outs = [BlockStore(d, f"b{j}", ledger, columns=("pos", "wid"))
                for j in range(nb)]
        partition_runs(src, outs, lambda p, w: p // B)
        got = []
        for j, out in enumerate(outs):
            srt = BlockStore(d, f"s{j}", ledger, columns=("pos", "wid"))
            sort_runs(out, srt, key=0)
            blocks = list(merge_runs(srt, key=0, block_rows=chunk))
            if not blocks:
                continue
            p = np.concatenate([b[0] for b in blocks])
            w = np.concatenate([b[1] for b in blocks])
            assert (p // B == j).all()          # ownership
            assert (np.diff(p) >= 0).all()      # sorted stream
            got.append(np.stack([p, w], 1))
        got = np.concatenate(got) if got else np.zeros((0, 2), np.int64)
        order_got = np.lexsort((got[:, 0], got[:, 1]))
        order_ref = np.lexsort((pos, wid))
        np.testing.assert_array_equal(got[order_got][:, 0], pos[order_ref])
        np.testing.assert_array_equal(got[order_got][:, 1], wid[order_ref])
    assert ledger.rand_reads == 0 == ledger.rand_writes


@SETTINGS
@given(
    rows=st.integers(1, 120),
    probes=st.integers(1, 300),
    block=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_monotone_lookup_join_matches_gather(rows, probes, block, seed):
    """The offv sort-merge-join half: MonotoneLookup over an NpyColumnStore
    equals a direct table gather for any nondecreasing probe stream, charges
    every block load to the ledger, and reports its buffers to the gauge."""
    rng = np.random.default_rng(seed)
    table = rng.integers(0, 1 << 40, rows).astype(np.int64)
    keys = np.sort(rng.integers(0, rows, probes)).astype(np.int64)
    ledger, gauge = IOLedger(), MemoryGauge()
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/offv.npy"
        np.save(path, table)
        lk = MonotoneLookup([NpyColumnStore(path, ledger, gauge)],
                            block_rows=block, gauge=gauge)
        cut = probes // 2
        got = np.concatenate([lk.lookup(keys[:cut]), lk.lookup(keys[cut:])])
    np.testing.assert_array_equal(got, table[keys])
    assert ledger.bytes_read > 0 and ledger.rand_reads == 0
    assert gauge.peak_rows <= max(block, probes)


@SETTINGS
@given(
    walkers=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    step=st.integers(0, 40),
    log_n=st.integers(1, 20),
)
def test_walk_rng_counter_properties(walkers, seed, step, log_n):
    """The shared walk RNG is a pure counter function: order-independent,
    and start vertices always land in [0, n)."""
    wid = np.arange(walkers, dtype=np.uint32)
    a = walk_rand_np(seed, wid, step)
    perm = np.random.default_rng(seed).permutation(walkers)
    b = walk_rand_np(seed, wid[perm], step)
    np.testing.assert_array_equal(a[perm], b)   # value depends only on (w, t)
    n = 1 << log_n
    starts = walk_start_np(seed, wid, n)
    assert starts.dtype == np.int64
    assert ((starts >= 0) & (starts < n)).all()
