"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.types import GraphConfig, owner_of, quadrant_thresholds
from repro.distributed.collectives import (
    bucket_by_destination, merge_sorted_runs, merge_two_sorted, unbucket)
from repro.kernels import ref
from repro.serve.sampling import SamplingParams, sample
from repro.train.fault import StragglerPolicy

SETTINGS = settings(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# bucketing (the paper's Alg. 8 under static shapes)
# ---------------------------------------------------------------------------


@SETTINGS
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 8),
    cap_frac=st.floats(0.1, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_bucket_invariants(n, k, cap_frac, seed):
    rng = np.random.default_rng(seed)
    dest = rng.integers(0, k, n).astype(np.int32)
    data = rng.integers(0, 1 << 30, n).astype(np.int32)
    capacity = max(1, int(n * cap_frac / k))
    b = bucket_by_destination(jnp.asarray(data), jnp.asarray(dest), k, capacity)

    data_np = np.asarray(b.data)
    valid_np = np.asarray(b.valid)
    # 1. dropped count is exact
    exp_dropped = sum(max(0, int((dest == j).sum()) - capacity) for j in range(k))
    assert int(b.dropped) == exp_dropped
    # 2. kept records form a sub-multiset, stable within destination
    for j in range(k):
        want = data[dest == j][:capacity]
        got = data_np[j][valid_np[j]]
        np.testing.assert_array_equal(got, want)
    # 3. round trip: unbucket returns every kept record to its origin
    back = np.asarray(unbucket(b.data, b.position, fill=-1))
    kept = back != -1
    np.testing.assert_array_equal(back[kept], data[kept])
    assert kept.sum() == n - exp_dropped


@SETTINGS
@given(n=st.integers(0, 200), m=st.integers(0, 200), seed=st.integers(0, 2**31 - 1))
def test_merge_two_sorted(n, m, seed):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(0, 1000, n)).astype(np.int32)
    b = np.sort(rng.integers(0, 1000, m)).astype(np.int32)
    out = np.asarray(merge_two_sorted(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(out, np.sort(np.concatenate([a, b]), kind="stable"))


@SETTINGS
@given(logk=st.integers(0, 3), run=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_merge_sorted_runs(logk, run, seed):
    k = 1 << logk
    rng = np.random.default_rng(seed)
    runs = np.sort(rng.integers(0, 10_000, (k, run)), axis=1).astype(np.int32)
    out = np.asarray(merge_sorted_runs(jnp.asarray(runs)))
    np.testing.assert_array_equal(out, np.sort(runs.reshape(-1)))


def test_merge_sorted_runs_payload():
    rng = np.random.default_rng(0)
    keys = np.sort(rng.integers(0, 100, (4, 16)), axis=1).astype(np.int32)
    payload = keys * 7 + 1
    k, p = merge_sorted_runs(jnp.asarray(keys), jnp.asarray(payload))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(k) * 7 + 1)


# ---------------------------------------------------------------------------
# R-MAT / graph config invariants
# ---------------------------------------------------------------------------


@SETTINGS
@given(scale=st.integers(2, 24), count=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1))
def test_rmat_ref_in_range_and_deterministic(scale, count, seed):
    cfg = GraphConfig(scale=scale, seed=seed)
    s1, d1 = ref.rmat_ref(cfg, 0, count)
    s2, d2 = ref.rmat_ref(cfg, 0, count)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert int(jnp.min(s1)) >= 0 and int(jnp.max(s1)) < cfg.n
    assert int(jnp.min(d1)) >= 0 and int(jnp.max(d1)) < cfg.n


def test_quadrant_thresholds_sum():
    cfg = GraphConfig()
    t_src, t_dst0, t_dst1 = quadrant_thresholds(cfg)
    # P(src=1) = c + d = 0.24
    assert abs(t_src / 2**32 - (cfg.c + cfg.d)) < 1e-6
    assert abs(t_dst0 / 2**32 - cfg.b / (cfg.a + cfg.b)) < 1e-6
    assert abs(t_dst1 / 2**32 - cfg.d / (cfg.c + cfg.d)) < 1e-6


@SETTINGS
@given(v=st.integers(0, 2**20 - 1), logb=st.integers(0, 20))
def test_owner_of(v, logb):
    B = 1 << logb
    assert int(owner_of(jnp.asarray(v), B)) == v // B


# ---------------------------------------------------------------------------
# straggler planning
# ---------------------------------------------------------------------------


@SETTINGS
@given(
    n=st.integers(1, 16),
    mb_per=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_straggler_plan_conserves_work(n, mb_per, seed):
    rng = np.random.default_rng(seed)
    times = rng.uniform(0.5, 5.0, n)
    policy = StragglerPolicy()
    micro = n * mb_per
    plan = policy.plan(times, micro)
    assert sum(plan) == micro
    assert all(p >= policy.min_share for p in plan)


def test_straggler_plan_shifts_work():
    policy = StragglerPolicy(slow_factor=1.5)
    times = [1.0, 1.0, 1.0, 10.0]   # worker 3 is 10x slower
    plan = policy.plan(times, 16)
    assert plan[3] < 4              # sheds load
    assert max(plan[:3]) > 4        # fast workers pick it up
    assert sum(plan) == 16


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 1000), step=st.integers(0, 100))
def test_sampling_greedy_and_topk(seed, step):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal(50)
    assert sample(logits, SamplingParams(temperature=0.0), step) == int(np.argmax(logits))
    tok = sample(logits, SamplingParams(temperature=1.0, top_k=5, seed=seed), step)
    top5 = np.argsort(logits)[-5:]
    assert tok in top5
    # determinism
    tok2 = sample(logits, SamplingParams(temperature=1.0, top_k=5, seed=seed), step)
    assert tok == tok2
