"""HLO cost model validation: the trip-count-aware analyzer vs XLA's own
cost_analysis on loop-free programs, and trip-count correction on scans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost
from repro.launch.roofline import Roofline


def _flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    t = hlo_cost.analyze(compiled.as_text())
    xla = compiled.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    return t.flops, float(xla.get("flops", 0.0)), t


def test_matmul_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    ours, xla, _ = _flops(lambda a, b: a @ b, a, b)
    assert ours == 2 * 128 * 512 * 256
    assert xla == pytest.approx(ours, rel=0.01)


def test_batched_matmul_flops():
    a = jnp.zeros((4, 64, 32), jnp.float32)
    b = jnp.zeros((4, 32, 16), jnp.float32)
    ours, xla, _ = _flops(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    assert ours == 2 * 4 * 64 * 16 * 32


def test_scan_trip_count_multiplies():
    """A scan of L matmuls must cost L x the single matmul — the exact
    failure mode of raw cost_analysis this module exists to fix."""
    L = 12
    w = jnp.zeros((L, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(x, w):
        def body(c, wl):
            return c @ wl, None
        out, _ = jax.lax.scan(body, x, w)
        return out

    ours, xla, _ = _flops(fn, x, w)
    single = 2 * 8 * 64 * 64
    assert ours == L * single, (ours, L * single)
    # and XLA's own count indeed misses the trip count (documents the why)
    assert xla < ours


def test_nested_scan_trip_counts():
    G, E = 3, 4
    w = jnp.zeros((G, E, 32, 32), jnp.float32)
    x = jnp.zeros((2, 32), jnp.float32)

    def fn(x, w):
        def inner(c, wl):
            return c @ wl, None

        def outer(c, wg):
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None

        out, _ = jax.lax.scan(outer, x, w)
        return out

    ours, _, _ = _flops(fn, x, w)
    assert ours == G * E * 2 * 2 * 32 * 32


def test_bytes_reasonable_for_copy():
    x = jnp.zeros((1024, 1024), jnp.float32)
    compiled = jax.jit(lambda x: x * 2.0).lower(x).compile()
    t = hlo_cost.analyze(compiled.as_text())
    assert 2 * x.nbytes <= t.bytes <= 4 * x.nbytes


def test_collective_parsing_synthetic():
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[256,256]{1,0} all-gather(%ar), dimensions={0}
  ROOT %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    comps, entry = hlo_cost.parse_module(hlo)
    t = hlo_cost.CostTotals()
    hlo_cost._cost_comp(entry, 1.0, comps, t)
    assert t.coll["all-reduce"] == 128 * 256 * 4
    assert t.coll["all-gather"] == 128 * 256 * 4   # operand, not result
    assert t.coll["collective-permute"] == 128 * 256 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_chip=197e12, bytes_per_chip=819e9 / 2,
                 coll_bytes_per_chip=0.0, coll_by_kind={}, chips=256,
                 model_flops=256 * 197e12 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.mfu_bound == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_dus_counts_update_only():
    big = jnp.zeros((1024, 1024), jnp.float32)
    upd = jnp.zeros((1, 1024), jnp.float32)

    def fn(big, upd):
        return jax.lax.dynamic_update_slice(big, upd, (jnp.int32(3), jnp.int32(0)))

    compiled = jax.jit(fn, donate_argnums=(0,)).lower(big, upd).compile()
    t = hlo_cost.analyze(compiled.as_text())
    assert t.bytes <= 20 * upd.nbytes, t.bytes  # not the 4MB buffer
