"""Multi-tenant job queue: work-stealing scheduler, fused walk batches,
dead-letter parking, lease re-dispatch after a host kill, and the
cluster-runtime bugfix sweep (derived heartbeat period, condition-variable
barriers with idle CPU, structured retry-exhaustion errors).

The acceptance contract: a 2-host queue of >= 3 concurrent jobs produces
bit-identical CSR + corpus artifacts to the same jobs run serially, a
poisoned job dead-letters after its lease budget while the rest drain and
its partial stores are GC'd, and a killed host's leased tasks re-dispatch
without re-running any completed task.
"""

import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import repro
from repro.core.cluster import (
    ClusterGenerator,
    ClusterSpec,
    LocalExecBackend,
    TaskError,
    heartbeat_period,
)
from repro.core.corpus import ShardedWalks, manifest_name
from repro.core.jobqueue import (
    JobScheduler,
    JobSpec,
    load_state,
    submit_job,
)
from repro.core.phases import (
    PartitionedGenerator,
    phase_task_plan,
    plain_config,
)
from repro.core.types import GraphConfig

_SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
_ENV = {"PYTHONPATH": _SRC}

CFG = GraphConfig(scale=8, nb=4, chunk_edges=256, edge_factor=4,
                  shuffle_variant="recompute", transport="socket")
JOBS = [
    dict(cfg=CFG.with_(seed=1), fuse_gen_relabel=True, fuse_walks=True,
         walks=[(8, 3, 1, "a.npy"), (8, 3, 2, "b.npy")]),
    dict(cfg=CFG.with_(seed=2), walks=[(8, 3, 7, "c.npy")]),
    dict(cfg=CFG.with_(scale=9, seed=3), fuse_gen_relabel=True, walks=[]),
]


def _sha_file(path):
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def _sha_csr(manifest_path):
    with open(manifest_path) as f:
        m = json.load(f)
    h = hashlib.sha256()
    for b in m["buckets"]:
        for k in ("offv", "adjv"):
            h.update(_sha_file(os.path.join(b["workdir"], b[k])).encode())
    return h.hexdigest()


def _sha_corpus(manifest_path):
    arr = np.ascontiguousarray(np.array(ShardedWalks(manifest_path)))
    return hashlib.sha256(arr.tobytes()).hexdigest()


def _artifacts(ctrl_dir, jobdef, tag):
    wd = os.path.join(ctrl_dir, tag)
    out = {"csr": _sha_csr(os.path.join(wd, "graph_manifest.json"))}
    for (_, _, _, o) in jobdef.get("walks", []):
        out[o] = _sha_corpus(os.path.join(wd, manifest_name(o)))
    return out


def _scheduler(root, backend=None, **kw):
    spec = ClusterSpec.local(2, os.path.join(root, "hosts"), nb=CFG.nb)
    kw.setdefault("heartbeat_timeout", 20.0)
    return JobScheduler(spec, os.path.join(root, "ctrl"),
                        backend=backend if backend is not None
                        else LocalExecBackend(env=_ENV), **kw)


def _submit_all(sched, jobs=JOBS):
    return [sched.submit(j["cfg"], walks=j.get("walks", ()),
                         fuse_walks=j.get("fuse_walks", False),
                         fuse_gen_relabel=j.get("fuse_gen_relabel", False))
            for j in jobs]


class _KillHost1First(LocalExecBackend):
    """Crash injection: host 1's FIRST launch dies hard (os._exit) after
    executing a handful of tasks — mid-lease, like kill -9."""

    def __init__(self, max_tasks=6):
        super().__init__(env=_ENV)
        self.max_tasks = max_tasks

    def host_args(self, host, attempt):
        if host.host_id == 1 and attempt == 0:
            return ["--max-tasks", str(self.max_tasks)]
        return []


# ---------------------------------------------------------------------------
# bugfix sweep units
# ---------------------------------------------------------------------------


def test_heartbeat_period_derived_and_clamped():
    """timeout/8, clamped to [0.2, 15]: short-timeout tests don't flap,
    long-timeout deployments don't spam the control socket (the old code
    hard-coded 2.0s for every timeout)."""
    assert heartbeat_period(16.0) == 2.0
    assert heartbeat_period(60.0) == 7.5
    assert heartbeat_period(0.5) == 0.2      # floor
    assert heartbeat_period(1e6) == 15.0     # ceiling
    assert heartbeat_period(8 * 0.2) * 8 <= 8 * 0.2 + 1e-9


def test_task_error_is_structured_and_job_scoped():
    e = TaskError("task k failed", task_key="gen:generate:3", attempts=2,
                  job="job0007")
    assert e.task_key == "gen:generate:3"
    assert e.attempts == 2
    assert e.job == "job0007"
    from repro.core.cluster import ClusterError
    assert isinstance(e, ClusterError)   # schedulers catch the subclass


def test_lease_steals_only_migratable_tail_tasks(tmp_path):
    """The work-stealing discipline on a bare controller: an idle host's
    lease first drains its own queue head; only then does it steal, taking
    stealable tasks from the longest victim queue's TAIL while leaving the
    owner-bound tasks in their original order."""
    from repro.core.cluster import ClusterController
    spec = ClusterSpec.local(2, str(tmp_path), nb=CFG.nb)
    ctl = ClusterController(spec, backend=None, lease_size=2)
    try:
        def _task(tid, owner, stealable):
            return {"id": tid, "key": f"k{tid}", "kernel": "x", "args": (),
                    "attempt": 0, "job": "job0000", "stealable": stealable,
                    "owner": owner}
        with ctl._lock:
            ctl._queues[1].extend(_task(t, 1, s) for t, s in
                                  ((0, False), (1, True), (2, False),
                                   (3, True), (4, True)))
            # own work first: host 1 pops its head, nothing counts as stolen
            lease = ctl._lease_locked(1)
            assert [t["id"] for t in lease] == [0, 1] and ctl.steals == 0
            # idle host 0 steals from the tail, skipping owner-bound task 2
            lease = ctl._lease_locked(0)
            assert [t["id"] for t in lease] == [4, 3]
            assert ctl.steals == 2
            assert set(ctl._inflight[0]) == {3, 4}
            assert [t["id"] for t in ctl._queues[1]] == [2]
            # nothing stealable left: host 0 comes up empty, no churn
            assert ctl._lease_locked(0) == [] and ctl.steals == 2
    finally:
        ctl.stop()


def test_phase_task_plan_shapes_and_rejections():
    pcfg = plain_config(CFG)
    plan = phase_task_plan(pcfg, walks=[(8, 3, 1, "a.npy")])
    phases = [p["phase"] for p in plan]
    assert phases[0] == "generate" and "csr_sorted" in phases
    for p in plan:
        for d in p["deps"]:
            assert phases.index(d) < phases.index(p["phase"])
    # fused: one walk_hop_fused barrier per hop regardless of corpus count
    fused = phase_task_plan(pcfg, walks=[(8, 3, 1, "a.npy"),
                                         (8, 3, 2, "b.npy")],
                            fuse_walks=True, fuse_gen_relabel=True)
    hop = [p for p in fused if p["phase"] == "walk_hop_0000"]
    assert len(hop) == 1 and len(hop[0]["keys"]) == pcfg.nb
    init = next(p for p in fused if p["phase"] == "walk_init")
    assert len(init["keys"]) == 2 * pcfg.nb       # one per (config, bucket)
    assert any(k.endswith(":w1_") for k in init["keys"])
    with pytest.raises(ValueError, match="pooled_cascade"):
        phase_task_plan(plain_config(CFG.with_(pooled_cascade=True)))
    with pytest.raises(ValueError, match="equal lengths"):
        phase_task_plan(pcfg, walks=[(8, 3, 1, "a.npy"), (8, 4, 2, "b.npy")],
                        fuse_walks=True)
    with pytest.raises(ValueError, match="recompute"):
        phase_task_plan(plain_config(CFG.with_(shuffle_variant="external")),
                        fuse_gen_relabel=True)


def test_submit_persists_and_round_trips(tmp_path):
    root = str(tmp_path)
    j = submit_job(root, CFG, walks=[(8, 3, 1, "a.npy")], fuse_walks=False,
                   name="first")
    assert j.job_id == 0 and j.tag == "job0000"
    j2 = submit_job(root, CFG.with_(seed=9))
    assert j2.job_id == 1
    state = load_state(root)
    back = [JobSpec.from_json(d) for d in state["jobs"]]
    assert [b.tag for b in back] == ["job0000", "job0001"]
    assert back[0].name == "first" and back[0].status == "queued"
    assert back[0].num_tasks == j.num_tasks > 0
    assert back[0].plan == j.plan


# ---------------------------------------------------------------------------
# fused corpora parity (single host — the fusion itself, no cluster)
# ---------------------------------------------------------------------------


def test_fused_walks_and_gen_relabel_bit_identical(tmp_path):
    """walk_corpus_fused: k corpora through one CSR scan per hop, each
    bit-identical to its own walk_corpus run; fused gen_relabel matches the
    two-phase recompute pipeline."""
    specs = [(12, 4, 3, "s3.npy"), (12, 4, 5, "s5.npy"), (12, 4, 9, "s9.npy")]
    ref = {}
    with PartitionedGenerator(CFG.with_(transport="fs"), str(tmp_path / "r"),
                              max_workers=0) as part:
        csr, _ = part.run()
        ref_sha = hashlib.sha256(
            b"".join(np.asarray(x).tobytes() for o, a in csr
                     for x in (o, a))).hexdigest()
        for (w, l, s, o) in specs:
            ref[o] = np.asarray(part.walk_corpus(w, l, seed=s,
                                                 out_name=o)).copy()
    gen = PartitionedGenerator(CFG.with_(transport="fs"), str(tmp_path / "f"),
                               max_workers=0)
    gen._fuse_gen_relabel = True
    with gen:
        csr2, _ = gen.run()
        assert hashlib.sha256(
            b"".join(np.asarray(x).tobytes() for o, a in csr2
                     for x in (o, a))).hexdigest() == ref_sha
        for w, (_, _, _, o) in zip(gen.walk_corpus_fused(specs), specs):
            np.testing.assert_array_equal(np.array(w), ref[o])


# ---------------------------------------------------------------------------
# acceptance: 3-job concurrent queue == serial, on 2 hosts
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_three_job_queue_bit_identical_to_serial(tmp_path):
    sched = _scheduler(str(tmp_path / "q"), max_concurrent=3, lease_size=2)
    try:
        jobs = _submit_all(sched)
        summary = sched.drain()
        assert [j["status"] for j in summary["jobs"]] == ["done"] * 3
        assert summary["utilization"] > 0
        queued = {j.tag: _artifacts(sched.root, d, j.tag)
                  for j, d in zip(jobs, JOBS)}
        # concurrent jobs really did overlap on the shared fleet
        log_jobs = {e["job"] for e in sched.controller.task_log}
        assert log_jobs == {j.tag for j in jobs}
    finally:
        sched.close()

    # serial oracle: each job alone on its own fresh 2-host cluster
    for k, d in enumerate(JOBS):
        spec = ClusterSpec.local(2, str(tmp_path / f"s{k}" / "hosts"),
                                 nb=CFG.nb)
        gen = ClusterGenerator(d["cfg"], spec,
                               str(tmp_path / f"s{k}" / "ctrl"),
                               backend=LocalExecBackend(env=_ENV),
                               heartbeat_timeout=20.0)
        try:
            mp, _ = gen.run()
            serial = {"csr": _sha_csr(mp)}
            for (W, L, s, o) in d.get("walks", []):
                w = gen.walk_corpus(W, L, seed=s, out_name=o)
                serial[o] = hashlib.sha256(np.ascontiguousarray(
                    np.array(w)).tobytes()).hexdigest()
        finally:
            gen.close()
        assert queued[f"job{k:04d}"] == serial, f"job{k:04d} diverged"


# ---------------------------------------------------------------------------
# dead-letter parking + GC (poisoned task)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_poisoned_job_dead_letters_fleet_drains_and_gc(tmp_path):
    """A job whose CSR kernel raises deterministically (csr 'scatter' under
    the feistel family) burns its lease budget, lands in the dead-letter
    queue with the task key + attempt count, the OTHER jobs drain to done,
    and the dead job's partial stores are GC'd on every host and the
    controller."""
    sched = _scheduler(str(tmp_path), max_concurrent=3, lease_budget=2)
    try:
        good = _submit_all(sched, JOBS[:2])
        bad = sched.submit(CFG.with_(seed=13), csr_variant="scatter")
        summary = sched.drain()
        by_tag = {j["job"]: j["status"] for j in summary["jobs"]}
        assert by_tag[bad.tag] == "dead"
        assert all(by_tag[j.tag] == "done" for j in good)
        (dl,) = summary["dead_letters"]
        assert dl["job"] == bad.tag
        assert dl["attempts"] == 2                 # the lease budget, spent
        assert "csr_scatter" in dl["task_key"]
        # queue state persisted the park
        state = load_state(sched.root)
        assert state["dead_letters"] == summary["dead_letters"]
        # GC: the poisoned job's namespace subdir is gone on every host
        # (generation completed before the CSR phase poisoned it, so
        # partials HAD been written) and on the controller.
        for h in sched.spec.hosts:
            assert not os.path.exists(os.path.join(h.workdir, bad.tag))
        assert not os.path.exists(os.path.join(sched.root, bad.tag))
        # the survivors' artifacts are intact
        for j, d in zip(good, JOBS[:2]):
            _artifacts(sched.root, d, j.tag)
    finally:
        sched.close()


# ---------------------------------------------------------------------------
# lease re-dispatch after a host kill — no completed task re-runs
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_killed_host_leases_redispatch_without_rerunning_done_work(tmp_path):
    """Host 1 dies hard mid-lease; its inflight tasks requeue to their owner,
    the relaunch resumes from checkpoints, all jobs finish bit-identical —
    and no task key that completed fresh ever executes fresh again."""
    sched = _scheduler(str(tmp_path / "q"), backend=_KillHost1First(),
                       max_concurrent=3, max_restarts=1)
    try:
        jobs = _submit_all(sched)
        summary = sched.drain()
        assert [j["status"] for j in summary["jobs"]] == ["done"] * 3
        assert sched.controller.restarts[1] == 1
        fresh = {}
        for e in sched.controller.task_log:
            if e["ok"] and not e["resumed"]:
                k = (e["job"], e["key"])   # keys repeat across jobs by design
                fresh[k] = fresh.get(k, 0) + 1
        rerun = {k: n for k, n in fresh.items() if n > 1}
        assert not rerun, f"completed tasks re-ran fresh: {rerun}"
        queued = {j.tag: _artifacts(sched.root, d, j.tag)
                  for j, d in zip(jobs, JOBS)}
    finally:
        sched.close()
    # parity against an unkilled queue run of the same jobs
    ref = _scheduler(str(tmp_path / "r"), max_concurrent=3)
    try:
        rjobs = _submit_all(ref)
        ref.drain()
        for j, d in zip(rjobs, JOBS):
            assert _artifacts(ref.root, d, j.tag) == queued[j.tag]
    finally:
        ref.close()


# ---------------------------------------------------------------------------
# idle CPU (the busy-poll bugfix, measured)
# ---------------------------------------------------------------------------


def _proc_cpu_seconds(pid):
    with open(f"/proc/{pid}/stat") as f:
        parts = f.read().rsplit(") ", 1)[1].split()
    # utime + stime, fields 14/15 of /proc/pid/stat (0-indexed 11/12 after
    # the comm field)
    return (int(parts[11]) + int(parts[12])) / os.sysconf("SC_CLK_TCK")


@pytest.mark.slow
@pytest.mark.skipif(not os.path.exists("/proc/self/stat"),
                    reason="needs /proc")
def test_idle_cluster_burns_no_cpu(tmp_path):
    """2 live hosts + controller, zero queued tasks, for 2 wall seconds:
    the condition-variable barriers and long-poll leases must leave the
    whole fleet asleep (the old 20ms busy-polls burned a core per
    waiter)."""
    sched = _scheduler(str(tmp_path), max_concurrent=2)
    try:
        pids = [h.pid for h in sched.controller._handles.values()]
        t0_self = time.process_time()
        t0_hosts = sum(_proc_cpu_seconds(p) for p in pids)
        time.sleep(2.0)
        d_self = time.process_time() - t0_self
        d_hosts = sum(_proc_cpu_seconds(p) for p in pids) - t0_hosts
        assert d_self < 0.4, f"controller burned {d_self:.2f}s CPU while idle"
        assert d_hosts < 0.6, f"hosts burned {d_hosts:.2f}s CPU while idle"
    finally:
        sched.close()


# ---------------------------------------------------------------------------
# CLI: submit -> queue -> drain
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_jobqueue_cli_end_to_end(tmp_path):
    root = str(tmp_path / "cli")
    env = dict(os.environ, **_ENV)

    def cli(*args, timeout=300):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.cluster", *args],
            env=env, capture_output=True, text=True, timeout=timeout)

    s1 = cli("submit", "--workdir", root, "--scale", "8", "--nb", "4",
             "--chunk-edges", "256", "--recompute", "--fuse-gen-relabel",
             "--walks", "8:3:1:a.npy", "--walks", "8:3:2:b.npy",
             "--fuse-walks")
    s2 = cli("submit", "--workdir", root, "--scale", "9", "--nb", "4",
             "--chunk-edges", "256", "--recompute")
    assert s1.returncode == 0 and s2.returncode == 0, s1.stderr + s2.stderr
    q = cli("queue", "--workdir", root)
    assert "queued" in q.stdout and "scale9" in q.stdout
    d = cli("drain", "--workdir", root, "--hosts", "2", "--nb", "4",
            "--max-concurrent", "2")
    assert d.returncode == 0, d.stderr[-2000:]
    summary = json.loads(d.stdout[d.stdout.index("{"):])
    assert [j["status"] for j in summary["jobs"]] == ["done", "done"]
    walks = ShardedWalks(os.path.join(root, "ctrl", "job0000",
                                      "a_manifest.json"))
    assert np.asarray(walks).shape == (8, 4)
