"""External-memory tier: BlockStore primitives, the external shuffle, the
phase orchestrator, and the partitioned multi-process mode."""

import numpy as np
import pytest

from repro.core.blockstore import (
    BlockStore, IOLedger, MemoryGauge, MonotoneLookup, merge_runs, sort_runs)
from repro.core.external import StreamingGenerator, RunStore, external_merge, external_sort_runs
from repro.core.hostgen import rmat_edges_np_cfg
from repro.core.phases import PartitionedGenerator
from repro.core.types import GraphConfig


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_external_merge_empty_store(tmp_path):
    ledger = IOLedger()
    store = RunStore(str(tmp_path), "empty", ledger)
    assert list(external_merge(store)) == []


def test_external_merge_single_and_empty_runs(tmp_path):
    ledger = IOLedger()
    store = RunStore(str(tmp_path), "runs", ledger)
    store.append_run(np.array([3, 1, 2]), np.array([30, 10, 20]))
    store.append_run(np.array([], np.int64), np.array([], np.int64))
    out = RunStore(str(tmp_path), "sorted", ledger)
    external_sort_runs(store, out, key_col=0)
    merged = list(external_merge(out, key_col=0))
    s = np.concatenate([b[0] for b in merged])
    d = np.concatenate([b[1] for b in merged])
    np.testing.assert_array_equal(s, [1, 2, 3])
    np.testing.assert_array_equal(d, [10, 20, 30])  # payload follows its key


def test_external_merge_many_runs_sorted_globally(tmp_path):
    rng = np.random.default_rng(0)
    ledger = IOLedger()
    store = RunStore(str(tmp_path), "runs", ledger)
    everything = []
    for _ in range(7):
        keys = rng.integers(0, 1000, 97)
        store.append_run(keys, keys * 3)
        everything.append(keys)
    out = RunStore(str(tmp_path), "sorted", ledger)
    external_sort_runs(store, out, key_col=0)
    merged_s = np.concatenate([b[0] for b in merge_runs(out, key=0, block_rows=16)])
    np.testing.assert_array_equal(merged_s, np.sort(np.concatenate(everything)))


def test_ioledger_invariants(tmp_path):
    """Counts and bytes stay consistent: every append is one sequential
    write of exactly the run's bytes; every read mirrors a prior write."""
    ledger = IOLedger()
    store = RunStore(str(tmp_path), "io", ledger)
    a = np.arange(100, dtype=np.int64)
    store.append_run(a, a)
    assert ledger.seq_writes == 1 and ledger.rand_writes == 0
    assert ledger.bytes_written == 2 * a.nbytes
    snap = ledger.snapshot()
    store.read_run(0)
    delta = ledger.delta_since(snap)
    assert delta["seq_reads"] == 1 and delta["bytes_read"] == 2 * a.nbytes
    assert delta["seq_writes"] == 0 == delta["bytes_written"]
    ledger.read(64, sequential=False)
    assert ledger.rand_reads == 1
    # totals monotone, equal to the sum of categories
    d = ledger.as_dict()
    assert d["bytes_read"] == 2 * a.nbytes + 64


def test_blockstore_attach_recovers_tag_order(tmp_path):
    ledger = IOLedger()
    store = BlockStore(str(tmp_path), "tagged", ledger, columns=("v",))
    store.append_run(np.array([2]), tag="001_00000")
    store.append_run(np.array([1]), tag="000_00000")
    store.append_run(np.array([3]), tag="001_00001")
    att = BlockStore.attach(str(tmp_path), "tagged", ledger, columns=("v",))
    vals = [int(v[0]) for (v,) in att.iter_runs()]
    assert vals == [1, 2, 3]  # lexicographic tag order == sender order


def test_monotone_lookup(tmp_path):
    ledger = IOLedger()
    table = np.random.default_rng(1).permutation(256)
    store = BlockStore(str(tmp_path), "pv", ledger, columns=("v",))
    for lo in range(0, 256, 32):
        store.append_run(table[lo:lo + 32])
    keys = np.sort(np.random.default_rng(2).integers(0, 256, 500))
    lk = MonotoneLookup([store], block_rows=16)
    got = np.concatenate([lk.lookup(keys[:200]), lk.lookup(keys[200:])])
    np.testing.assert_array_equal(got, table[keys])


def test_rmat_numpy_matches_device():
    import jax.numpy as jnp
    from repro.core.rmat import rmat_edge_block

    cfg = GraphConfig(scale=10)
    s_j, d_j = rmat_edge_block(cfg, jnp.uint32(17), 2048)
    s_n, d_n = rmat_edges_np_cfg(cfg, 17, 2048)
    np.testing.assert_array_equal(np.asarray(s_j, np.int64), s_n)
    np.testing.assert_array_equal(np.asarray(d_j, np.int64), d_n)


# ---------------------------------------------------------------------------
# external shuffle
# ---------------------------------------------------------------------------


def test_external_shuffle_matches_device_shuffle(tmp_path):
    """Paper Alg. 2-4 on disk == the device shuffle, bit for bit (nb=1 here;
    the multi-shard case is tested on the 8-device mesh in
    test_distributed.py)."""
    from repro.core.shuffle import distributed_shuffle
    from repro.distributed.collectives import flat_mesh

    cfg = GraphConfig(scale=9, nb=1, chunk_edges=64, shuffle_variant="external")
    gen = StreamingGenerator(cfg, str(tmp_path))
    pv_ext = np.asarray(gen.export_pv(gen.permutation()))
    pv_dev = np.asarray(distributed_shuffle(cfg, flat_mesh(1)))
    np.testing.assert_array_equal(pv_ext, pv_dev)


def test_external_shuffle_bounded_memory_and_sequential(tmp_path):
    """The acceptance criterion of the refactor: with chunk_edges << n the
    full external run never materializes an O(n) array (pv lives in bucket
    files), and the shuffle phase does sequential I/O only."""
    cfg = GraphConfig(scale=12, nb=16, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    assert cfg.n >= 16 * cfg.chunk_edges
    gen = StreamingGenerator(cfg, str(tmp_path))
    pv, csr, ledger = gen.run()
    # bounded memory: every buffer the disk tier materialized is O(chunk)
    assert gen.gauge.peak_rows <= 4 * cfg.chunk_edges
    assert gen.gauge.peak_rows < cfg.n
    # shuffle phase: sequential only
    shuffle_delta = gen.orchestrator.delta("shuffle")
    assert shuffle_delta["rand_reads"] == 0 == shuffle_delta["rand_writes"]
    # whole sorted pipeline: sequential only
    assert ledger.rand_reads == 0 == ledger.rand_writes
    # pv (read back from disk) is a permutation; the graph is complete
    hits = np.zeros(cfg.n, bool)
    hits[np.asarray(pv)] = True
    assert hits.all()
    assert sum(int(o[-1]) for o, _ in csr) == cfg.m


def test_external_variant_full_graph_matches_device(tmp_path):
    """shuffle_variant="external" end-to-end == the device pipeline at nb=1
    (same pv by the parity test above, same counter-RNG edges)."""
    from repro.core.csr import csr_to_host
    from repro.core.pipeline import generate

    cfg = GraphConfig(scale=9, nb=1, chunk_edges=512, shuffle_variant="external",
                      capacity_factor=4.0)
    pv, csr, _ = StreamingGenerator(cfg, str(tmp_path)).run()
    dev = generate(cfg.with_(shuffle_variant="device"))
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(dev.pv))
    o_dev, a_dev = csr_to_host(dev.csr, cfg)
    offv, adjv = csr[0]
    np.testing.assert_array_equal(np.diff(offv), np.diff(o_dev))
    for r in range(cfg.n):
        np.testing.assert_array_equal(
            np.sort(np.asarray(adjv[offv[r]:offv[r + 1]])),
            np.sort(a_dev[o_dev[r]:o_dev[r + 1]]))


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------


def test_orchestrator_checkpoint_resume(tmp_path):
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=512, edge_factor=4,
                      shuffle_variant="external", checkpoint_phases=True)
    g1 = StreamingGenerator(cfg, str(tmp_path))
    pv1, csr1, _ = g1.run()
    pv1 = np.asarray(pv1).copy()
    g2 = StreamingGenerator(cfg, str(tmp_path))
    pv2, csr2, _ = g2.run()
    statuses = {r["phase"]: r["status"] for r in g2.orchestrator.report()}
    for phase in ("shuffle", "generate", "relabel", "redistribute"):
        assert statuses[phase] == "resumed", statuses
    # resumed phases cost zero I/O
    assert g2.orchestrator.delta("shuffle")["bytes_read"] == 0
    np.testing.assert_array_equal(pv1, np.asarray(pv2))
    for (o1, _), (o2, _) in zip(csr1, csr2):
        np.testing.assert_array_equal(o1, o2)


def test_orchestrator_checkpoint_invalidated_on_config_change(tmp_path):
    """Resuming another config's checkpoint would be silent corruption (same
    workdir, new seed/scale) — the config key must invalidate it wholesale
    and the rerun over the dirty workdir must still be correct."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external", checkpoint_phases=True)
    StreamingGenerator(cfg, str(tmp_path)).run()
    g = StreamingGenerator(cfg.with_(seed=999), str(tmp_path))
    pv, csr, _ = g.run()
    assert all(r["status"] == "done" for r in g.orchestrator.report())
    hits = np.zeros(cfg.n, bool)
    hits[np.asarray(pv)] = True
    assert hits.all()
    assert sum(int(o[-1]) for o, _ in csr) == cfg.m


def test_invalid_nb_raises_cleanly(tmp_path):
    with pytest.raises(ValueError, match="must divide n"):
        StreamingGenerator(GraphConfig(scale=8, nb=3, shuffle_variant="external"),
                           str(tmp_path))
    with pytest.raises(ValueError, match="exchange slices"):
        StreamingGenerator(GraphConfig(scale=4, nb=8, shuffle_variant="external"),
                           str(tmp_path))


def test_orchestrator_per_phase_deltas_sum_to_total(tmp_path):
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=512, edge_factor=4,
                      shuffle_variant="external")
    gen = StreamingGenerator(cfg, str(tmp_path))
    _, _, ledger = gen.run()
    report = gen.orchestrator.report()
    for field in ("seq_reads", "seq_writes", "bytes_read", "bytes_written"):
        assert sum(r[field] for r in report) == getattr(ledger, field)


# ---------------------------------------------------------------------------
# partitioned multi-process mode
# ---------------------------------------------------------------------------


def _row_multisets_equal(csr_a, csr_b):
    for (o1, a1), (o2, a2) in zip(csr_a, csr_b):
        np.testing.assert_array_equal(o1, o2)
        for r in range(len(o1) - 1):
            np.testing.assert_array_equal(
                np.sort(np.asarray(a1[o1[r]:o1[r + 1]])),
                np.sort(np.asarray(a2[o2[r]:o2[r + 1]])))


def test_partitioned_equals_streaming(tmp_path):
    """The bucket kernels produce the identical graph whether one process
    runs all buckets (StreamingGenerator) or the partitioned driver does
    (in-process mode here; spawn mode in the smoke test below)."""
    cfg = GraphConfig(scale=10, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    pv_s, csr_s, _ = StreamingGenerator(cfg, str(tmp_path / "seq")).run()
    part = PartitionedGenerator(cfg, str(tmp_path / "par"), max_workers=0)
    csr_p, _ = part.run()
    pv_p = np.concatenate([
        np.concatenate([v for (v,) in b.iter_runs()]) for b in part.pv_buckets()])
    np.testing.assert_array_equal(np.asarray(pv_s), pv_p)
    _row_multisets_equal(csr_s, csr_p)


@pytest.mark.slow
def test_partitioned_true_multiprocess_smoke(tmp_path):
    """Real worker processes (spawn pool) over the shared filesystem."""
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    with PartitionedGenerator(cfg, str(tmp_path), max_workers=2) as part:
        csr, ledger = part.run()
    assert sum(int(o[-1]) for o, _ in csr) == cfg.m
    assert ledger.rand_reads == 0 == ledger.rand_writes
