"""Training subsystem: optimizer math, schedules, grad accumulation,
gradient compression, end-to-end loss descent."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_smoke_config
from repro.models.registry import input_specs
from repro.train import OptimConfig, init_state, make_train_step
from repro.train import optim as optim_lib
from repro.train.compression import (
    CompressionConfig, compress_state_init, compressed_grads, dequantize_int8,
    quantize_int8, topk_mask)

SMALL = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)


# ---------------------------------------------------------------------------
# optimizer unit tests
# ---------------------------------------------------------------------------


def test_adamw_matches_reference_impl():
    """Our AdamW vs a hand-rolled numpy reference on a small tensor."""
    ocfg = OptimConfig(lr=1e-2, warmup_steps=0, weight_decay=0.1,
                       clip_norm=0.0, master_fp32=True, schedule="constant")
    p0 = np.asarray([[1.0, -2.0], [0.5, 3.0]], np.float32)
    g = np.asarray([[0.1, 0.2], [-0.3, 0.4]], np.float32)
    params = {"w": jnp.asarray(p0)}
    state = optim_lib.init(ocfg, params)
    new_params, state, _ = optim_lib.apply_updates(
        ocfg, params, {"w": jnp.asarray(g)}, state)
    # reference
    m = 0.1 * g
    v = 0.05 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    upd = mhat / (np.sqrt(vhat) + ocfg.eps) + 0.1 * p0
    ref = p0 - 1e-2 * upd
    np.testing.assert_allclose(np.asarray(new_params["w"]), ref, rtol=1e-5)


def test_no_decay_on_norm_scale_params():
    ocfg = OptimConfig(lr=1e-2, warmup_steps=0, weight_decay=1.0,
                       clip_norm=0.0, schedule="constant")
    params = {"ln": {"scale": jnp.ones((4,))}, "w": jnp.ones((4,))}
    state = optim_lib.init(ocfg, params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = optim_lib.apply_updates(ocfg, params, zero_g, state)
    np.testing.assert_allclose(np.asarray(new_params["ln"]["scale"]), 1.0)
    assert np.all(np.asarray(new_params["w"]) < 1.0)  # decayed


def test_schedule_warmup_cosine():
    ocfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(optim_lib.schedule(ocfg, jnp.asarray(0))) == 0.0
    assert abs(float(optim_lib.schedule(ocfg, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(optim_lib.schedule(ocfg, jnp.asarray(110)))
    assert abs(end - 0.1) < 1e-6
    mid = float(optim_lib.schedule(ocfg, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


def test_global_norm_clipping():
    ocfg = OptimConfig(lr=1.0, warmup_steps=0, clip_norm=1.0,
                       weight_decay=0.0, schedule="constant")
    params = {"w": jnp.zeros((3,))}
    state = optim_lib.init(ocfg, params)
    big = {"w": jnp.asarray([300.0, 400.0, 0.0])}   # norm 500
    _, state2, metrics = optim_lib.apply_updates(ocfg, params, big, state)
    assert abs(float(metrics["grad_norm"]) - 500.0) < 1e-3
    # clipped first moment = 0.1 * g/500
    np.testing.assert_allclose(
        np.asarray(state2.mu["w"]), [0.06, 0.08, 0.0], atol=1e-6)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_quantization_roundtrip_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-7


def test_topk_mask_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2])
    m = np.asarray(topk_mask(g, 0.4))  # keep 2
    assert m.tolist() == [False, True, False, True, False]


def test_error_feedback_preserves_signal():
    """With EF, the *sum* of decoded grads tracks the sum of true grads —
    compression error cannot accumulate as bias."""
    cfg = CompressionConfig(kind="int8", ef=True)
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((64,))}
    ef = compress_state_init(cfg, params)
    total_true = np.zeros(64)
    total_dec = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)}
        dec, ef = compressed_grads(cfg, g, ef)
        total_true += np.asarray(g["w"])
        total_dec += np.asarray(dec["w"])
    resid = np.abs(total_true - total_dec).max()
    assert resid < 0.01 * 0.5 / 127 * 2 + 1e-4  # bounded by one quantum


# ---------------------------------------------------------------------------
# train step integration
# ---------------------------------------------------------------------------


def _loss_curve(arch="internlm2-1.8b", accum=1, compression=None, steps=6):
    cfg = get_smoke_config(arch)
    ocfg = OptimConfig(lr=3e-3, warmup_steps=2, total_steps=100)
    state, _ = init_state(cfg, ocfg, compression=compression)
    batch = input_specs(cfg, SMALL, mode="init")
    fn = jax.jit(make_train_step(cfg, ocfg, None, accum_steps=accum,
                                 compression=compression))
    losses = []
    for _ in range(steps):
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_dense():
    losses = _loss_curve()
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_loss_decreases_moe():
    losses = _loss_curve("qwen3-moe-235b-a22b")
    assert losses[-1] < losses[0]


def test_loss_decreases_ssm():
    losses = _loss_curve("mamba2-780m")
    assert losses[-1] < losses[0]


def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same batch (mean-of-means)."""
    l1 = _loss_curve(accum=1, steps=3)
    l2 = _loss_curve(accum=2, steps=3)
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


def test_compressed_training_converges():
    base = _loss_curve(steps=6)
    comp = _loss_curve(steps=6,
                       compression=CompressionConfig(kind="int8", ef=True))
    assert comp[-1] < comp[0]
    assert abs(comp[-1] - base[-1]) < 0.25 * abs(base[0] - base[-1]) + 0.05


def test_labels_ignore_index_masks():
    from repro.train.step import softmax_xent
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, -100, -100]])
    loss, ntok = softmax_xent(logits, labels)
    assert int(ntok) == 2
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_bf16_moments_still_converge():
    """bf16 Adam moments (HBM-fit lever in §Perf) must not break descent."""
    ocfg = OptimConfig(lr=5e-2, warmup_steps=0, weight_decay=0.0,
                      clip_norm=0.0, schedule="constant",
                      moments_dtype="bfloat16")
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = optim_lib.init(ocfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(60):
        g = {"w": params["w"]}            # grad of 0.5*||w||^2
        params, state, _ = optim_lib.apply_updates(ocfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5
