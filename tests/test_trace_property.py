"""Hypothesis property tests for the trace subsystem's timeline laws:
generated well-nested span trees always validate clean, injected
violations (negative durations, child overflowing its parent) are always
caught, merge_traces is a pure function of file CONTENTS (deterministic
under any partitioning of events into files and any file naming), the
Perfetto export preserves event counts and never emits negative rebased
timestamps, and MetricsRegistry.combined is order-insensitive.

Module-level importorskip, same policy as tests/test_cluster_property.py:
the non-hypothesis twins of the critical cases live in tests/test_trace.py
so tier-1 keeps coverage even without hypothesis installed.
"""

import json

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.trace import (  # noqa: E402
    MetricsRegistry,
    merge_traces,
    to_perfetto,
    validate_timeline,
)

_SETTINGS = dict(max_examples=60, deadline=None)


def _span(name, cat, ts, dur, lane=(None, 1, 1)):
    host, pid, tid = lane
    rec = {"name": name, "cat": cat, "ph": "X", "ts": float(ts),
           "dur": float(dur), "pid": pid, "tid": tid}
    if host is not None:
        rec["host"] = host
    return rec


@st.composite
def nested_timelines(draw):
    """A well-formed lane: top-level phase spans laid end to end, each
    holding strictly nested kernel children (recursively), plus leaf-cat
    events sprinkled anywhere (exempt from the nesting law)."""
    events = []

    def children(t0, t1, depth, prefix):
        n = draw(st.integers(0, 3 if depth else 0))
        edges = sorted(draw(st.lists(
            st.floats(0.0, 1.0, allow_nan=False), min_size=2 * n,
            max_size=2 * n)))
        for i in range(n):
            lo = t0 + (t1 - t0) * edges[2 * i]
            hi = t0 + (t1 - t0) * edges[2 * i + 1]
            if hi <= lo:
                continue
            events.append(_span(f"{prefix}k{i}", "kernel", lo, hi - lo))
            children(lo, hi, depth - 1, f"{prefix}k{i}.")

    t = 0.0
    for p in range(draw(st.integers(0, 4))):
        dur = draw(st.floats(0.5, 10.0, allow_nan=False))
        events.append(_span(f"phase{p}", "phase", t, dur))
        children(t, t + dur, depth=2, prefix=f"p{p}.")
        t += dur + draw(st.floats(0.0, 1.0, allow_nan=False))
    for i in range(draw(st.integers(0, 4))):
        events.append(_span(f"io{i}", "io",
                            draw(st.floats(0.0, t + 1.0, allow_nan=False)),
                            draw(st.floats(0.0, 20.0, allow_nan=False))))
    return events


@given(events=nested_timelines())
@settings(**_SETTINGS)
def test_well_nested_timelines_validate_clean(events):
    assert validate_timeline(events) == []


@given(events=nested_timelines(), ix=st.integers(0, 2**32),
       neg=st.floats(-100.0, -0.001, allow_nan=False))
@settings(**_SETTINGS)
def test_injected_negative_duration_always_caught(events, ix, neg):
    events = list(events) + [_span("extra", "io", 0.0, 1.0)]
    events[ix % len(events)]["dur"] = neg
    problems = validate_timeline(events)
    assert any("negative duration" in p for p in problems)


@given(events=nested_timelines(), overflow=st.floats(0.1, 50.0,
                                                     allow_nan=False))
@settings(**_SETTINGS)
def test_child_overflowing_parent_always_caught(events, overflow):
    phases = [e for e in events if e["cat"] == "phase"]
    if not phases:
        return
    p = phases[0]
    bad = _span("bad_kernel", "kernel", p["ts"] + p["dur"] / 2,
                p["dur"] / 2 + overflow)
    problems = validate_timeline(events + [bad])
    assert any("overflows its parent" in p_ for p_ in problems)


@given(events=nested_timelines(), cuts=st.lists(st.integers(0, 2**32),
                                                max_size=3),
       seed=st.randoms(use_true_random=False))
@settings(**_SETTINGS)
def test_merge_is_invariant_under_file_partitioning(tmp_path_factory,
                                                    events, cuts, seed):
    """However the same events are split across per-process files — and
    whatever those files are named — the merged timeline is identical."""
    shuffled = list(events)
    seed.shuffle(shuffled)
    bounds = sorted({c % (len(events) + 1) for c in cuts})
    parts, prev = [], 0
    for b in bounds + [len(events)]:
        parts.append(shuffled[prev:b])
        prev = b
    d1 = tmp_path_factory.mktemp("one")
    d2 = tmp_path_factory.mktemp("parts")
    with open(d1 / "trace_1.jsonl", "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")
    for i, part in enumerate(parts):
        with open(d2 / f"trace_{i + 100}.jsonl", "w") as f:
            for e in part:
                f.write(json.dumps(e) + "\n")
    merged_one = merge_traces([str(d1)])
    merged_parts = merge_traces([str(d2)])
    assert merged_parts == merged_one
    # and the merge is genuinely sorted by ts
    ts = [e["ts"] for e in merged_one]
    assert ts == sorted(ts)


@given(events=nested_timelines())
@settings(**_SETTINGS)
def test_perfetto_export_preserves_events_and_rebases(events):
    doc = to_perfetto(events)
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert len(evs) == len(events)
    assert all(e["ts"] >= 0 for e in evs)
    assert all(e["dur"] >= 0 for e in evs if e["ph"] == "X")
    if evs:
        assert min(e["ts"] for e in evs) == 0


@given(snaps=st.lists(
    st.tuples(st.sampled_from(["a", "b", "c", "d"]),
              st.fixed_dictionaries({
                  "schema": st.just(1),
                  "io": st.dictionaries(
                      st.sampled_from(["bytes_read", "bytes_written"]),
                      st.integers(0, 1 << 40)),
                  "memory": st.fixed_dictionaries(
                      {"peak_rows": st.integers(0, 1 << 20),
                       "budget_rows": st.integers(0, 1 << 20)}),
              })),
    max_size=8))
@settings(**_SETTINGS)
def test_registry_combined_is_order_insensitive(snaps):
    fwd, rev = MetricsRegistry(), MetricsRegistry()
    for name, snap in snaps:
        fwd.update(name, snap)
    for name, snap in reversed(snaps):
        rev.update(name, snap)
    if [n for n, _ in snaps] == [n for n, _ in dict(snaps).items()]:
        # no duplicate names: order can't matter at all
        assert fwd.combined() == rev.combined()
    assert fwd.combined()["schema"] == 1
