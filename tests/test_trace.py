"""Unit tests for core/trace.py — the run-wide tracing + metrics subsystem.

Covers the tracer lifecycle (null default, install/idempotence/uninstall,
bounded-buffer drops), the merge/validate/export pipeline (torn lines,
negative durations, the nesting law, Perfetto structure), the unified
telemetry schema (unified_snapshot, MetricsRegistry, run_metadata), the
checkpoint-key contract (trace is normalized out of result_config_key),
phase spans across kill+resume (no duplicates for checkpointed phases),
and the CI kernel-coverage lint.  The hypothesis twins live in
tests/test_trace_property.py.
"""

import dataclasses
import json
import os

import pytest

from repro.core.blockstore import IOLedger
from repro.core.phases import PhaseOrchestrator, PlainCfg, result_config_key
from repro.core import trace as trace_mod
from repro.core.trace import (
    GLOBAL,
    MetricsRegistry,
    Tracer,
    get_tracer,
    install_tracer,
    lint_kernel_coverage,
    maybe_install_tracer,
    merge_traces,
    phase_durations,
    run_metadata,
    to_perfetto,
    trace_files,
    uninstall_tracer,
    unified_snapshot,
    validate_timeline,
    write_perfetto,
)


@pytest.fixture(autouse=True)
def _reset_tracer():
    """The tracer is process-global state; every test starts and ends with
    the NullTracer installed (and the global registry empty)."""
    uninstall_tracer()
    GLOBAL.clear()
    yield
    uninstall_tracer()
    GLOBAL.clear()


def _read_jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Tracer lifecycle
# ---------------------------------------------------------------------------


def test_default_tracer_is_null_and_free(tmp_path):
    tr = get_tracer()
    assert tr.enabled is False
    tr.event("x", "phase", 0.0, 1.0)
    tr.instant("y")
    with tr.span("z"):
        pass
    tr.flush()
    assert list(tmp_path.iterdir()) == []   # nothing ever touches disk


def test_maybe_install_disabled_is_noop(tmp_path):
    tr = maybe_install_tracer(str(tmp_path), enabled=False)
    assert tr.enabled is False
    assert not (tmp_path / "trace").exists()


def test_tracer_writes_labeled_spans(tmp_path):
    tr = install_tracer(str(tmp_path), host=1, job="job0001")
    assert get_tracer() is tr and tr.enabled
    tr.event("generate", "kernel", 100.0, 2.5, args={"bucket": 3})
    tr.instant("recv:edges", cat="wire", bytes=64)
    with tr.span("send:edges", cat="wire", bytes=128):
        pass
    uninstall_tracer()   # close() flushes
    recs = _read_jsonl(tmp_path / "trace" / f"trace_{os.getpid()}.jsonl")
    assert len(recs) == 3
    by_name = {r["name"]: r for r in recs}
    ev = by_name["generate"]
    assert ev["ph"] == "X" and ev["cat"] == "kernel"
    assert ev["ts"] == 100.0 and ev["dur"] == 2.5
    assert ev["args"] == {"bucket": 3}
    assert ev["host"] == 1 and ev["job"] == "job0001"
    assert ev["pid"] == os.getpid() and "tid" in ev
    assert by_name["recv:edges"]["ph"] == "i"
    assert by_name["send:edges"]["dur"] >= 0.0
    assert by_name["send:edges"]["args"] == {"bytes": 128}


def test_install_is_idempotent_first_wins(tmp_path):
    a = install_tracer(str(tmp_path / "a"))
    b = install_tracer(str(tmp_path / "b"))
    assert a is b
    assert b.path.startswith(str(tmp_path / "a"))
    assert not (tmp_path / "b").exists()


def test_bounded_buffer_drops_instead_of_blocking(tmp_path):
    tr = Tracer(str(tmp_path), max_buffer=4, flush_interval=3600.0)
    for i in range(10):
        tr.event(f"e{i}", "kernel", float(i), 0.1)
    assert tr.dropped == 6
    tr.close()
    recs = _read_jsonl(tr.path)
    # 4 kept events + the final trace_dropped meta instant
    assert len(recs) == 5
    assert recs[-1]["name"] == "trace_dropped"
    assert recs[-1]["args"]["dropped"] == 6


# ---------------------------------------------------------------------------
# Merge + validation + export
# ---------------------------------------------------------------------------


def _span(name, cat, ts, dur, **kw):
    return {"name": name, "cat": cat, "ph": "X", "ts": ts, "dur": dur,
            "pid": 1, "tid": 1, **kw}


def test_merge_traces_skips_torn_lines_and_sorts(tmp_path):
    a = tmp_path / "trace_1.jsonl"
    b = tmp_path / "trace_2.jsonl"
    a.write_text(json.dumps(_span("late", "phase", 5.0, 1.0)) + "\n"
                 + '{"name": "torn", "ts": 1')          # killed mid-flush
    b.write_text("not json at all\n"
                 + json.dumps(_span("early", "phase", 1.0, 1.0)) + "\n"
                 + json.dumps({"no_ts": True}) + "\n")
    events = merge_traces([str(tmp_path)])
    assert [e["name"] for e in events] == ["early", "late"]
    # dir scan and explicit file list agree
    assert merge_traces([str(a), str(b)]) == events
    assert trace_files([str(tmp_path)]) == sorted([str(a), str(b)])


def test_merge_parent_precedes_child_at_equal_ts():
    # sort key (ts, -dur, name): the longer span comes first
    events = sorted(
        [_span("child", "kernel", 1.0, 1.0), _span("parent", "phase", 1.0, 5.0)],
        key=lambda r: (r["ts"], -r["dur"], r["name"]))
    assert [e["name"] for e in events] == ["parent", "child"]


def test_validate_timeline_flags_negative_duration():
    problems = validate_timeline([_span("bad", "io", 1.0, -0.5)])
    assert len(problems) == 1 and "negative duration" in problems[0]


def test_validate_timeline_nesting_law():
    ok = [_span("phase_a", "phase", 0.0, 10.0),
          _span("k1", "kernel", 1.0, 2.0),
          _span("k2", "kernel", 4.0, 5.0)]
    assert validate_timeline(ok) == []
    bad = [_span("phase_a", "phase", 0.0, 10.0),
           _span("k_overflow", "kernel", 8.0, 5.0)]   # ends at 13 > 10
    problems = validate_timeline(bad)
    assert len(problems) == 1 and "overflows its parent" in problems[0]
    # leaf categories are exempt: interleaved io spans legally overlap
    assert validate_timeline([_span("merge:a", "io", 0.0, 10.0),
                              _span("sort:b", "io", 8.0, 5.0)]) == []
    # distinct lanes never nest against each other
    other_lane = _span("k_other", "kernel", 8.0, 5.0, host=2)
    assert validate_timeline([ok[0], other_lane]) == []


def test_to_perfetto_structure_and_rebasing():
    events = [_span("p", "phase", 100.0, 1.5, host=0),
              _span("k", "kernel", 100.5, 0.25, host=1, job="job0001"),
              {"name": "i", "cat": "wire", "ph": "i", "ts": 101.0,
               "pid": 2, "tid": 9, "host": 1}]
    doc = to_perfetto(events)
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    insts = [e for e in evs if e["ph"] == "i"]
    # one process_name metadata row per (host, pid) lane
    assert {m["args"]["name"] for m in metas} == \
        {"host 0 / pid 1", "host 1 / pid 1", "host 1 / pid 2"}
    assert len(spans) == 2 and len(insts) == 1
    by = {e["name"]: e for e in spans}
    assert by["p"]["ts"] == 0 and by["p"]["dur"] == 1_500_000     # µs, rebased
    assert by["k"]["ts"] == 500_000 and by["k"]["dur"] == 250_000
    assert by["k"]["args"]["job"] == "job0001"
    assert by["p"]["pid"] != by["k"]["pid"]
    assert to_perfetto([]) == {"traceEvents": [], "displayTimeUnit": "ms"}


def test_write_perfetto_round_trips(tmp_path):
    path = write_perfetto([_span("p", "phase", 0.0, 1.0)],
                          str(tmp_path / "out.json"))
    with open(path) as f:
        doc = json.load(f)
    assert any(e["name"] == "p" for e in doc["traceEvents"])


def test_phase_durations_sums_phase_cat_only():
    events = [_span("generate", "phase", 0.0, 2.0),
              _span("generate", "phase", 5.0, 3.0),
              _span("generate", "kernel", 0.5, 1.0),     # not a phase span
              _span("csr", "phase", 10.0, 4.0)]
    assert phase_durations(events) == {"generate": 5.0, "csr": 4.0}


# ---------------------------------------------------------------------------
# Unified telemetry schema
# ---------------------------------------------------------------------------


def test_unified_snapshot_sections_and_duck_typing():
    led = IOLedger()
    led.write(1024)
    led.stall(read_wait_s=0.5, overlap_s=0.1)
    snap = unified_snapshot(ledger=led)
    assert snap["schema"] == 1
    assert snap["io"]["bytes_written"] == 1024
    assert "read_wait_s" not in snap["io"]        # stalls are split out
    assert snap["stalls"] == {"read_wait_s": 0.5, "write_wait_s": 0.0,
                              "overlap_s": 0.1}
    assert "wire" not in snap and "memory" not in snap   # omitted, not null
    # a ledger that crossed the wire as a dict snapshots identically
    assert unified_snapshot(ledger=led.as_dict()) == snap


def test_metrics_registry_combined_sums_and_maxes():
    reg = MetricsRegistry()
    reg.update("a", {"schema": 1, "io": {"bytes_read": 10},
                     "memory": {"peak_rows": 5, "budget_rows": 100}})
    reg.update("b", {"schema": 1, "io": {"bytes_read": 7, "seq_reads": 2},
                     "memory": {"peak_rows": 9, "budget_rows": 100}})
    reg.update("b", {"schema": 1, "io": {"bytes_read": 8, "seq_reads": 2},
                     "memory": {"peak_rows": 9, "budget_rows": 100}})
    combined = reg.combined()
    assert combined["sources"] == ["a", "b"]
    assert combined["io"] == {"bytes_read": 18, "seq_reads": 2}  # latest-wins
    assert combined["memory"] == {"peak_rows": 9, "budget_rows": 100}
    reg.clear()
    assert reg.combined() == {"schema": 1}


def test_run_metadata_values_are_all_strings():
    meta = run_metadata(config_digest="abc123")
    for key in ("schema", "hostname", "timestamp", "python", "git_sha"):
        assert isinstance(meta[key], str) and meta[key]
    assert meta["config_digest"] == "abc123"


# ---------------------------------------------------------------------------
# Checkpoint-key contract + kernel-coverage lint
# ---------------------------------------------------------------------------


def _pcfg(**kw):
    base = dict(scale=8, edge_factor=2, seed=1, a=0.57, b=0.19, c=0.19,
                d=0.05, nb=2, chunk_edges=256, rounds=2)
    base.update(kw)
    return PlainCfg(**base)


def test_result_config_key_erases_trace():
    pcfg = _pcfg()
    assert result_config_key(dataclasses.replace(pcfg, trace=True)) == \
        result_config_key(dataclasses.replace(pcfg, trace=False))


def test_lint_kernel_coverage_is_clean():
    assert lint_kernel_coverage() == []


def test_lint_catches_unwrapped_kernel(monkeypatch):
    from repro.core import phases

    def naked(pcfg, workdir, *a, **kw):   # pragma: no cover - never called
        pass

    monkeypatch.setitem(phases._KERNELS, "generate", naked)
    problems = lint_kernel_coverage()
    assert any("generate" in p and "not wrapped" in p for p in problems)


# ---------------------------------------------------------------------------
# Phase spans across kill + resume
# ---------------------------------------------------------------------------


def test_resume_emits_no_duplicate_phase_spans(tmp_path):
    """Run 1 completes p1, p2 with checkpoints; run 2 (same workdir, as
    after a kill) resumes both and runs p3.  The merged timeline must hold
    exactly ONE phase span per completed phase — resumed phases did no
    work, so they contribute no span."""
    workdir = str(tmp_path)
    save = lambda r: {"v": r}
    load = lambda d: d["v"]

    install_tracer(workdir)
    orch = PhaseOrchestrator(workdir, IOLedger(), checkpoint=True,
                             config_key="k")
    orch.run_phase("p1", lambda: 1, save=save, load=load)
    orch.run_phase("p2", lambda: 2, save=save, load=load)
    uninstall_tracer()                     # the "kill": flush + reset

    install_tracer(workdir)                # the resumed process
    orch2 = PhaseOrchestrator(workdir, IOLedger(), checkpoint=True,
                              config_key="k")
    assert orch2.run_phase("p1", lambda: 99, save=save, load=load) == 1
    assert orch2.run_phase("p2", lambda: 99, save=save, load=load) == 2
    orch2.run_phase("p3", lambda: 3, save=save, load=load)
    statuses = {r["phase"]: r["status"] for r in orch2.report()}
    assert statuses == {"p1": "resumed", "p2": "resumed", "p3": "done"}
    uninstall_tracer()

    events = merge_traces([os.path.join(workdir, "trace")])
    names = [e["name"] for e in events if e.get("cat") == "phase"]
    assert sorted(names) == ["p1", "p2", "p3"]      # one span each, ever
    assert validate_timeline(events) == []
    # the GLOBAL registry picked up the orchestrator's unified snapshot
    assert "orchestrator" in GLOBAL.names()
    assert GLOBAL.combined()["schema"] == 1


def test_run_phase_emits_nothing_when_untraced(tmp_path):
    orch = PhaseOrchestrator(str(tmp_path), IOLedger())
    orch.run_phase("p1", lambda: 1)
    assert not (tmp_path / "trace").exists()
    assert [r["status"] for r in orch.report()] == ["done"]


def test_trace_cli_lint_entry():
    assert trace_mod.main(["lint"]) == 0
    assert trace_mod.main([]) == 2
