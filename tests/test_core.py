"""Graph-generation core: single-device pipeline invariants + the
out-of-core (external memory) path vs the device path."""

import numpy as np
import pytest

from repro.core import validate as V
from repro.core.csr import csr_to_host
from repro.core.external import StreamingGenerator
from repro.core.pipeline import generate, generate_baseline_hash
from repro.core.types import GraphConfig

CFG = GraphConfig(scale=10, nb=1, capacity_factor=4.0)


@pytest.fixture(scope="module")
def result():
    return generate(CFG)


def test_permutation_is_bijection(result):
    assert V.check_permutation(result.pv)


def test_no_drops(result):
    assert int(result.dropped_redistribute) == 0
    assert int(result.dropped_relabel) == 0


def test_relabel_multiset(result):
    from repro.core.rmat import rmat_edge_block
    import jax.numpy as jnp

    src, dst = rmat_edge_block(CFG, jnp.uint32(0), CFG.m)
    assert V.check_relabel(src, dst, result.src, result.dst, result.pv)


def test_ownership(result):
    assert V.check_ownership(result.owned.src, result.owned.valid, CFG)


def test_csr_invariants(result):
    checks = V.check_csr(result.csr, result.owned, CFG)
    assert all(checks.values()), checks


def test_debiasing(result):
    """The point of the shuffle (paper §I): raw R-MAT endpoints concentrate
    on small ids; relabeled endpoints are near-uniform."""
    from repro.core.rmat import rmat_edge_block
    import jax.numpy as jnp

    src_raw, dst_raw = rmat_edge_block(CFG, jnp.uint32(0), CFG.m)
    raw = V.endpoint_skew(src_raw, dst_raw, CFG.n)
    rel = V.endpoint_skew(result.src, result.dst, CFG.n)
    assert raw > 0.3            # heavily biased to the low 1/16 of ids
    assert abs(rel - 1 / 16) < 0.02


def test_degree_distribution_heavy_tail(result):
    stats = V.degree_stats(result.csr, CFG)
    assert stats["max_degree"] > 10 * stats["mean_degree"]


def test_variants_agree():
    """sorted-merge CSR (paper §III-B7) == scatter CSR (Alg. 10/11) output."""
    r_sorted = generate(CFG.with_(csr_variant="sorted"))
    r_scatter = generate(CFG.with_(csr_variant="scatter"))
    o1, a1 = csr_to_host(r_sorted.csr, CFG)
    o2, a2 = csr_to_host(r_scatter.csr, CFG)
    np.testing.assert_array_equal(o1, o2)
    # adjacency rows may be permuted within a row; compare per-row multisets
    for r in range(CFG.n):
        np.testing.assert_array_equal(
            np.sort(a1[o1[r]:o1[r + 1]]), np.sort(a2[o2[r]:o2[r + 1]]))


def test_relabel_variants_agree():
    r_ring = generate(CFG.with_(relabel_variant="ring"))
    r_a2a = generate(CFG.with_(relabel_variant="alltoall"))
    np.testing.assert_array_equal(
        V.edge_multiset(r_ring.src, r_ring.dst),
        V.edge_multiset(r_a2a.src, r_a2a.dst))


def test_baseline_hash_kernel():
    """The memory-resident Graph500 baseline produces a valid CSR with the
    same edge count and de-biased endpoints."""
    offv, adjv = generate_baseline_hash(CFG)
    offv = np.asarray(offv)
    assert offv[-1] == CFG.m
    assert (np.diff(offv) >= 0).all()


def test_external_memory_path_matches_device(tmp_path):
    """The literal out-of-core generator (memmap runs, bounded memory) must
    produce the exact same graph as the device pipeline: same counter RNG,
    same (nb=1) shuffle => same permutation => identical degree vectors."""
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=1 << 10, capacity_factor=4.0)
    pv, csr, ledger = StreamingGenerator(cfg, str(tmp_path)).run()
    dev = generate(cfg.with_(nb=1))

    np.testing.assert_array_equal(np.asarray(pv), np.asarray(dev.pv))
    deg_ext = np.concatenate([np.diff(np.asarray(o)) for o, _ in csr])
    o_dev, a_dev = csr_to_host(dev.csr, cfg.with_(nb=1))
    np.testing.assert_array_equal(deg_ext, np.diff(o_dev))
    # per-row adjacency multisets agree
    a_ext = np.concatenate([a for _, a in csr])
    off = np.concatenate([[0], np.cumsum(deg_ext)])
    for r in range(cfg.n):
        np.testing.assert_array_equal(
            np.sort(a_ext[off[r]:off[r + 1]]),
            np.sort(a_dev[o_dev[r]:o_dev[r + 1]]))
    # and the I/O ledger must show the sorted path doing NO random I/O
    assert ledger.rand_reads == 0
    assert ledger.rand_writes == 0


def test_external_csr_scatter_does_random_io(tmp_path):
    """Alg. 10/11 (scatter CSR) hits random I/O — the measured reason the
    paper's Fig. 2 CSR curve blows up; §III-B7 (sorted) avoids it."""
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=1 << 10, capacity_factor=4.0)
    _, _, ledger = StreamingGenerator(cfg, str(tmp_path)).run(csr_variant="scatter")
    assert ledger.rand_writes > 0
