"""Hypothesis property tests for the cluster config-shape invariants:
ClusterSpec / peer_addrs parsing round-trips, bucket-ownership partition
laws, and result_config_key normalizing cluster/transport fields out of
checkpoint keys (resume across cluster shapes must hit the same key).

Module-level importorskip, same policy as tests/test_property.py: the
non-hypothesis twins of the critical cases live in tests/test_cluster.py so
tier-1 keeps coverage even without hypothesis installed.
"""

import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import (  # noqa: E402
    ClusterSpec,
    HostSpec,
    format_peer_addrs,
    parse_peer_addrs,
)
from repro.core.phases import PlainCfg, result_config_key  # noqa: E402

_SETTINGS = dict(max_examples=80, deadline=None)

_hostname = st.from_regex(r"[a-z][a-z0-9\-\.]{0,15}", fullmatch=True)


@st.composite
def cluster_specs(draw):
    num_hosts = draw(st.integers(1, 8))
    nb = draw(st.integers(num_hosts, 64))
    hosts = tuple(
        HostSpec(h, f"/data/w{h}", draw(_hostname))
        for h in range(num_hosts))
    return ClusterSpec(nb=nb, hosts=hosts,
                       controller_host=draw(_hostname),
                       controller_port=draw(st.integers(0, 65535)))


@given(spec=cluster_specs())
@settings(**_SETTINGS)
def test_cluster_spec_json_round_trip(spec):
    assert ClusterSpec.from_json(spec.to_json()) == spec


@given(spec=cluster_specs())
@settings(**_SETTINGS)
def test_bucket_ownership_is_a_contiguous_partition(spec):
    """Every bucket owned exactly once, ranges contiguous and in host order
    (the paper's RP applied to hosts), owner_of inverts buckets_of."""
    seen = []
    for h in range(spec.num_hosts):
        r = spec.buckets_of(h)
        assert r.step == 1
        seen.extend(r)
    assert seen == list(range(spec.nb))
    for b in range(spec.nb):
        assert b in spec.buckets_of(spec.owner_of(b))


@given(addrs=st.lists(
    st.tuples(_hostname, st.integers(0, 65535)).map(
        lambda t: f"{t[0]}:{t[1]}"),
    min_size=1, max_size=16).map(tuple))
@settings(**_SETTINGS)
def test_peer_addrs_round_trip(addrs):
    assert parse_peer_addrs(format_peer_addrs(addrs)) == addrs


@st.composite
def plain_cfgs(draw):
    scale = draw(st.integers(6, 16))
    nb = draw(st.sampled_from([1, 2, 4]))
    return PlainCfg(
        scale=scale, edge_factor=draw(st.integers(1, 8)),
        seed=draw(st.integers(0, 2**31 - 1)),
        a=0.57, b=0.19, c=0.19, d=0.05,
        nb=nb, chunk_edges=draw(st.sampled_from([128, 256, 1 << 14])),
        rounds=draw(st.integers(1, 4)),
        merge_fanin=draw(st.sampled_from([0, 2, 64])),
    )


@given(pcfg=plain_cfgs(),
       peers=st.none() | st.lists(
           st.tuples(_hostname, st.integers(0, 65535)).map(
               lambda t: f"{t[0]}:{t[1]}"),
           min_size=1, max_size=4).map(tuple),
       transport=st.sampled_from(["fs", "socket"]))
@settings(**_SETTINGS)
def test_result_config_key_erases_transport_and_peers(pcfg, peers, transport):
    """The checkpoint key is invariant under everything that only moves
    bytes differently — transport choice, peer addresses (any cluster
    shape/ports) — and keyed on everything that changes the bytes or the
    phase schedule."""
    varied = dataclasses.replace(pcfg, transport=transport, peer_addrs=peers)
    assert result_config_key(varied) == result_config_key(pcfg)
    # ... but not under result-affecting fields:
    assert result_config_key(dataclasses.replace(pcfg, seed=pcfg.seed ^ 1)) \
        != result_config_key(pcfg)
    # pooled_cascade changes the phase schedule -> deliberately kept in key
    assert result_config_key(
        dataclasses.replace(pcfg, pooled_cascade=True)) \
        != result_config_key(dataclasses.replace(pcfg, pooled_cascade=False))
