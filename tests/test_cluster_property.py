"""Hypothesis property tests for the cluster config-shape invariants:
ClusterSpec / peer_addrs parsing round-trips, bucket-ownership partition
laws, result_config_key normalizing cluster/transport fields out of
checkpoint keys (resume across cluster shapes must hit the same key), and
the shard-map laws (core/shardmap.py): partition preserved under arbitrary
assign/admit histories, strict version bumps, JSON round-trips,
stale-frame fencing, and plan_rebalance determinism/conservation.

Module-level importorskip, same policy as tests/test_property.py: the
non-hypothesis twins of the critical cases live in tests/test_cluster.py so
tier-1 keeps coverage even without hypothesis installed.
"""

import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import (  # noqa: E402
    ClusterSpec,
    HostSpec,
    format_peer_addrs,
    parse_peer_addrs,
)
from repro.core.phases import PlainCfg, result_config_key  # noqa: E402
from repro.core.shardmap import (  # noqa: E402
    ShardMap,
    apply_moves,
    frame_version_ok,
    plan_rebalance,
)

_SETTINGS = dict(max_examples=80, deadline=None)

_hostname = st.from_regex(r"[a-z][a-z0-9\-\.]{0,15}", fullmatch=True)


@st.composite
def cluster_specs(draw):
    num_hosts = draw(st.integers(1, 8))
    nb = draw(st.integers(num_hosts, 64))
    hosts = tuple(
        HostSpec(h, f"/data/w{h}", draw(_hostname))
        for h in range(num_hosts))
    return ClusterSpec(nb=nb, hosts=hosts,
                       controller_host=draw(_hostname),
                       controller_port=draw(st.integers(0, 65535)))


@given(spec=cluster_specs())
@settings(**_SETTINGS)
def test_cluster_spec_json_round_trip(spec):
    assert ClusterSpec.from_json(spec.to_json()) == spec


@given(spec=cluster_specs())
@settings(**_SETTINGS)
def test_bucket_ownership_is_a_contiguous_partition(spec):
    """Every bucket owned exactly once, ranges contiguous and in host order
    (the paper's RP applied to hosts), owner_of inverts buckets_of."""
    seen = []
    for h in range(spec.num_hosts):
        r = spec.buckets_of(h)
        assert r.step == 1
        seen.extend(r)
    assert seen == list(range(spec.nb))
    for b in range(spec.nb):
        assert b in spec.buckets_of(spec.owner_of(b))


@given(addrs=st.lists(
    st.tuples(_hostname, st.integers(0, 65535)).map(
        lambda t: f"{t[0]}:{t[1]}"),
    min_size=1, max_size=16).map(tuple))
@settings(**_SETTINGS)
def test_peer_addrs_round_trip(addrs):
    assert parse_peer_addrs(format_peer_addrs(addrs)) == addrs


@st.composite
def plain_cfgs(draw):
    scale = draw(st.integers(6, 16))
    nb = draw(st.sampled_from([1, 2, 4]))
    return PlainCfg(
        scale=scale, edge_factor=draw(st.integers(1, 8)),
        seed=draw(st.integers(0, 2**31 - 1)),
        a=0.57, b=0.19, c=0.19, d=0.05,
        nb=nb, chunk_edges=draw(st.sampled_from([128, 256, 1 << 14])),
        rounds=draw(st.integers(1, 4)),
        merge_fanin=draw(st.sampled_from([0, 2, 64])),
    )


@given(pcfg=plain_cfgs(),
       peers=st.none() | st.lists(
           st.tuples(_hostname, st.integers(0, 65535)).map(
               lambda t: f"{t[0]}:{t[1]}"),
           min_size=1, max_size=4).map(tuple),
       transport=st.sampled_from(["fs", "socket"]))
@settings(**_SETTINGS)
def test_result_config_key_erases_transport_and_peers(pcfg, peers, transport):
    """The checkpoint key is invariant under everything that only moves
    bytes differently — transport choice, peer addresses (any cluster
    shape/ports) — and keyed on everything that changes the bytes or the
    phase schedule."""
    varied = dataclasses.replace(pcfg, transport=transport, peer_addrs=peers)
    assert result_config_key(varied) == result_config_key(pcfg)
    # ... but not under result-affecting fields:
    assert result_config_key(dataclasses.replace(pcfg, seed=pcfg.seed ^ 1)) \
        != result_config_key(pcfg)
    # pooled_cascade changes the phase schedule -> deliberately kept in key
    assert result_config_key(
        dataclasses.replace(pcfg, pooled_cascade=True)) \
        != result_config_key(dataclasses.replace(pcfg, pooled_cascade=False))
    # ... and the live shard-map version is pure routing state: a resumed
    # run must hit the same checkpoint keys after any number of rebalances
    assert result_config_key(
        dataclasses.replace(pcfg, shard_map_version=7)) \
        == result_config_key(pcfg)


# ---------------------------------------------------------------------------
# ShardMap laws (core/shardmap.py)
# ---------------------------------------------------------------------------


def _apply_history(nb, num_hosts, ops):
    """Replay a drawn (admit | assign) op list as VALID mutations, mapping
    raw drawn ints onto the map's current shape; returns the map plus the
    count of applied mutations and of applied assigns."""
    smap = ShardMap.contiguous(nb, num_hosts)
    mutations = assigns = 0
    for op in ops:
        if op[0] == "admit":
            hid = smap.admit_host()
            assert hid == smap.num_hosts - 1
            mutations += 1
        else:
            if smap.num_hosts < 2:
                continue   # every assign would be a rejected no-op
            b = op[1] % smap.nb
            h = op[2] % smap.num_hosts
            if h == smap.owner_of(b):
                h = (h + 1) % smap.num_hosts
            smap.assign(b, h)
            mutations += 1
            assigns += 1
    return smap, mutations, assigns


_ops = st.lists(
    st.one_of(
        st.just(("admit",)),
        st.tuples(st.just("assign"), st.integers(0, 2**32),
                  st.integers(0, 2**32))),
    max_size=24)


@given(num_hosts=st.integers(1, 8), nb=st.integers(0, 64))
@settings(**_SETTINGS)
def test_contiguous_map_reproduces_static_split(num_hosts, nb):
    nb += num_hosts   # nb >= num_hosts
    smap = ShardMap.contiguous(nb, num_hosts)
    spec = ClusterSpec(nb=nb, hosts=tuple(
        HostSpec(h, f"/data/w{h}") for h in range(num_hosts)))
    assert smap.version == 0 and smap.gens == [0] * nb
    for b in range(nb):
        assert smap.owner_of(b) == spec.owner_of(b)
    for h in range(num_hosts):
        assert smap.buckets_of(h) == list(spec.buckets_of(h))


@given(num_hosts=st.integers(1, 6), nb=st.integers(0, 26), ops=_ops)
@settings(**_SETTINGS)
def test_mutation_history_preserves_partition_and_bumps_version(
        num_hosts, nb, ops):
    nb += num_hosts
    smap, mutations, assigns = _apply_history(nb, num_hosts, ops)
    smap.validate()   # partition invariant after ANY valid history
    # every mutation bumps the version exactly once; every assign bumps
    # exactly one bucket's gen exactly once
    assert smap.version == mutations
    assert sum(smap.gens) == assigns
    # buckets_of inverts owner_of and partitions range(nb)
    seen = [b for h in range(smap.num_hosts) for b in smap.buckets_of(h)]
    assert sorted(seen) == list(range(nb))
    # JSON round-trip is exact
    assert ShardMap.from_json(smap.to_json()) == smap


@given(frame=st.none() | st.integers(0, 2**31), minv=st.integers(0, 2**31))
@settings(**_SETTINGS)
def test_frame_version_fencing_laws(frame, minv):
    # unversioned senders always pass (compat); versioned frames pass
    # iff at-or-past the ratchet, so passing is monotone in the frame
    # version and anti-monotone in the ratchet
    ok = frame_version_ok(frame, minv)
    if frame is None:
        assert ok
    else:
        assert ok == (frame >= minv)
        if ok:
            assert frame_version_ok(frame + 1, minv)
        if minv:
            assert frame_version_ok(frame, minv - 1) or not ok


@st.composite
def rebalance_cases(draw):
    num_hosts = draw(st.integers(1, 6))
    nb = num_hosts + draw(st.integers(0, 12))
    smap, _, _ = _apply_history(nb, num_hosts, draw(_ops))
    loads = dict(enumerate(draw(st.lists(st.integers(0, 1 << 30),
                                         min_size=nb, max_size=nb))))
    return smap, loads, draw(st.integers(0, 4))


@given(case=rebalance_cases())
@settings(**_SETTINGS)
def test_plan_rebalance_laws(case):
    smap, loads, max_moves = case
    moves = plan_rebalance(smap, loads, max_moves=max_moves)
    # pure function of (map, loads): replanning from the same snapshot
    # (e.g. a resumed rebalance) yields the identical plan
    assert plan_rebalance(smap, loads, max_moves=max_moves) == moves
    # each bucket moves at most once per plan (one barrier dispatch)
    assert len({b for b, _, _ in moves}) == len(moves)
    if max_moves:
        assert len(moves) <= max_moves
    if smap.num_hosts < 2:
        assert moves == []

    def host_loads(owners):
        hl = [0] * smap.num_hosts
        for b, v in loads.items():
            hl[owners[b]] += v
        return hl

    before = host_loads(smap.owners)
    vbefore = smap.version
    # the plan applies cleanly (src fields match live owners, in order)
    apply_moves(smap, moves)
    smap.validate()
    assert smap.version == vbefore + len(moves)
    after = host_loads(smap.owners)
    # conservation: rebalancing moves bytes, never creates or drops them
    assert sum(after) == sum(before)
    # a non-empty plan strictly improves balance (sum of squared host
    # loads — the potential function that proves the planner terminates)
    if moves:
        assert sum(v * v for v in after) < sum(v * v for v in before)
