"""The jax version floor and the compat shims must agree.

distributed/compat.py carries three fallbacks that exist ONLY because the
container pins jax at the floor (0.4.37) while the public names
(`jax.shard_map`, `lax.axis_size`, `lax.pvary`) graduated in 0.4.38.
These tests pin that story to reality: the floor constant matches the
shims' rationale, the installed jax satisfies the floor, and — when the
installed jax IS the floor — every fallback branch is live (none of the
shims is dead code).  If the container's jax ever moves past the floor,
test_all_shims_live_at_the_floor starts vacuously passing and
test_floor_tracks_installed_jax fails loudly instead: the signal to bump
JAX_VERSION_FLOOR and delete the then-dead fallbacks (ROADMAP item).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed import compat

# (module, public name) pairs whose post-floor graduation is each shim's
# reason to exist — one entry per shim in compat.py, kept in sync by eye.
POST_FLOOR_NAMES = [(jax, "shard_map"), (lax, "axis_size"), (lax, "pvary")]


def _vtuple(s: str):
    return tuple(int(p) for p in s.split(".")[:3])


def test_floor_constant_matches_shim_story():
    assert compat.JAX_VERSION_FLOOR == (0, 4, 37)
    assert len(POST_FLOOR_NAMES) == 3   # three shims, three reasons


def test_floor_tracks_installed_jax():
    v = _vtuple(jax.__version__)
    assert v >= compat.JAX_VERSION_FLOOR, (
        f"installed jax {jax.__version__} is below the documented floor")
    # The floor exists to mark where the fallbacks stop being needed.  If
    # the container's jax has every public name, the floor is stale and
    # the fallbacks are dead branches — bump JAX_VERSION_FLOOR and delete
    # them (see compat.py's module doc + ROADMAP "jax version floor").
    if all(hasattr(m, n) for m, n in POST_FLOOR_NAMES):
        assert v == compat.JAX_VERSION_FLOOR, (
            f"jax {jax.__version__} has shard_map/axis_size/pvary natively;"
            " the compat fallbacks are dead — raise the floor and prune")


def test_all_shims_live_at_the_floor():
    if _vtuple(jax.__version__) != compat.JAX_VERSION_FLOOR:
        pytest.skip("only meaningful on a floor-pinned container")
    # At the floor NONE of the public names exist yet, so every fallback
    # branch in compat.py is the live one — no shim is dead weight.
    for mod, name in POST_FLOOR_NAMES:
        assert not hasattr(mod, name), (
            f"{mod.__name__}.{name} exists at the floor; the compat shim "
            "for it is dead code")
    from jax.experimental.shard_map import shard_map as experimental
    assert compat.shard_map is experimental


def test_shims_execute_inside_shard_map():
    # Whichever branch is live, the three names must compose: axis_size
    # constant-folds to the mesh axis length, pvary is (at worst) identity.
    mesh = Mesh(np.array(jax.devices()[:1]), ("i",))

    def body(x):
        return compat.pvary(x, ("i",)) + compat.axis_size("i")

    y = compat.shard_map(body, mesh=mesh, in_specs=P("i"),
                         out_specs=P("i"))(jnp.zeros(4, jnp.int32))
    np.testing.assert_array_equal(np.asarray(y), np.ones(4, np.int32))
