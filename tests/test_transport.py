"""Pluggable exchange transport: socket/filesystem parity, framing guards,
partial-frame sweeping, mid-exchange kill + resume, and checkpoint GC.

The SocketTransport must be a drop-in for the `{sender}_{seq}` filesystem
convention: bit-identical stores (and therefore bit-identical graphs and
walk corpora — which the fs backend already proves against the device
oracle), the same O(chunk) memory bound, and the same crash-replay story.
"""

import hashlib
import os

import numpy as np
import pytest

from repro.core.blockstore import BlockStore, IOLedger, MemoryGauge
from repro.core.external import StreamingGenerator
from repro.core.phases import (
    _KERNELS, PartitionedGenerator, relabel_inbox_name)
from repro.core.transport import (
    ExchangeServer, FilesystemTransport, SocketTransport, TransportError,
    make_transport, sweep_partial_frames)
from repro.core.types import GraphConfig
from repro.data.walks import concat_bucket_csr, host_walks, start_vertex


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------


def test_socket_roundtrip_matches_filesystem(tmp_path):
    """The same appends through both backends produce byte-identical run
    files, recovered in the same (sender-lexicographic) order."""
    d_fs, d_sk = str(tmp_path / "fs"), str(tmp_path / "sk")
    os.makedirs(d_fs), os.makedirs(d_sk)
    rng = np.random.default_rng(0)
    runs = [(rng.integers(0, 99, 37), rng.integers(0, 99, 37)),
            (rng.integers(0, 99, 5), rng.integers(0, 99, 5))]
    ledger = IOLedger()
    fs = FilesystemTransport(d_fs, ledger)
    with ExchangeServer(d_sk) as srv:
        sk = SocketTransport(d_sk, ledger, peers=(srv.addr,))
        for tr, _d in ((fs, d_fs), (sk, d_sk)):
            ch = tr.channel(0, "inbox")
            for k, (a, b) in enumerate(runs):
                ch.append_run(a, b, tag=f"007_{k:05d}")
            tr.flush()
        got_fs = list(fs.drain_inbox("inbox").iter_runs())
        got_sk = list(sk.drain_inbox("inbox").iter_runs())
        sk.close()
    assert len(got_fs) == len(got_sk) == len(runs)
    for (a1, b1), (a2, b2) in zip(got_fs, got_sk):
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)
    # identical bytes on disk, not merely equal arrays
    for f in sorted(os.listdir(os.path.join(d_fs, "inbox"))):
        with open(os.path.join(d_fs, "inbox", f), "rb") as fa, \
             open(os.path.join(d_sk, "inbox", f), "rb") as fb:
            assert fa.read() == fb.read(), f
    assert srv.stats.frames_recv == len(runs)


def test_socket_refuses_reordered_seq(tmp_path):
    """Per-connection sequence numbers are a corruption guard: a gap means a
    lost/reordered frame and the server must refuse, not silently accept."""
    with ExchangeServer(str(tmp_path)) as srv:
        tr = SocketTransport(str(tmp_path), IOLedger(), peers=(srv.addr,))
        ch = tr.channel(0, "inbox")
        ch.append_run(np.arange(3), np.arange(3), tag="000_00000")
        tr._conns[srv.addr][1] = 7   # simulate dropped frames 1..6
        with pytest.raises(TransportError, match="seq"):
            ch.append_run(np.arange(3), np.arange(3), tag="000_00001")
        tr.close()


def test_socket_refuses_truncated_frame(tmp_path):
    with ExchangeServer(str(tmp_path)) as srv:
        tr = SocketTransport(str(tmp_path), IOLedger(), peers=(srv.addr,))
        with pytest.raises(TransportError, match="truncated|payload"):
            tr._rpc(srv.addr, 0, {"store": "inbox", "tag": "000_00000",
                                  "dtype": "<i8", "rows": 10, "ncols": 2},
                    b"\x00" * 24)
        tr.close()


def test_clean_inboxes_sweeps_stale_runs_and_partial_frames(tmp_path):
    """The pre-senders sweep must clear complete stale runs AND `.part`
    partial frames, identically through both backends."""
    ledger = IOLedger()
    for sub, mk in (("fs", lambda d: FilesystemTransport(d, ledger)),
                    ("sk", None)):
        d = str(tmp_path / sub)
        inbox = os.path.join(d, "inbox")
        os.makedirs(inbox)
        store = BlockStore(d, "inbox", ledger)
        store.append_run(np.arange(4), np.arange(4), tag="001_00000")
        with open(os.path.join(inbox, "run_001_00001.npy.part"), "wb") as f:
            f.write(b"torn frame")
        if mk is not None:
            tr = mk(d)
            tr.clean_inboxes(["inbox"])
        else:
            with ExchangeServer(d) as srv:
                tr = SocketTransport(d, ledger, peers=(srv.addr,))
                tr.clean_inboxes(["inbox"])
                tr.close()
        assert not os.path.exists(inbox)


def test_sweep_partial_frames_only_touches_part_files(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "store"))
    real = os.path.join(d, "store", "run_000_00000.npy")
    stray = os.path.join(d, "store", "run_000_00001.npy.part")
    top_stray = os.path.join(d, "x.part")
    for p in (real, stray, top_stray):
        with open(p, "wb") as f:
            f.write(b"x")
    sweep_partial_frames(d)
    assert os.path.exists(real)
    assert not os.path.exists(stray) and not os.path.exists(top_stray)


def test_make_transport_socket_requires_peers(tmp_path):
    cfg = GraphConfig(scale=8, transport="socket")
    with pytest.raises(ValueError, match="peer_addrs"):
        make_transport(cfg, str(tmp_path), IOLedger())
    with pytest.raises(ValueError, match="transport"):
        make_transport(GraphConfig(scale=8).with_(transport="carrier-pigeon"),
                       str(tmp_path), IOLedger())


def test_streaming_generator_rejects_socket(tmp_path):
    with pytest.raises(ValueError, match="PartitionedGenerator"):
        StreamingGenerator(GraphConfig(scale=8, transport="socket"),
                           str(tmp_path))


def test_filesystem_alias_canonicalized(tmp_path):
    """transport="filesystem" is the long-form alias for "fs" — accepted
    everywhere "fs" is, including the single-process driver."""
    from repro.core.phases import plain_config
    assert plain_config(GraphConfig(scale=8, transport="filesystem")).transport == "fs"
    gen = StreamingGenerator(GraphConfig(scale=8, transport="filesystem",
                                         shuffle_variant="external",
                                         chunk_edges=128, edge_factor=2),
                             str(tmp_path))
    pv, csr, _ = gen.run()
    assert sum(int(o[-1]) for o, _ in csr) == 2 * 256


# ---------------------------------------------------------------------------
# end-to-end parity: generator + walk corpus, fs vs socket vs oracle
# ---------------------------------------------------------------------------


def _full_run(cfg, workdir, W, L, wseed, **gen_kw):
    """generate + relabel + redistribute + CSR + walk corpus; returns
    (pv, csr sha256, walks array, generator)."""
    part = PartitionedGenerator(cfg, workdir, max_workers=0, **gen_kw)
    csr, _ = part.run()
    walks = np.asarray(part.walk_corpus(W, L, seed=wseed)).copy()
    pv = np.concatenate([
        np.concatenate([v for (v,) in b.iter_runs()] or [np.zeros(0, np.int64)])
        for b in part.pv_buckets()])
    h = hashlib.sha256()
    for o, a in csr:
        h.update(np.asarray(o).tobytes())
        h.update(np.asarray(a).tobytes())
    return pv, h.hexdigest(), walks, csr, part


@pytest.mark.parametrize("nb", [1, 4, 8])
def test_socket_full_pipeline_bit_identical_to_fs(tmp_path, nb):
    """Acceptance criterion: with transport="socket" over loopback the full
    pipeline (and the walk corpus riding the same transport) is bit-identical
    to the filesystem transport at nb in {1, 4, 8} — and both match the host
    walk oracle on the assembled CSR."""
    W, L, wseed = 33, 6, 3
    cfg = GraphConfig(scale=9, nb=nb, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    pv_f, csr_f, walks_f, csr, pf = _full_run(
        cfg, str(tmp_path / "fs"), W, L, wseed)
    pv_s, csr_s, walks_s, _, ps = _full_run(
        cfg.with_(transport="socket"), str(tmp_path / "sk"), W, L, wseed,
        exchange_servers=2)
    try:
        np.testing.assert_array_equal(pv_f, pv_s)
        assert csr_f == csr_s
        np.testing.assert_array_equal(walks_f, walks_s)
        # socket mode actually moved frames, and both backends account the
        # same exchanged bytes (sender side), every one of which the socket
        # server received
        assert ps.exchange_stats.frames_recv > 0
        assert pf.exchange_stats.bytes_sent == ps.exchange_stats.bytes_sent > 0
        assert ps.exchange_stats.bytes_recv == ps.exchange_stats.bytes_sent
        # both equal the host oracle on the same CSR layout
        offv, adjv = concat_bucket_csr(csr)
        wid = np.arange(W, dtype=np.uint32)
        ref = host_walks(offv, adjv, start_vertex(wseed, wid, cfg.n), L,
                         wseed, n=cfg.n, walker_ids=wid)
        np.testing.assert_array_equal(walks_s, ref)
    finally:
        pf.close()
        ps.close()


def test_socket_bounded_memory_and_sequential(tmp_path):
    """The O(chunk) gauge bound must hold over the wire: no exchange path —
    sender framing, receiver buffering, or inbox drain — materializes a full
    bucket, and disk I/O stays purely sequential."""
    chunk, nb, W, L = 256, 16, 64, 6
    cfg = GraphConfig(scale=12, nb=nb, chunk_edges=chunk, edge_factor=2,
                      shuffle_variant="external", transport="socket")
    with PartitionedGenerator(cfg, str(tmp_path), max_workers=0,
                              exchange_servers=2) as part:
        part.run()
        part.walk_corpus(W, L, seed=0)
        wpb = -(-W // nb)
        assert part.gauge.peak_rows <= 4 * (chunk + wpb)
        assert part.gauge.peak_rows < cfg.n
        assert part.ledger.rand_reads == 0 == part.ledger.rand_writes
        for srv in part._servers:
            assert srv.gauge.peak_rows <= chunk


@pytest.mark.slow
def test_socket_true_multiprocess_smoke(tmp_path):
    """Real spawned workers rendezvousing with the parent's loopback
    ExchangeServers — the multi-host deployment shape on one machine."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external", transport="socket")
    with PartitionedGenerator(cfg, str(tmp_path), max_workers=2,
                              exchange_servers=2) as part:
        csr, ledger = part.run()
        walks = np.asarray(part.walk_corpus(20, 5, seed=1)).copy()
    assert sum(int(o[-1]) for o, _ in csr) == cfg.m
    assert walks.shape == (20, 6)
    assert ledger.rand_reads == 0 == ledger.rand_writes


# ---------------------------------------------------------------------------
# mid-exchange kill + resume
# ---------------------------------------------------------------------------


def test_socket_mid_exchange_kill_resume_bit_identical(tmp_path):
    """Kill a worker mid-exchange (some frames already delivered to the
    receiver, the rest never sent), leave a forged partial frame behind, and
    resume: the crashed phase replays from the senders' checkpointed input
    stores onto pre-cleaned inboxes, and every output byte matches an
    uninterrupted filesystem-transport run."""
    cfg_fs = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                         shuffle_variant="external")
    cfg_sk = cfg_fs.with_(transport="socket")
    W, L, wseed = 23, 5, 9
    pv_f, csr_f, walks_f, _, pf = _full_run(cfg_fs, str(tmp_path / "ref"),
                                            W, L, wseed)
    pf.close()

    d = str(tmp_path / "crash")
    orig = _KERNELS["relabel_scatter"]

    def crashing_scatter(pcfg, workdir, i, pass_ix, *, ledger, gauge=None,
                         transport=None):
        if pass_ix == 1 and i == 2:
            # deliver a partial exchange, then die: frames for dest 0 land,
            # nothing else does
            tr = make_transport(pcfg, workdir, ledger, gauge)
            ch = tr.channel(0, relabel_inbox_name(1, 0))
            ch.append_run(np.array([7], np.int64), np.array([8], np.int64),
                          tag="002_00000")
            tr.close()
            raise RuntimeError("injected mid-exchange kill")
        return orig(pcfg, workdir, i, pass_ix, ledger=ledger, gauge=gauge,
                    transport=transport)

    _KERNELS["relabel_scatter"] = crashing_scatter
    try:
        with PartitionedGenerator(cfg_sk, d, max_workers=0, checkpoint=True,
                                  exchange_servers=2) as part:
            with pytest.raises(RuntimeError, match="injected"):
                part.run()
    finally:
        _KERNELS["relabel_scatter"] = orig

    # forge the stray a killed receiver would leave mid-frame
    inbox = os.path.join(d, relabel_inbox_name(1, 1))
    os.makedirs(inbox, exist_ok=True)
    with open(os.path.join(inbox, "run_003_00000.npy.part"), "wb") as f:
        f.write(b"torn")

    with PartitionedGenerator(cfg_sk, d, max_workers=0, checkpoint=True,
                              exchange_servers=2) as part:
        csr, _ = part.run()
        statuses = {r["phase"]: r["status"]
                    for r in part.orchestrator.report()}
        assert statuses["shuffle"] == "resumed", statuses
        assert statuses["generate"] == "resumed", statuses
        assert statuses["relabel"] == "done", statuses
        walks = np.asarray(part.walk_corpus(W, L, seed=wseed)).copy()
        pv = np.concatenate([
            np.concatenate([v for (v,) in b.iter_runs()])
            for b in part.pv_buckets()])
        h = hashlib.sha256()
        for o, a in csr:
            h.update(np.asarray(o).tobytes())
            h.update(np.asarray(a).tobytes())
    assert not os.path.exists(os.path.join(inbox, "run_003_00000.npy.part"))
    np.testing.assert_array_equal(pv, pv_f)
    assert h.hexdigest() == csr_f
    np.testing.assert_array_equal(walks, walks_f)


def test_partitioned_checkpoint_resume_all_phases(tmp_path):
    """A completed checkpointed partitioned run resumes every phase without
    recomputation, across transports (result keys normalize the transport
    out, so a crashed fs run may resume under socket and vice versa)."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    d = str(tmp_path)
    with PartitionedGenerator(cfg, d, max_workers=0, checkpoint=True) as p1:
        csr1, _ = p1.run()
        off1 = [np.asarray(o).copy() for o, _ in csr1]
    with PartitionedGenerator(cfg.with_(transport="socket"), d, max_workers=0,
                              checkpoint=True) as p2:
        csr2, _ = p2.run()
        assert all(r["status"] == "resumed"
                   for r in p2.orchestrator.report()), p2.orchestrator.report()
        for o1, (o2, _) in zip(off1, csr2):
            np.testing.assert_array_equal(o1, np.asarray(o2))


# ---------------------------------------------------------------------------
# checkpoint GC
# ---------------------------------------------------------------------------


def test_checkpoint_gc_drops_consumed_stores(tmp_path):
    """Once every downstream consumer is checkpointed, intermediate stores
    are gone — only final artifacts (CSR files, pv.npy) remain — and the
    resumed run is still byte-identical."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external", checkpoint_phases=True)
    d = str(tmp_path)
    g1 = StreamingGenerator(cfg, d)
    pv1, csr1, _ = g1.run()
    pv1 = np.asarray(pv1).copy()
    for name in (["edges", "relabeled_p1"]
                 + [f"owned_{i:03d}" for i in range(cfg.nb)]
                 + [f"pv_r{g1._pcfg.rounds}_b{i:03d}" for i in range(cfg.nb)]):
        assert not os.path.exists(os.path.join(d, name)), name
    assert os.path.exists(os.path.join(d, "pv.npy"))
    g2 = StreamingGenerator(cfg, d)
    pv2, csr2, _ = g2.run()
    statuses = {r["phase"]: r["status"] for r in g2.orchestrator.report()}
    assert all(s == "resumed" for s in statuses.values()), statuses
    np.testing.assert_array_equal(pv1, np.asarray(pv2))
    for (o1, a1), (o2, a2) in zip(csr1, csr2):
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_checkpoint_gc_scatter_after_sorted_fails_with_guidance(tmp_path):
    """A checkpointed 'sorted' run frees the redistribute outputs; a later
    'scatter' run over the same workdir must fail with a clear message (not
    a FileNotFoundError inside np.load) pointing at keep_phase_stores."""
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external", checkpoint_phases=True)
    d = str(tmp_path)
    StreamingGenerator(cfg, d).run(csr_variant="sorted")
    with pytest.raises(ValueError, match="keep_phase_stores"):
        StreamingGenerator(cfg, d).run(csr_variant="scatter")


def test_checkpoint_gc_keep_all_escape_hatch(tmp_path):
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external", checkpoint_phases=True,
                      keep_phase_stores=True)
    d = str(tmp_path)
    StreamingGenerator(cfg, d).run()
    for name in ("edges", "relabeled_p1", "owned_000", "owned_001"):
        assert os.path.isdir(os.path.join(d, name)), name


def test_checkpoint_gc_partitioned_keeps_pv_buckets(tmp_path):
    """The partitioned driver's pv buckets ARE its permutation output —
    GC must drop its consumed edge stores but never the pv buckets."""
    from repro.core.phases import edges_store_name, owned_store_name, pv_store_name
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    d = str(tmp_path)
    with PartitionedGenerator(cfg, d, max_workers=0) as part:
        part.run()
        rounds = part.pcfg.rounds
        for i in range(cfg.nb):
            for name in (edges_store_name(i), edges_store_name(i, 0),
                         edges_store_name(i, 1), owned_store_name(i)):
                assert not os.path.exists(os.path.join(d, name)), name
            assert os.path.isdir(os.path.join(d, pv_store_name(rounds, i)))
        assert part.pv_buckets()[0].total_rows() == cfg.n // cfg.nb
