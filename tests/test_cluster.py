"""Multi-host cluster runtime: 2-host loopback parity, host-kill resume,
auto-restart, pooled cascade merges, and the partitioned scatter CSR.

The acceptance contract: a 2-host run via the local-exec backend (disjoint
workdirs, socket transport) produces a graph and walk corpus bit-identical
to the single-host PartitionedGenerator, with no single workdir ever holding
the full corpus; killing one host mid-phase and relaunching resumes from
that host's checkpoints only.
"""

import hashlib
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core.cluster import (
    ClusterError,
    ClusterGenerator,
    ClusterSpec,
    HostSpec,
    LocalExecBackend,
    format_peer_addrs,
    parse_peer_addrs,
)
from repro.core.corpus import ShardedWalks, shard_name
from repro.core.phases import (
    PartitionedGenerator,
    plain_config,
    result_config_key,
)
from repro.core.types import GraphConfig

_SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
_ENV = {"PYTHONPATH": _SRC}

CFG = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                  shuffle_variant="external")
W, L, WSEED = 17, 5, 3


def _csr_sha(csr):
    h = hashlib.sha256()
    for o, a in csr:
        h.update(np.asarray(o).tobytes())
        h.update(np.asarray(a).tobytes())
    return h.hexdigest()


@pytest.fixture(scope="module")
def single_host_ref(tmp_path_factory):
    """The single-host oracle every cluster scenario compares against."""
    d = str(tmp_path_factory.mktemp("ref"))
    with PartitionedGenerator(CFG, d, max_workers=0) as part:
        csr, _ = part.run()
        walks = np.asarray(part.walk_corpus(W, L, seed=WSEED)).copy()
        sha = _csr_sha(csr)
    return {"workdir": d, "csr_sha": sha, "walks": walks}


def _cluster(tmp_path, name, backend=None, **kw):
    spec = ClusterSpec.local(2, str(tmp_path / name), nb=CFG.nb)
    gen = ClusterGenerator(
        CFG.with_(transport="socket"), spec, str(tmp_path / name / "ctrl"),
        backend=backend if backend is not None else LocalExecBackend(env=_ENV),
        checkpoint=True, **kw)
    return spec, gen


class _KillHost1First(LocalExecBackend):
    """Crash injection: host 1's FIRST launch dies hard (os._exit) after
    executing a handful of tasks — mid-phase, like kill -9."""

    def __init__(self, max_tasks=6):
        super().__init__(env=_ENV)
        self.max_tasks = max_tasks

    def host_args(self, host, attempt):
        if host.host_id == 1 and attempt == 0:
            return ["--max-tasks", str(self.max_tasks)]
        return []


# ---------------------------------------------------------------------------
# acceptance: 2-host parity + shard placement
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_two_host_cluster_bit_identical_to_single_host(tmp_path,
                                                       single_host_ref):
    spec, gen = _cluster(tmp_path, "cl")
    try:
        manifest_path, ledger = gen.run()
        walks = gen.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks),
                                      single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
        # graph manifest names each bucket's owner host + files
        m = json.load(open(manifest_path))
        assert [b["host"] for b in m["buckets"]] == [0, 0, 1, 1]
        for b in m["buckets"]:
            assert os.path.exists(os.path.join(b["workdir"], b["offv"]))
        # sharded collect: every shard lives on its OWNER host's workdir and
        # nowhere else — in particular the controller's workdir holds no
        # corpus bytes, only manifests + checkpoint state.
        for j in range(CFG.nb):
            owner_dir = spec.hosts[spec.owner_of(j)].workdir
            other_dir = spec.hosts[1 - spec.owner_of(j)].workdir
            assert os.path.exists(os.path.join(owner_dir,
                                               shard_name("walks.npy", j)))
            assert not os.path.exists(os.path.join(other_dir,
                                                   shard_name("walks.npy", j)))
            assert not os.path.exists(os.path.join(gen.workdir,
                                                   shard_name("walks.npy", j)))
        # the corpus manifest reaches across the host workdirs
        again = ShardedWalks(walks.manifest_path)
        np.testing.assert_array_equal(np.asarray(again),
                                      single_host_ref["walks"])
        # exchange actually crossed the sockets
        assert gen.exchange_stats.frames_recv > 0
    finally:
        gen.close()


# ---------------------------------------------------------------------------
# failure handling
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cluster_auto_restart_dead_host(tmp_path, single_host_ref):
    """Host 1 is killed (hard exit) mid-phase; the controller detects the
    death, relaunches it through the exec backend, re-dispatches the lost
    tasks, and the run completes bit-identical — within one launch."""
    spec, gen = _cluster(tmp_path, "ar", backend=_KillHost1First(),
                         max_restarts=1)
    try:
        gen.run()
        walks = np.asarray(gen.walk_corpus(W, L, seed=WSEED)).copy()
        assert gen.controller.restarts[1] == 1, gen.controller.restarts
        np.testing.assert_array_equal(walks, single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
    finally:
        gen.close()


@pytest.mark.slow
def test_cluster_host_kill_relaunch_resumes_host_only(tmp_path,
                                                      single_host_ref):
    """With the restart budget spent, a mid-phase host kill fails the run;
    relaunching the whole cluster over the same workdirs resumes: the
    surviving host replays NOTHING it completed (per-host checkpoints), only
    the killed host recomputes, and the output is bit-identical."""
    spec, gen = _cluster(tmp_path, "kr", backend=_KillHost1First(),
                         max_restarts=0)
    run1_done = set()
    try:
        with pytest.raises(ClusterError, match="restart budget"):
            gen.run()
    finally:
        run1_done = {e["key"] for e in gen.controller.task_log
                     if e["host"] == 0 and e["ok"]}
        gen.close()
    assert run1_done, "host 0 should have completed some tasks before abort"

    gen = ClusterGenerator(CFG.with_(transport="socket"), spec,
                           str(tmp_path / "kr" / "ctrl"),
                           backend=LocalExecBackend(env=_ENV), checkpoint=True)
    try:
        gen.run()
        walks = np.asarray(gen.walk_corpus(W, L, seed=WSEED)).copy()
        log = gen.controller.task_log
        recomputed = [e for e in log if e["host"] == 0
                      and e["key"] in run1_done and not e["resumed"]]
        assert not recomputed, f"host 0 recomputed: {recomputed[:5]}"
        assert any(e["host"] == 1 and not e["resumed"] for e in log), \
            "host 1 should have recomputed its unfinished work"
        np.testing.assert_array_equal(walks, single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
    finally:
        gen.close()


# ---------------------------------------------------------------------------
# pooled cascade + partitioned scatter (satellites)
# ---------------------------------------------------------------------------


def test_partitioned_scatter_bit_identical_to_sorted(tmp_path,
                                                     single_host_ref):
    """csr_variant='scatter' under the partitioned driver: same files as
    'sorted' (within-row adjacency is encounter order either way), but the
    ledger shows the Fig. 2 random-write blowup."""
    with PartitionedGenerator(CFG, str(tmp_path), max_workers=0) as part:
        csr, ledger = part.run(csr_variant="scatter")
        assert _csr_sha(csr) == single_host_ref["csr_sha"]
        assert ledger.rand_writes > 0
        walks = np.asarray(part.walk_corpus(W, L, seed=WSEED))
        np.testing.assert_array_equal(walks, single_host_ref["walks"])


def test_partitioned_scatter_after_checkpointed_sorted_fails_with_guidance(
        tmp_path):
    d = str(tmp_path)
    with PartitionedGenerator(CFG, d, max_workers=0, checkpoint=True) as p:
        p.run("sorted")
    with PartitionedGenerator(CFG, d, max_workers=0, checkpoint=True) as p:
        with pytest.raises(ValueError, match="keep_phase_stores"):
            p.run("scatter")


def test_pooled_cascade_bit_identical_and_resumable(tmp_path,
                                                    single_host_ref):
    """pooled_cascade at a tiny fan-in forces several pool-dispatched
    cascade LEVELS; output must match the flat merge bit for bit, and a
    completed checkpoint must resume every phase."""
    pcfg = CFG.with_(pooled_cascade=True, merge_fanin=2)
    d = str(tmp_path)
    with PartitionedGenerator(pcfg, d, max_workers=0, checkpoint=True) as p:
        csr, _ = p.run("sorted")
        assert _csr_sha(csr) == single_host_ref["csr_sha"]
        phases = [r["phase"] for r in p.orchestrator.report()]
        assert any(ph.startswith("csr_cascade_l") for ph in phases), phases
        walks = np.asarray(p.walk_corpus(W, L, seed=WSEED))
        np.testing.assert_array_equal(walks, single_host_ref["walks"])
    with PartitionedGenerator(pcfg, d, max_workers=0, checkpoint=True) as p:
        csr2, _ = p.run("sorted")
        assert all(r["status"] == "resumed" for r in p.orchestrator.report())
        assert _csr_sha(csr2) == single_host_ref["csr_sha"]


# ---------------------------------------------------------------------------
# spec / config-shape invariants (tier-1 twins of the hypothesis suite)
# ---------------------------------------------------------------------------


def test_cluster_spec_round_trip_and_ownership(tmp_path):
    spec = ClusterSpec(nb=8, hosts=(HostSpec(0, "/a", "n1"),
                                    HostSpec(1, "/b", "n2"),
                                    HostSpec(2, "/c")))
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    p = spec.save(str(tmp_path / "spec.json"))
    assert ClusterSpec.load(p) == spec
    owned = [b for h in range(3) for b in spec.buckets_of(h)]
    assert owned == list(range(8))                     # disjoint cover
    for b in range(8):
        assert b in spec.buckets_of(spec.owner_of(b))  # owner inverts


def test_cluster_spec_validation():
    with pytest.raises(ValueError, match="host_ids"):
        ClusterSpec(nb=4, hosts=(HostSpec(0, "/a"), HostSpec(2, "/b")))
    with pytest.raises(ValueError, match="distinct"):
        ClusterSpec(nb=4, hosts=(HostSpec(0, "/a"), HostSpec(1, "/a")))
    with pytest.raises(ValueError, match="cover"):
        ClusterSpec(nb=1, hosts=(HostSpec(0, "/a"), HostSpec(1, "/b")))


def test_peer_addrs_parse_format_round_trip():
    addrs = ("127.0.0.1:1234", "node1:80", "[::1]:9")
    assert parse_peer_addrs(format_peer_addrs(addrs)) == addrs
    with pytest.raises(ValueError):
        parse_peer_addrs("no-port")
    with pytest.raises(ValueError):
        parse_peer_addrs("host:notaport")


def test_result_config_key_normalizes_cluster_fields():
    """Resume across cluster shapes must hit the same key: transport and
    rendezvous addresses never affect the result bytes."""
    base = plain_config(CFG)
    sock = plain_config(CFG.with_(
        transport="socket", peer_addrs=("h1:1", "h2:2", "h1:3", "h2:4")))
    assert result_config_key(base) == result_config_key(sock)
    # pooled_cascade is bit-identical but schedule-different: kept IN the key
    pooled = plain_config(CFG.with_(pooled_cascade=True))
    assert result_config_key(pooled) != result_config_key(base)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cluster_cli_end_to_end(tmp_path):
    """`python -m repro.launch.cluster run` — controller + 2 hosts + corpus,
    all from the CLI (what the quickstart and the CI job exercise)."""
    root = str(tmp_path / "cli")
    env = dict(os.environ, **_ENV)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.cluster", "run",
         "--hosts", "2", "--workdir", root, "--scale", "8", "--nb", "4",
         "--edge-factor", "2", "--chunk-edges", "256",
         "--walkers", "12", "--length", "4"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    walks = ShardedWalks(os.path.join(root, "ctrl", "walks_manifest.json"))
    assert np.asarray(walks).shape == (12, 5)
    assert os.path.exists(os.path.join(root, "ctrl", "graph_manifest.json"))


# ---------------------------------------------------------------------------
# recompute shuffle on the cluster (communication-free permutation)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_two_host_cluster_recompute_parity(tmp_path):
    """shuffle_variant='recompute' across two real hosts: bit-identical CSR
    and walk corpus vs the single-host partitioned driver, with ZERO shuffle
    phases in the schedule — the permutation is recomputed on whichever host
    needs a label, never exchanged."""
    rcfg = CFG.with_(shuffle_variant="recompute")
    ref_dir = str(tmp_path / "ref")
    with PartitionedGenerator(rcfg, ref_dir, max_workers=0) as part:
        csr, _ = part.run()
        ref_walks = np.asarray(part.walk_corpus(W, L, seed=WSEED)).copy()
        ref_sha = _csr_sha(csr)
    spec = ClusterSpec.local(2, str(tmp_path / "cl"), nb=CFG.nb)
    gen = ClusterGenerator(rcfg.with_(transport="socket"), spec,
                           str(tmp_path / "cl" / "ctrl"),
                           backend=LocalExecBackend(env=_ENV), checkpoint=True)
    try:
        gen.run()
        walks = gen.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks), ref_walks)
        assert _csr_sha(gen.load_csr()) == ref_sha
        phases = [r["phase"] for r in gen.orchestrator.report()]
        assert not any(p.startswith("shuffle") for p in phases)
        assert "relabel_recompute_map" in phases
    finally:
        gen.close()
