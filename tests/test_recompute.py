"""The communication-free recompute shuffle (shuffle_variant='recompute').

Acceptance contract: with the keyed Feistel permutation family, a recompute
run produces BIT-IDENTICAL CSR bucket files to an external run of the same
seed that materializes the same family through the full store machinery —
while running zero shuffle phases, exchanging zero shuffle-phase wire bytes,
and moving strictly fewer ledger bytes.  Plus: the pooled-cascade routing of
the relabel join and the walk hops (PR 3 residue) stays bit-identical to the
inline cascade.
"""

import hashlib
import os

import numpy as np
import pytest

from repro.core.external import StreamingGenerator
from repro.core.hostgen import graph_perm_inv_np, graph_perm_np
from repro.core.phases import (
    PartitionedGenerator,
    csr_adjv_path,
    csr_offv_path,
    plain_config,
)
from repro.core.types import GraphConfig

CFG = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4)


def _file_sha(*paths) -> str:
    h = hashlib.sha256()
    for p in paths:
        with open(p, "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def _csr_file_sha(workdir: str, nb: int) -> str:
    return _file_sha(*[p for i in range(nb)
                       for p in (csr_offv_path(workdir, i),
                                 csr_adjv_path(workdir, i))])


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def test_recompute_canonicalizes_to_feistel():
    p = plain_config(CFG.with_(shuffle_variant="recompute"))
    assert p.perm_family == "feistel"
    assert p.feistel_rounds == 4


def test_config_validation():
    with pytest.raises(ValueError, match="shuffle_variant"):
        plain_config(CFG.with_(shuffle_variant="telepathy"))
    with pytest.raises(ValueError, match="perm_family"):
        plain_config(CFG.with_(perm_family="rot13"))
    with pytest.raises(ValueError, match="device"):
        plain_config(CFG.with_(shuffle_variant="device",
                               perm_family="feistel"))
    with pytest.raises(ValueError, match="scale"):
        plain_config(CFG.with_(scale=32, shuffle_variant="recompute"))
    with pytest.raises(ValueError, match="even"):
        plain_config(CFG.with_(shuffle_variant="recompute",
                               feistel_rounds=3))
    # feistel configs are exempt from the slice-exchange shape constraint
    # (nb need not divide bucket_size): scale 9 / nb 4 / feistel must build.
    plain_config(CFG.with_(shuffle_variant="external", perm_family="feistel"))


def test_result_config_key_separates_variants():
    from repro.core.phases import result_config_key
    keys = {result_config_key(plain_config(c))
            for c in (CFG.with_(shuffle_variant="external"),
                      CFG.with_(shuffle_variant="recompute"),
                      CFG.with_(shuffle_variant="external",
                                perm_family="feistel"),
                      CFG.with_(shuffle_variant="recompute",
                                feistel_rounds=6))}
    assert len(keys) == 4


# ---------------------------------------------------------------------------
# streaming driver parity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def streaming_pair(tmp_path_factory):
    out = {}
    for label, variant in (("external", "external"), ("recompute", "recompute")):
        d = str(tmp_path_factory.mktemp(label))
        gen = StreamingGenerator(
            CFG.with_(shuffle_variant=variant, perm_family="feistel"), d)
        pv, csr, ledger = gen.run()
        out[label] = {
            "workdir": d, "pv": np.asarray(pv).copy(),
            "csr_sha": _csr_file_sha(d, CFG.nb),
            "pv_sha": _file_sha(os.path.join(d, "pv.npy")),
            "bytes": ledger.bytes_read + ledger.bytes_written,
            "hash_evals": ledger.hash_evals,
            "report": gen.orchestrator.report(),
        }
    return out


def test_streaming_csr_bit_identical(streaming_pair):
    assert (streaming_pair["recompute"]["csr_sha"]
            == streaming_pair["external"]["csr_sha"])


def test_streaming_pv_bit_identical(streaming_pair):
    assert (streaming_pair["recompute"]["pv_sha"]
            == streaming_pair["external"]["pv_sha"])
    pv = streaming_pair["recompute"]["pv"]
    # pv is the recomputable family: forward and inverse agree with hostgen
    ids = np.arange(CFG.n, dtype=np.int64)
    np.testing.assert_array_equal(pv, graph_perm_np(CFG.seed, ids, CFG.n))
    np.testing.assert_array_equal(graph_perm_inv_np(CFG.seed, pv, CFG.n), ids)
    np.testing.assert_array_equal(np.sort(pv), ids)  # pv_is_permutation


def test_streaming_recompute_runs_no_shuffle_and_fewer_bytes(streaming_pair):
    rec, ext = streaming_pair["recompute"], streaming_pair["external"]
    rec_phases = [r["phase"] for r in rec["report"]]
    assert not any(p.startswith("shuffle") for p in rec_phases)
    assert "relabel_recompute" in rec_phases
    assert rec["bytes"] < ext["bytes"]
    assert rec["hash_evals"] > 0


def test_streaming_recompute_refuses_pv_stores(tmp_path):
    gen = StreamingGenerator(CFG.with_(shuffle_variant="recompute"),
                             str(tmp_path))
    with pytest.raises(ValueError, match="graph_perm_np"):
        gen.permutation()


def test_scatter_csr_rejects_feistel(tmp_path):
    gen = StreamingGenerator(
        CFG.with_(shuffle_variant="recompute", csr_variant="scatter"),
        str(tmp_path))
    with pytest.raises(ValueError, match="scatter"):
        gen.run()


# ---------------------------------------------------------------------------
# partitioned driver parity (pool) + zero shuffle wire bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [0, 2])
def test_partitioned_recompute_parity(tmp_path, workers, streaming_pair):
    d = str(tmp_path / "part")
    cfg = CFG.with_(shuffle_variant="recompute")
    with PartitionedGenerator(cfg, d, max_workers=workers) as part:
        part.run()
        report = part.orchestrator.report()
        assert part.ledger.hash_evals > 0
    assert _csr_file_sha(d, CFG.nb) == streaming_pair["external"]["csr_sha"]
    phases = [r["phase"] for r in report]
    assert not any(p.startswith("shuffle") for p in phases)
    # zero wire bytes outside the one owner exchange every variant pays
    for r in report:
        if not r["phase"].startswith("relabel_recompute"):
            assert r.get("wire_bytes_sent", 0) == 0, r


def test_partitioned_recompute_refuses_pv_buckets(tmp_path):
    with PartitionedGenerator(CFG.with_(shuffle_variant="recompute"),
                              str(tmp_path), max_workers=0) as part:
        with pytest.raises(ValueError, match="graph_perm_np"):
            part.pv_buckets()


def test_partitioned_recompute_pooled_cascade_parity(tmp_path, streaming_pair):
    d = str(tmp_path / "pooled")
    cfg = CFG.with_(shuffle_variant="recompute", pooled_cascade=True,
                    merge_fanin=2)
    with PartitionedGenerator(cfg, d, max_workers=2) as part:
        part.run()
    assert _csr_file_sha(d, CFG.nb) == streaming_pair["external"]["csr_sha"]


def test_partitioned_recompute_checkpoint_resume(tmp_path):
    cfg = CFG.with_(shuffle_variant="recompute")
    d = str(tmp_path / "ck")
    with PartitionedGenerator(cfg, d, max_workers=0, checkpoint=True) as part:
        part.run()
        sha = _csr_file_sha(d, CFG.nb)
    with PartitionedGenerator(cfg, d, max_workers=0, checkpoint=True) as part:
        part.run()
        report = part.orchestrator.report()
    assert _csr_file_sha(d, CFG.nb) == sha
    assert all(r["status"] == "resumed" for r in report)


# ---------------------------------------------------------------------------
# pooled relabel + pooled walk hops (PR 3 residue) — inline parity
# ---------------------------------------------------------------------------


def test_pooled_relabel_and_walks_bit_identical_to_inline(tmp_path):
    shas, corpora = [], []
    for pooled in (False, True):
        d = str(tmp_path / f"pc{pooled}")
        cfg = CFG.with_(shuffle_variant="external", pooled_cascade=pooled,
                        merge_fanin=2)
        with PartitionedGenerator(cfg, d, max_workers=0) as part:
            part.run()
            corpora.append(np.asarray(part.walk_corpus(19, 5, seed=7)).copy())
        shas.append(_csr_file_sha(d, CFG.nb))
    assert shas[0] == shas[1]
    np.testing.assert_array_equal(corpora[0], corpora[1])


def test_recompute_walks_match_external_feistel(tmp_path):
    corpora = []
    for variant in ("external", "recompute"):
        d = str(tmp_path / variant)
        cfg = CFG.with_(shuffle_variant=variant, perm_family="feistel")
        with PartitionedGenerator(cfg, d, max_workers=0) as part:
            part.run()
            corpora.append(np.asarray(part.walk_corpus(19, 5, seed=7)).copy())
    np.testing.assert_array_equal(corpora[0], corpora[1])


# ---------------------------------------------------------------------------
# device pipeline twins
# ---------------------------------------------------------------------------


def test_device_pipeline_recompute_variant():
    from repro.core.pipeline import generate
    from repro.distributed.collectives import flat_mesh

    cfg = GraphConfig(scale=7, nb=1, edge_factor=4)
    res = generate(cfg, flat_mesh(1), shuffle_variant="recompute")
    pv = np.asarray(res.pv)
    ids = np.arange(cfg.n, dtype=np.int64)
    np.testing.assert_array_equal(np.sort(pv), ids)
    np.testing.assert_array_equal(pv, graph_perm_np(cfg.seed, ids, cfg.n))
    # relabel_recompute relabeled through the same family: new = pv[old]
    from repro.core.pipeline import generate_edges
    src, dst = generate_edges(cfg, flat_mesh(1))
    np.testing.assert_array_equal(np.asarray(res.src), pv[np.asarray(src)])
    np.testing.assert_array_equal(np.asarray(res.dst), pv[np.asarray(dst)])
