"""Hypothesis property tests for the keyed invertible Feistel permutation
family (core/hostgen.py) and its jax/Pallas twins — the recompute shuffle's
correctness hinges on exactly these invariants:

  * feistel_perm_np is a BIJECTION on [0, 2**nbits) for every key/rounds,
    and feistel_perm_inv_np inverts it exactly;
  * keyed_perm_np cycle-walks any non-power-of-two [0, n) to a permutation
    (termination is a theorem — the walk traverses a cycle of a bijection
    on the covering power of two — but we assert it empirically too);
  * the three containers (numpy uint64, jnp uint32, Pallas int32 lanes)
    agree bit for bit on their shared domains, across input dtypes.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hostgen import (
    FEISTEL_ROUNDS,
    feistel_perm_inv_np,
    feistel_perm_np,
    graph_perm_inv_np,
    graph_perm_key,
    graph_perm_np,
    keyed_perm_inv_np,
    keyed_perm_np,
    perm_domain_bits,
)

SETTINGS = settings(max_examples=40, deadline=None)
KEYS = st.integers(0, 2**32 - 1)
EVEN_ROUNDS = st.sampled_from([2, 4, 6, 8])


# ---------------------------------------------------------------------------
# numpy family: bijectivity + inverse
# ---------------------------------------------------------------------------


@SETTINGS
@given(key=KEYS, nbits=st.integers(1, 12), rounds=EVEN_ROUNDS)
def test_feistel_full_bijection_small_domains(key, nbits, rounds):
    x = np.arange(1 << nbits, dtype=np.uint64)
    y = feistel_perm_np(x, key, nbits, rounds=rounds)
    assert y.dtype == np.uint64
    # bijection on the full domain: output is a permutation of the input
    np.testing.assert_array_equal(np.sort(y), x)
    np.testing.assert_array_equal(feistel_perm_inv_np(y, key, nbits,
                                                      rounds=rounds), x)


@SETTINGS
@given(key=KEYS, nbits=st.integers(1, 62), seed=st.integers(0, 2**31 - 1),
       rounds=EVEN_ROUNDS)
def test_feistel_inverse_round_trip_sampled(key, nbits, seed, rounds):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << nbits, 257, dtype=np.uint64)
    y = feistel_perm_np(x, key, nbits, rounds=rounds)
    assert int(y.max(initial=0)) < (1 << nbits)
    np.testing.assert_array_equal(feistel_perm_inv_np(y, key, nbits,
                                                      rounds=rounds), x)


@SETTINGS
@given(key=KEYS, rounds=st.sampled_from([0, 1, 3, 5, -2]))
def test_feistel_rejects_odd_or_tiny_rounds(key, rounds):
    with pytest.raises(ValueError):
        feistel_perm_np(np.arange(4, dtype=np.uint64), key, 2, rounds=rounds)


# ---------------------------------------------------------------------------
# cycle-walking: arbitrary domains
# ---------------------------------------------------------------------------


@SETTINGS
@given(key=KEYS, n=st.integers(1, 5000), rounds=EVEN_ROUNDS)
def test_cycle_walk_is_permutation_and_terminates(key, n, rounds):
    x = np.arange(n, dtype=np.int64)
    y = keyed_perm_np(x, key, n, rounds=rounds)   # termination: returns at all
    assert y.dtype == np.int64
    np.testing.assert_array_equal(np.sort(y), x)
    np.testing.assert_array_equal(keyed_perm_inv_np(y, key, n, rounds=rounds), x)


@SETTINGS
@given(key=KEYS, n=st.integers(1, 5000))
def test_cycle_walk_rejects_out_of_range(key, n):
    with pytest.raises(ValueError):
        keyed_perm_np(np.asarray([n], np.int64), key, n)
    with pytest.raises(ValueError):
        keyed_perm_np(np.asarray([-1], np.int64), key, n)


@SETTINGS
@given(seed=st.integers(0, 2**32 - 1), scale=st.integers(1, 16))
def test_graph_perm_matches_keyed_perm(seed, scale):
    n = 1 << scale
    x = np.arange(0, n, max(1, n // 64), dtype=np.int64)
    np.testing.assert_array_equal(
        graph_perm_np(seed, x, n),
        keyed_perm_np(x, graph_perm_key(seed), n))
    y = graph_perm_np(seed, x, n)
    np.testing.assert_array_equal(graph_perm_inv_np(seed, y, n), x)


# ---------------------------------------------------------------------------
# twin agreement: numpy / jnp / Pallas
# ---------------------------------------------------------------------------


@SETTINGS
@given(key=KEYS, nbits=st.integers(1, 32), seed=st.integers(0, 2**31 - 1),
       rounds=EVEN_ROUNDS,
       dtype=st.sampled_from([np.int64, np.uint64, np.int32, np.uint32]))
def test_numpy_jnp_twins_agree(key, nbits, seed, rounds, dtype):
    from repro.core.shuffle import feistel_perm

    rng = np.random.default_rng(seed)
    hi = min(1 << nbits, np.iinfo(dtype).max)
    x = rng.integers(0, max(1, hi), 129).astype(dtype)
    want = feistel_perm_np(x.astype(np.uint64), key, nbits, rounds=rounds)
    got = np.asarray(feistel_perm(np.asarray(x, np.uint32), key, nbits,
                                  rounds=rounds), np.uint64)
    np.testing.assert_array_equal(got, want)


@SETTINGS
@given(key=KEYS, n=st.integers(2, 5000), seed=st.integers(0, 2**31 - 1))
def test_numpy_jnp_cycle_walk_agree_non_power_of_two(key, n, seed):
    from repro.core.shuffle import keyed_perm

    rng = np.random.default_rng(seed)
    x = rng.integers(0, n, 65, dtype=np.int64)
    want = keyed_perm_np(x, key, n)
    got = np.asarray(keyed_perm(np.asarray(x, np.uint32), key, n), np.int64)
    np.testing.assert_array_equal(got, want)


@SETTINGS
@given(key=KEYS, nbits=st.integers(10, 14), seed=st.integers(0, 2**31 - 1))
def test_pallas_twin_agrees_on_power_of_two_tiles(key, nbits, seed):
    from repro.kernels.rmat import TILE, feistel_perm_pallas

    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << nbits, TILE, dtype=np.int32)
    want = feistel_perm_np(x.astype(np.uint64), key, nbits)
    got = np.asarray(feistel_perm_pallas(np.asarray(x), key, nbits), np.uint64)
    np.testing.assert_array_equal(got, want)


def test_perm_domain_bits():
    assert perm_domain_bits(1) == 1
    assert perm_domain_bits(2) == 1
    assert perm_domain_bits(3) == 2
    assert perm_domain_bits(1 << 20) == 20
    assert perm_domain_bits((1 << 20) + 1) == 21
    assert FEISTEL_ROUNDS % 2 == 0 and FEISTEL_ROUNDS >= 2
