"""End-to-end system tests: the launch drivers run whole jobs."""

import os
import subprocess
import sys

import numpy as np
import pytest


def test_train_driver_end_to_end(tmp_path):
    """Graph gen -> walks -> 60 train steps; loss must drop."""
    from repro.launch.train import main

    losses = main(["--scale", "10", "--steps", "60", "--batch", "8",
                   "--seq", "32", "--lr", "3e-3",
                   "--ckpt-dir", str(tmp_path / "ck")])
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_train_driver_resumes(tmp_path):
    """Kill after 30 steps, rerun: resumes from the checkpoint and the
    combined loss curve continues downward (deterministic data order)."""
    from repro.launch.train import main

    ck = str(tmp_path / "ck")
    first = main(["--scale", "10", "--steps", "30", "--batch", "4",
                  "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "10"])
    second = main(["--scale", "10", "--steps", "60", "--batch", "4",
                   "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "10"])
    # resumed run only executes the remaining steps
    assert len(second) < 60
    assert np.mean(second[-5:]) < np.mean(first[:5])


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    out = main(["--requests", "6", "--max-batch", "2", "--max-new", "6"])
    assert len(out) == 6
    assert all(len(v) == 6 for v in out.values())
