"""Data pipeline: host walks, tokenization, deterministic loader."""

import numpy as np
import pytest

from repro.core.pipeline import generate
from repro.core.types import GraphConfig
from repro.data import LoaderConfig, WalkLoader
from repro.data.walks import host_walks, walks_to_tokens

CFG = GraphConfig(scale=10, nb=1, capacity_factor=4.0)


@pytest.fixture(scope="module")
def graph():
    return generate(CFG)


def test_host_walks_follow_edges(graph):
    from repro.core.csr import csr_to_host

    offv, adjv = csr_to_host(graph.csr, CFG)
    starts = np.asarray([0, 17, 555])
    walks = host_walks(offv, adjv, starts, 20, seed=3, n=CFG.n)
    assert walks.shape == (3, 21)
    for w in walks:
        for t in range(20):
            u, v = w[t], w[t + 1]
            neigh = adjv[offv[u]:offv[u + 1]]
            if neigh.size:
                assert v in neigh
            else:
                assert 0 <= v < CFG.n     # sink teleport


def test_host_walks_deterministic(graph):
    from repro.core.csr import csr_to_host

    offv, adjv = csr_to_host(graph.csr, CFG)
    s = np.asarray([5, 6])
    a = host_walks(offv, adjv, s, 10, seed=1, n=CFG.n)
    b = host_walks(offv, adjv, s, 10, seed=1, n=CFG.n)
    np.testing.assert_array_equal(a, b)
    c = host_walks(offv, adjv, s, 10, seed=2, n=CFG.n)
    assert (a != c).any()


def test_walks_to_tokens_shift():
    walks = np.asarray([[10, 11, 12, 13]])
    tokens, labels = walks_to_tokens(walks, vocab=8)
    np.testing.assert_array_equal(tokens, [[2, 3, 4]])
    np.testing.assert_array_equal(labels, [[3, 4, 5]])


def test_loader_pure_function_of_step(graph):
    ld = WalkLoader(CFG, graph.csr, LoaderConfig(batch_size=4, seq_len=16, vocab=64))
    a = ld.batch(5)
    b = ld.batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = ld.batch(6)
    assert (np.asarray(a["tokens"]) != np.asarray(c["tokens"])).any()
    assert a["tokens"].shape == (4, 16)
    assert int(a["tokens"].max()) < 64


def test_loader_iterator(graph):
    ld = WalkLoader(CFG, graph.csr, LoaderConfig(batch_size=2, seq_len=8, vocab=32))
    it = iter(ld)
    b0 = next(it)
    b1 = next(it)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(ld.batch(0)["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(ld.batch(1)["tokens"]))
