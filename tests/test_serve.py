"""Serving engine: continuous batching must equal one-at-a-time decoding."""

import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.registry import init_all
from repro.serve import Engine, Request, SamplingParams, generate_reference

FAMS = ["internlm2-1.8b", "mamba2-780m", "zamba2-2.7b", "deepseek-v2-lite-16b"]


def _requests(n, vocab, seed=0, max_new=5):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(1, 7))
        out.append(Request(uid=i,
                           prompt=rng.integers(0, vocab, plen).tolist(),
                           max_new_tokens=max_new))
    return out


@pytest.mark.parametrize("arch", FAMS)
def test_engine_matches_oracle(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_all(cfg)
    reqs = _requests(5, cfg.vocab_size)
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    got = eng.run(reqs)
    for r in reqs:
        ref = generate_reference(cfg, params, r, max_len=64)
        assert got[r.uid] == ref, (arch, r.uid)


def test_slot_reuse_and_stats():
    cfg = get_smoke_config("internlm2-1.8b")
    params, _ = init_all(cfg)
    reqs = _requests(6, cfg.vocab_size, max_new=3)
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    out = eng.run(reqs)
    assert len(out) == 6
    assert eng.decode_tokens == 18
    # 6 requests x 3 tokens on 2 slots needs >= 9 engine steps
    assert eng.steps >= 9


def test_eos_stops_generation():
    cfg = get_smoke_config("internlm2-1.8b")
    params, _ = init_all(cfg)
    # find the greedy first token, then use it as eos
    probe = Request(uid=0, prompt=[5], max_new_tokens=1)
    first = generate_reference(cfg, params, probe, max_len=32)[0]
    req = Request(uid=1, prompt=[5], max_new_tokens=10, eos_id=first)
    eng = Engine(cfg, params, max_batch=1, max_len=32)
    out = eng.run([req])
    assert out[1] == [first]


def test_bucketed_prefill_equals_exact():
    """Right-padded power-of-two prefill must not change results (dense)."""
    cfg = get_smoke_config("internlm2-1.8b")
    params, _ = init_all(cfg)
    reqs = _requests(4, cfg.vocab_size, seed=3)
    out_b = Engine(cfg, params, max_batch=2, max_len=64,
                   bucket_prefill=True).run([dataclasses.replace(r) for r in reqs])
    out_e = Engine(cfg, params, max_batch=2, max_len=64,
                   bucket_prefill=False).run([dataclasses.replace(r) for r in reqs])
    assert out_b == out_e


def test_temperature_sampling_is_deterministic():
    cfg = get_smoke_config("internlm2-1.8b")
    params, _ = init_all(cfg)
    mk = lambda: Request(uid=0, prompt=[1, 2], max_new_tokens=6,  # noqa: E731
                         sampling=SamplingParams(temperature=0.8, top_k=10, seed=42))
    a = Engine(cfg, params, max_batch=1, max_len=32).run([mk()])
    b = Engine(cfg, params, max_batch=1, max_len=32).run([mk()])
    assert a == b
