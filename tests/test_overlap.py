"""Overlapped I/O layer (ISSUE 9): PrefetchReader / WriteBehindWriter
parity + error rethrow, stall-counter accounting, gauge bounds under
doubled residency, io_overlap config plumbing, and on-vs-off bit-identity
on the streaming, partitioned, and cluster shapes (incl. kill + resume)."""

import hashlib
import os
import pickle

import numpy as np
import pytest

from repro.core.blockstore import (
    BlockStore,
    IOLedger,
    MemoryGauge,
    PrefetchReader,
    WriteBehindWriter,
    merge_runs,
    partition_runs,
    sort_runs,
    write_behind,
)
from repro.core.external import StreamingGenerator
from repro.core.phases import (
    _KERNELS,
    PartitionedGenerator,
    plain_config,
    result_config_key,
)
from repro.core.types import GraphConfig


def _digest(stream):
    h = hashlib.sha256()
    for cols in stream:
        for c in cols:
            h.update(np.ascontiguousarray(c).tobytes())
    return h.hexdigest()


def _store_digest(store):
    h = hashlib.sha256()
    for i in range(store.num_runs):
        for c in store.read_run(i):
            h.update(np.ascontiguousarray(c).tobytes())
    return h.hexdigest()


def _csr_sha(csr):
    h = hashlib.sha256()
    for o, a in csr:
        h.update(np.asarray(o).tobytes())
        h.update(np.asarray(a).tobytes())
    return h.hexdigest()


def _build(workdir, name, nruns=12, rows=128, seed=0):
    store = BlockStore(workdir, name, IOLedger(), columns=("k", "p"))
    rng = np.random.default_rng(seed)
    for i in range(nruns):
        k = np.sort(rng.integers(0, 1 << 30, rows))
        store.append_run(k, i * rows + np.arange(rows))
    return store


# ---------------------------------------------------------------------------
# PrefetchReader / WriteBehindWriter primitives
# ---------------------------------------------------------------------------


def test_prefetch_reader_yields_identical_stream():
    items = [np.arange(i, i + 5) for i in range(7)]
    led = IOLedger()
    got = list(PrefetchReader(iter(items), ledger=led))
    assert len(got) == len(items)
    for a, b in zip(got, items):
        np.testing.assert_array_equal(a, b)
    # stall accounting landed somewhere (wait or hidden, both legal)
    d = led.as_dict()
    assert d["read_wait_s"] >= 0.0 and d["overlap_s"] >= 0.0


def test_prefetch_reader_rethrows_at_consumer():
    def gen():
        yield 1
        yield 2
        raise OSError("disk gone")

    r = PrefetchReader(gen())
    assert next(r) == 1
    assert next(r) == 2
    with pytest.raises(OSError, match="disk gone"):
        next(r)
    r.close()  # close after error must not raise again


def test_prefetch_reader_close_mid_stream():
    def gen():
        for i in range(100):
            yield i

    r = PrefetchReader(gen())
    assert next(r) == 0
    r.close()  # abandoning the stream must not hang or leak the thread


@pytest.mark.parametrize("rows", [64, 8192])
def test_write_behind_writer_parity_and_order(tmp_path, rows):
    """Both sides of the async byte floor: 64-row chunks append inline
    (handoff would cost more than the write), 8192-row int64 chunks ride
    the writer thread — bit-identical stores and tag order either way."""
    led, gauge = IOLedger(), MemoryGauge()
    direct = BlockStore(str(tmp_path), "direct", led, columns=("a", "b"))
    behind = BlockStore(str(tmp_path), "behind", led, columns=("a", "b"),
                        gauge=gauge)
    rng = np.random.default_rng(3)
    chunks = [(rng.integers(0, 99, rows), rng.integers(0, 99, rows))
              for _ in range(9)]
    for a, b in chunks:
        direct.append_run(a, b, tag=f"t_{direct.num_runs:05d}")
    with WriteBehindWriter([behind], ledger=led, gauge=gauge) as w:
        sink = w.sink(0)
        for i, (a, b) in enumerate(chunks):
            sink.append_run(a, b, tag=f"t_{i:05d}")
    assert _store_digest(behind) == _store_digest(direct)
    # FIFO single writer: tag order (= append order) is preserved
    assert behind.manifest()["runs"] == direct.manifest()["runs"]


def test_write_behind_error_rethrows_and_fails_stop(tmp_path):
    class _Boom:
        columns = ("v",)

        def __init__(self):
            self.appended = 0

        def append_run(self, *cols, tag=None):
            self.appended += 1
            if self.appended == 2:
                raise OSError("enospc")

    sink = _Boom()
    w = WriteBehindWriter([sink], ledger=IOLedger())
    proxy = w.sink(0)
    big = np.zeros(9000, np.int64)  # above the async floor: writer thread
    proxy.append_run(big)
    with pytest.raises(OSError, match="enospc"):
        # the failure surfaces at a subsequent put/flush/close, never lost
        for _ in range(8):
            proxy.append_run(big)
        w.flush()
    w.abort()
    # fail-stop: nothing was written past the failing chunk
    assert sink.appended == 2


def test_write_behind_context_aborts_on_exception(tmp_path):
    led = IOLedger()
    out = BlockStore(str(tmp_path), "o", led, columns=("v",))
    with pytest.raises(RuntimeError, match="consumer died"):
        with write_behind([out], led, MemoryGauge()) as sinks:
            sinks[0].append_run(np.arange(4))
            raise RuntimeError("consumer died")  # must not mask into an I/O error


def test_write_behind_disabled_passthrough(tmp_path):
    led = IOLedger()
    out = BlockStore(str(tmp_path), "o", led, columns=("v",))
    with write_behind([out], led, MemoryGauge(), enabled=False) as sinks:
        assert sinks[0] is out  # serial path: the store itself, no proxy


# ---------------------------------------------------------------------------
# kernel primitives: bit-identity on vs off
# ---------------------------------------------------------------------------


def test_sort_merge_partition_overlap_bit_identical(tmp_path):
    d = str(tmp_path)
    src = _build(d, "src", nruns=11, rows=200)
    led, gauge = IOLedger(), MemoryGauge()

    ref_sorted = BlockStore(d, "s0", led, columns=("k", "p"), gauge=gauge)
    ov_sorted = BlockStore(d, "s1", led, columns=("k", "p"), gauge=gauge)
    sort_runs(src, ref_sorted, key=0)
    sort_runs(src, ov_sorted, key=0, overlap=True)
    assert _store_digest(ov_sorted) == _store_digest(ref_sorted)

    # cascaded merge (max_fanin=3 forces two levels over 11 runs)
    ref = _digest(merge_runs(ref_sorted, key=0, max_fanin=3))
    ov = _digest(merge_runs(ref_sorted, key=0, max_fanin=3, overlap=True))
    assert ov == ref

    parts_ref = [BlockStore(d, f"pr{j}", led, columns=("k", "p"), gauge=gauge)
                 for j in range(3)]
    parts_ov = [BlockStore(d, f"po{j}", led, columns=("k", "p"), gauge=gauge)
                for j in range(3)]
    partition_runs(src, parts_ref, lambda k, p: k % 3, tag_prefix="x")
    partition_runs(src, parts_ov, lambda k, p: k % 3, tag_prefix="x",
                   overlap=True)
    for a, b in zip(parts_ov, parts_ref):
        assert _store_digest(a) == _store_digest(b)
        assert [os.path.basename(p) for p in a.manifest()["runs"]] == \
               [os.path.basename(p) for p in b.manifest()["runs"]]


def test_overlap_peak_rows_at_most_doubles(tmp_path):
    """The tentpole memory contract: overlap <= DOUBLES the resident chunk
    bound, never more (one in-flight buffer per direction)."""
    d = str(tmp_path)
    src = _build(d, "src", nruns=9, rows=256)

    def peak(overlap):
        led, gauge = IOLedger(), MemoryGauge()
        store = BlockStore.attach(d, "src", led, columns=("k", "p"),
                                  gauge=gauge)
        out = BlockStore(d, f"out{int(overlap)}", led, columns=("k", "p"),
                         gauge=gauge)
        with write_behind([out], led, gauge, enabled=overlap) as sinks:
            for cols in merge_runs(store, key=0, max_fanin=3,
                                   overlap=overlap):
                sinks[0].append_run(*cols)
        return gauge.peak_rows

    assert peak(True) <= 2 * peak(False)


def test_gauge_cursor_rows_derives_from_budget():
    """Satellite: refill block size comes from the gauge budget / fan-in,
    halved under overlap so prefetch doubling stays inside the budget."""
    g = MemoryGauge(budget_rows=1024)
    assert g.cursor_rows(4, 10 ** 9) == 1024 // 4
    assert g.cursor_rows(4, 10 ** 9, overlap=True) == 1024 // 8
    # small runs win over the budget cap
    assert g.cursor_rows(4, 64) == 16
    # no budget -> legacy max_run / fan split
    assert MemoryGauge().cursor_rows(4, 1000) == 250
    assert g.cursor_rows(4096, 10 ** 9) == 1  # floor at 1 row


def test_deep_cascade_stays_inside_budget_with_overlap(tmp_path):
    d = str(tmp_path)
    _build(d, "deep", nruns=27, rows=64)
    budget = 512
    led = IOLedger()
    gauge = MemoryGauge(budget_rows=budget)
    store = BlockStore.attach(d, "deep", led, columns=("k", "p"), gauge=gauge)
    ref = _digest(merge_runs(store, key=0, max_fanin=3))
    gauge2 = MemoryGauge(budget_rows=budget)
    store2 = BlockStore.attach(d, "deep", led, columns=("k", "p"),
                               gauge=gauge2)
    ov = _digest(merge_runs(store2, key=0, max_fanin=3, overlap=True))
    assert ov == ref
    # cursor buffers (fan * block, doubled for prefetch) never exceeded the
    # budget; emitted merge blocks are charged separately and are bounded
    # by the same budget per buffer.
    assert gauge2.peak_rows <= 2 * budget


def test_read_run_whole_run_load_is_gauge_tracked(tmp_path):
    """Satellite: read_run loads the WHOLE run (mmap_mode=None) and must
    report that allocation — block-sized consumers go through iter_blocks."""
    led = IOLedger()
    store = BlockStore(str(tmp_path), "r", led, columns=("v",))
    store.append_run(np.arange(5000))
    g_read = MemoryGauge()
    s1 = BlockStore.attach(str(tmp_path), "r", led, columns=("v",),
                           gauge=g_read)
    s1.read_run(0)
    assert g_read.peak_rows == 5000  # the whole-run load was tracked
    g_blk = MemoryGauge()
    s2 = BlockStore.attach(str(tmp_path), "r", led, columns=("v",),
                           gauge=g_blk)
    got = 0
    for (v,) in s2.iter_blocks(512):
        got += v.size
    assert got == 5000
    assert g_blk.peak_rows == 512  # block-sized path stays block-sized


# ---------------------------------------------------------------------------
# IOLedger stall counters: snapshot / delta / merge / pickle
# ---------------------------------------------------------------------------


def test_stall_counters_snapshot_delta_merge_roundtrip():
    led = IOLedger()
    led.read(4096)
    led.hashes(10)
    led.bucket(3, 512)
    led.stall(read_wait_s=0.25, overlap_s=1.5)
    snap = led.snapshot()
    led.write(8192, sequential=False)
    led.hashes(7)
    led.bucket(3, 128)
    led.bucket(5, 64)
    led.stall(read_wait_s=0.125, write_wait_s=0.5, overlap_s=0.25)
    delta = led.delta_since(snap)
    assert delta["read_wait_s"] == pytest.approx(0.125)
    assert delta["write_wait_s"] == pytest.approx(0.5)
    assert delta["overlap_s"] == pytest.approx(0.25)
    assert delta["bytes_written"] == 8192 and delta["rand_writes"] == 1
    assert delta["hash_evals"] == 7
    # dict-valued counters survive the snapshot/delta flattening
    assert delta["bucket_bytes[3]"] == 128
    assert delta["bucket_bytes[5]"] == 64
    assert delta["bytes_read"] == 0

    # merge() accumulates the stalls like any other counter
    other = IOLedger()
    other.merge(led.as_dict())
    other.merge(delta)
    assert other.read_wait_s == pytest.approx(0.375 + 0.125)
    assert other.write_wait_s == pytest.approx(1.0)
    assert other.overlap_s == pytest.approx(1.75 + 0.25)
    assert other.bucket_bytes[3] == 640 + 128
    assert other.hash_evals == 17 + 7


def test_ledger_and_gauge_pickle_across_processes():
    """Locks are runtime-only state: both must pickle (pool workers ship
    them back to the parent) and rebuild a working lock on load."""
    led = IOLedger()
    led.stall(read_wait_s=0.5, overlap_s=0.25)
    led2 = pickle.loads(pickle.dumps(led))
    assert led2.read_wait_s == pytest.approx(0.5)
    led2.stall(write_wait_s=0.125)  # lock was rebuilt, not lost
    g = MemoryGauge(budget_rows=777)
    g.track(10)
    g2 = pickle.loads(pickle.dumps(g))
    assert g2.budget_rows == 777 and g2.peak_rows == 10
    g2.track(20)
    assert g2.peak_rows == 20


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def test_io_overlap_normalized_out_of_result_key(monkeypatch):
    monkeypatch.delenv("REPRO_IO_OVERLAP", raising=False)
    cfg_on = GraphConfig(scale=9, nb=4, chunk_edges=256,
                         shuffle_variant="external")
    cfg_off = cfg_on.with_(io_overlap=False)
    p_on, p_off = plain_config(cfg_on), plain_config(cfg_off)
    assert p_on.io_overlap is True and p_off.io_overlap is False
    assert result_config_key(p_on) == result_config_key(p_off)


def test_io_overlap_env_override(monkeypatch):
    cfg = GraphConfig(scale=9, nb=4, shuffle_variant="external")
    monkeypatch.setenv("REPRO_IO_OVERLAP", "0")
    assert plain_config(cfg).io_overlap is False
    monkeypatch.setenv("REPRO_IO_OVERLAP", "1")
    assert plain_config(cfg.with_(io_overlap=False)).io_overlap is True
    monkeypatch.delenv("REPRO_IO_OVERLAP")
    assert plain_config(cfg).io_overlap is True


# ---------------------------------------------------------------------------
# deployment shapes: on vs off bit-identity
# ---------------------------------------------------------------------------

_CFG = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                   shuffle_variant="external")


def test_streaming_overlap_on_off_bit_identical(tmp_path):
    pv_on, csr_on, led_on = StreamingGenerator(
        _CFG, str(tmp_path / "on")).run()
    pv_off, csr_off, led_off = StreamingGenerator(
        _CFG.with_(io_overlap=False), str(tmp_path / "off")).run()
    np.testing.assert_array_equal(np.asarray(pv_on), np.asarray(pv_off))
    assert _csr_sha(csr_on) == _csr_sha(csr_off)
    # timing-only: the BYTE accounting is identical too, only stalls differ
    assert led_on.bytes_read == led_off.bytes_read
    assert led_on.bytes_written == led_off.bytes_written
    assert led_off.read_wait_s == 0.0 == led_off.write_wait_s


def test_partitioned_overlap_on_off_bit_identical(tmp_path):
    with PartitionedGenerator(_CFG, str(tmp_path / "on"),
                              max_workers=0) as p_on:
        csr_on, _ = p_on.run()
        walks_on = np.asarray(p_on.walk_corpus(17, 5, seed=3)).copy()
        sha_on = _csr_sha(csr_on)
    with PartitionedGenerator(_CFG.with_(io_overlap=False),
                              str(tmp_path / "off"), max_workers=0) as p_off:
        csr_off, _ = p_off.run()
        walks_off = np.asarray(p_off.walk_corpus(17, 5, seed=3)).copy()
        sha_off = _csr_sha(csr_off)
    assert sha_on == sha_off
    np.testing.assert_array_equal(walks_on, walks_off)


def test_mid_phase_kill_resume_with_overlap_on(tmp_path):
    """A kernel dying mid-phase with overlap ON (in-flight write-behind
    chunks lost) must rethrow at the phase, never checkpoint the phase, and
    resume bit-identical to an overlap-OFF uninterrupted run."""
    ref_dir = str(tmp_path / "ref")
    with PartitionedGenerator(_CFG.with_(io_overlap=False), ref_dir,
                              max_workers=0) as ref:
        csr_ref, _ = ref.run()
        sha_ref = _csr_sha(csr_ref)

    d = str(tmp_path / "crash")
    orig = _KERNELS["relabel_apply"]
    calls = {"n": 0}

    def crashing_apply(pcfg, workdir, i, pass_ix, *, ledger, gauge=None,
                       transport=None):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected mid-phase kill")
        return orig(pcfg, workdir, i, pass_ix, ledger=ledger, gauge=gauge,
                    transport=transport)

    _KERNELS["relabel_apply"] = crashing_apply
    try:
        with PartitionedGenerator(_CFG, d, max_workers=0,
                                  checkpoint=True) as part:
            with pytest.raises(RuntimeError, match="injected"):
                part.run()
    finally:
        _KERNELS["relabel_apply"] = orig

    with PartitionedGenerator(_CFG, d, max_workers=0,
                              checkpoint=True) as part:
        csr, _ = part.run()
        statuses = {r["phase"]: r["status"]
                    for r in part.orchestrator.report()}
    assert statuses["shuffle"] == "resumed", statuses
    assert statuses["generate"] == "resumed", statuses
    assert _csr_sha(csr) == sha_ref


@pytest.mark.slow
def test_two_host_cluster_overlap_off_parity(tmp_path):
    """2-host socket cluster with io_overlap FORCED OFF == the single-host
    partitioned run with it on (default): cross-shape AND cross-flag parity
    in one run — the existing cluster suite already pins cluster-on ==
    single-host-on."""
    from repro.core.cluster import ClusterGenerator, ClusterSpec, LocalExecBackend
    import repro as _repro

    src = os.path.dirname(os.path.dirname(os.path.abspath(_repro.__file__)))
    with PartitionedGenerator(_CFG, str(tmp_path / "ref"),
                              max_workers=0) as ref:
        csr_ref, _ = ref.run()
        walks_ref = np.asarray(ref.walk_corpus(17, 5, seed=3)).copy()
        sha_ref = _csr_sha(csr_ref)

    spec = ClusterSpec.local(2, str(tmp_path / "cl"), nb=_CFG.nb)
    gen = ClusterGenerator(
        _CFG.with_(transport="socket", io_overlap=False), spec,
        str(tmp_path / "cl" / "ctrl"),
        backend=LocalExecBackend(env={"PYTHONPATH": src,
                                      "REPRO_IO_OVERLAP": "0"}),
        checkpoint=True)
    try:
        gen.run()
        walks = np.asarray(gen.walk_corpus(17, 5, seed=3)).copy()
        assert _csr_sha(gen.load_csr()) == sha_ref
        np.testing.assert_array_equal(walks, walks_ref)
    finally:
        gen.close()
