"""Fault tolerance: atomic checkpoints, corrupt-latest fallback, restart
supervision, heartbeats, elastic re-mesh restore."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train.fault import HeartbeatMonitor, StragglerPolicy, WorkerFailure, run_with_restarts


def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x)}, "step": jnp.asarray(x)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    s = _state(3.0)
    ck.save(d, 7, s)
    got = ck.restore(d, 7, _state())
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), 3.0)
    assert float(got["step"]) == 3.0
    assert ck.latest_step(d) == 7


def test_keep_k_gc(tmp_path):
    d = str(tmp_path)
    for i in range(6):
        ck.save(d, i, _state(i), keep=3)
    assert ck.all_steps(d) == [3, 4, 5]


def test_corrupt_latest_falls_back(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _state(1.0))
    ck.save(d, 2, _state(2.0))
    # corrupt the newest: truncate a leaf file
    leaf = os.path.join(d, "step_00000002", "params.w.npy")
    with open(leaf, "wb") as f:
        f.write(b"not-numpy")
    assert ck.latest_step(d) == 1
    got, step = ck.restore_latest(d, _state())
    assert step == 1
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), 1.0)


def test_mid_save_crash_leaves_no_trusted_ckpt(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _state(1.0))
    # simulate a crash mid-save: a tmp dir without rename
    os.makedirs(os.path.join(d, "tmp.step_00000005"))
    with open(os.path.join(d, "tmp.step_00000005", "params.w.npy"), "wb") as f:
        f.write(b"partial")
    assert ck.latest_step(d) == 1  # tmp dir never trusted


def test_manifest_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ck.save(d, 3, _state(1.0))
    man = os.path.join(d, "step_00000003", "manifest.json")
    m = json.load(open(man))
    m["leaves"]["params.w"]["shape"] = [9, 9]
    json.dump(m, open(man, "w"))
    assert ck.latest_step(d) is None


def test_async_save(tmp_path):
    d = str(tmp_path)
    ck.save(d, 4, _state(4.0), blocking=False)
    ck.wait_for_async_saves()
    assert ck.latest_step(d) == 4


def test_run_with_restarts_survives_failures(tmp_path):
    d = str(tmp_path)
    crashes = {"left": 3}
    seen_steps = []

    def train_fn(state, step):
        seen_steps.append(step)
        if step == 7 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise WorkerFailure("node died")
        return {"params": {"w": state["params"]["w"] + 1.0},
                "step": jnp.asarray(float(step))}

    final = run_with_restarts(
        train_fn, ckpt_dir=d, init_state=_state(), total_steps=10,
        save_every=2, max_restarts=5)
    # 10 net steps succeeded; each crash replayed from the last checkpoint
    assert float(final["step"]) == 9.0
    assert seen_steps.count(7) == 4           # 3 failures + 1 success
    # deterministic data order: replayed steps are exactly the ckpt-aligned suffix
    assert seen_steps[:8] == list(range(8))


def test_run_with_restarts_gives_up(tmp_path):
    def always_fail(state, step):
        raise WorkerFailure("dead")

    with pytest.raises(WorkerFailure):
        run_with_restarts(always_fail, ckpt_dir=str(tmp_path),
                          init_state=_state(), total_steps=3,
                          save_every=1, max_restarts=2)


def test_heartbeat_monitor():
    t = {"now": 0.0}
    hb = HeartbeatMonitor([0, 1, 2], timeout=10.0, clock=lambda: t["now"])
    t["now"] = 5.0
    hb.beat(0)
    hb.beat(1)
    t["now"] = 12.0
    assert hb.dead() == [2]
    assert hb.alive() == [0, 1]
    hb.beat(2)
    assert hb.dead() == []


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one sharding restores onto a different mesh
    (here: 1 device with a different target sharding object)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    d = str(tmp_path)
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(d, 0, s)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
    sh = {"w": NamedSharding(mesh, P("x", None))}
    got = ck.restore(d, 0, s, shardings=sh)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(s["w"]))
    assert got["w"].sharding == sh["w"]
