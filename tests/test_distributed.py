"""Multi-shard behaviour on 8 fake CPU devices.

XLA locks the device count at first jax init, so these run in SUBPROCESSES
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the conftest/pytest
process itself must keep seeing 1 device per the assignment).
"""

import os
import subprocess
import sys

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(body: str, timeout=600):
    r = subprocess.run([sys.executable, "-c", body], env=ENV, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pipeline_8_shards_full_validation():
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core.types import GraphConfig
from repro.core.pipeline import generate
from repro.core import validate as V
from repro.core.rmat import rmat_edge_block

cfg = GraphConfig(scale=12, nb=8, capacity_factor=4.0)
res = generate(cfg)
assert int(res.dropped_redistribute) == 0
assert V.check_permutation(res.pv)
src, dst = rmat_edge_block(cfg, jnp.uint32(0), cfg.m)
assert V.check_relabel(src, dst, res.src, res.dst, res.pv)
assert V.check_ownership(res.owned.src, res.owned.valid, cfg)
checks = V.check_csr(res.csr, res.owned, cfg)
assert all(checks.values()), checks
print("OK8")
""")
    assert "OK8" in out


def test_shard_count_invariance():
    """The SAME graph comes out at nb=1, 2, 8 (counter RNG + deterministic
    shuffle make the pipeline topology-independent) — the property that lets
    an elastic restart regenerate data on a different cluster size."""
    out = run_py("""
import numpy as np
from repro.core.types import GraphConfig
from repro.core.pipeline import generate
from repro.core.csr import csr_to_host
from repro.core import validate as V

degs = []
for nb in (1, 2, 8):
    cfg = GraphConfig(scale=10, nb=nb, capacity_factor=6.0)
    res = generate(cfg)
    assert int(res.dropped_redistribute) == 0, nb
    # relabeled edge multiset is the invariant (pv depends on nb rounds)
    degs.append(np.sort(np.asarray(V.edge_multiset(res.src, res.dst))))
# pv differs per nb (different shuffle round structure) but every variant
# must be a valid de-biased graph with identical degree STATISTICS profile;
# exact-multiset equality holds between runs with the same nb:
res2 = generate(GraphConfig(scale=10, nb=8, capacity_factor=6.0))
np.testing.assert_array_equal(
    degs[2], np.sort(np.asarray(V.edge_multiset(res2.src, res2.dst))))
print("OKINV")
""")
    assert "OKINV" in out


def test_distributed_walks_match_host_oracle():
    out = run_py("""
import numpy as np
from repro.core.types import GraphConfig
from repro.core.pipeline import generate
from repro.core.csr import csr_to_host
from repro.data.walks import distributed_walks, host_walks, start_vertex
from repro.distributed.collectives import flat_mesh

cfg = GraphConfig(scale=10, nb=8, capacity_factor=4.0)
mesh = flat_mesh(8)
res = generate(cfg, mesh)
offv, adjv = csr_to_host(res.csr, cfg)
W = 16
hist, valid, wid, dropped = distributed_walks(
    cfg, mesh, res.csr.offv, res.csr.adjv,
    length=12, seed=7, walkers_per_shard=W, capacity_factor=8.0)
hist, valid, wid = map(np.asarray, (hist, valid, wid))
assert int(dropped) == 0, int(dropped)
live = valid & (wid >= 0)
assert live.sum() == 8 * W
starts = start_vertex(7, wid[live].astype(np.uint32), cfg.bucket_size,
                      (wid[live] // W) * cfg.bucket_size)
ref = host_walks(offv, adjv, starts, 12, 7, n=cfg.n, walker_ids=wid[live])
np.testing.assert_array_equal(hist[live], ref)
print("OKWALK")
""")
    assert "OKWALK" in out


def test_moe_alltoall_matches_dense_dispatch():
    """EP all_to_all dispatch == dense dispatch (same routing, same experts)
    on a (2 data x 4 model) mesh."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs.base import get_smoke_config
from repro.models.registry import init_all, get_model
from repro.models.nn import DistContext
from repro.distributed.sharding import make_dist

cfg = get_smoke_config('qwen3-moe-235b-a22b').with_(num_layers=2)
api = get_model(cfg)
params, f = init_all(cfg)
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ('data', 'model'))
B, S = 4, 8
tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)), jnp.int32)
batch = {'tokens': tokens}

logits_dense, aux_d = api.forward(cfg, params, batch, None)
dist = make_dist(cfg, mesh, None, fsdp=False, moe_dispatch='alltoall')
logits_a2a, aux_a = api.forward(cfg, params, batch, dist)
assert float(aux_a['dropped']) == 0.0, float(aux_a['dropped'])
np.testing.assert_allclose(np.asarray(logits_dense, np.float32),
                           np.asarray(logits_a2a, np.float32), atol=3e-2, rtol=3e-2)
print("OKMOE")
""")
    assert "OKMOE" in out


def test_external_shuffle_parity_8_shards():
    """The disk-resident external shuffle (paper Alg. 2-4 on disk) is
    bit-identical to the device shuffle on an 8-shard mesh, and the full
    external pipeline reproduces the device pipeline's graph."""
    out = run_py("""
import tempfile
import numpy as np
from repro.core.types import GraphConfig
from repro.core.external import StreamingGenerator
from repro.core.pipeline import generate
from repro.core.shuffle import distributed_shuffle
from repro.distributed.collectives import flat_mesh

cfg = GraphConfig(scale=10, nb=8, chunk_edges=128, edge_factor=4,
                  capacity_factor=6.0, shuffle_variant="external")
with tempfile.TemporaryDirectory() as d:
    gen = StreamingGenerator(cfg, d)
    pv_ext, csr_ext, ledger = gen.run()
    pv_ext = np.asarray(pv_ext).copy()
    deg_ext = np.concatenate([np.diff(o) for o, _ in csr_ext])
    adj_rows = [np.sort(np.asarray(a[o[r]:o[r+1]]))
                for o, a in csr_ext for r in range(len(o) - 1)]
pv_dev = np.asarray(distributed_shuffle(cfg, flat_mesh(8)))
np.testing.assert_array_equal(pv_ext, pv_dev)
res = generate(cfg)
from repro.core.csr import csr_to_host
o_dev, a_dev = csr_to_host(res.csr, cfg)
np.testing.assert_array_equal(deg_ext, np.diff(o_dev))
for r in range(cfg.n):
    np.testing.assert_array_equal(adj_rows[r], np.sort(a_dev[o_dev[r]:o_dev[r+1]]))
assert ledger.rand_reads == 0 == ledger.rand_writes
print("OKEXT")
""")
    assert "OKEXT" in out


def test_podwise_int8_psum():
    """Cross-pod compressed gradient reduction ~= exact mean."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.collectives import shard_map
from repro.train.compression import podwise_psum_int8

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ('pod',))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

def per_pod(gl):
    return podwise_psum_int8({'w': gl[0]}, 'pod')['w']

out = shard_map(per_pod, mesh=mesh, in_specs=P('pod'), out_specs=P('pod'))(g)
got = np.asarray(out).reshape(8, -1)
want = np.asarray(g).mean(0)
for i in range(8):
    np.testing.assert_allclose(got[i], want, atol=2e-2)
print("OKPSUM")
""")
    assert "OKPSUM" in out
