"""Out-of-core random walks: oracle parity, bounded memory, resumability.

The external sampler (data/walks.external_walks + the walk kernels in
core/phases.py) must be bit-identical to the host oracle on the same CSR
layout, keep its working set independent of graph size, do zero random I/O,
and survive a mid-corpus crash without changing a single byte of output.
"""

import numpy as np
import pytest

from repro.core.blockstore import IOLedger, MemoryGauge
from repro.core.external import StreamingGenerator
from repro.core.phases import PartitionedGenerator, _KERNELS
from repro.core.types import GraphConfig
from repro.data import ExternalWalkLoader, LoaderConfig, WalkLoader
from repro.data.walks import (
    concat_bucket_csr, external_walks, host_walks, start_vertex)


def _external_graph(cfg, workdir):
    """Generate via the disk tier and return the assembled oracle CSR."""
    _, csr, _ = StreamingGenerator(cfg, workdir).run()
    return concat_bucket_csr(csr)


def _oracle(offv, adjv, n, W, L, seed):
    wid = np.arange(W, dtype=np.uint32)
    starts = start_vertex(seed, wid, n)
    return host_walks(offv, adjv, starts, L, seed, n=n, walker_ids=wid)


# ---------------------------------------------------------------------------
# oracle parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scale,nb,edge_factor,seed,W,L", [
    (8, 1, 4, 0, 13, 6),        # single bucket (degenerate exchange)
    (9, 4, 4, 1, 64, 10),       # multi-bucket, generic
    (9, 4, 1, 2, 50, 12),       # sink-heavy: edge_factor 1 leaves deg-0 rows
    (10, 8, 2, 3, 33, 7),       # walkers not divisible by nb
])
def test_external_walks_match_host_oracle(tmp_path, scale, nb, edge_factor,
                                          seed, W, L):
    cfg = GraphConfig(scale=scale, nb=nb, chunk_edges=256,
                      edge_factor=edge_factor, shuffle_variant="external")
    offv, adjv = _external_graph(cfg, str(tmp_path))
    ref = _oracle(offv, adjv, cfg.n, W, L, seed)
    res = external_walks(cfg, str(tmp_path), num_walkers=W, length=L, seed=seed)
    assert res.walks.dtype == np.int64 == ref.dtype
    np.testing.assert_array_equal(np.asarray(res.walks), ref)


def test_external_walks_exercises_sink_teleport(tmp_path):
    """The sink-heavy config must actually hit the teleport branch — a walk
    leaving a deg-0 vertex can land anywhere, and both samplers must agree."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=1,
                      shuffle_variant="external")
    offv, adjv = _external_graph(cfg, str(tmp_path))
    deg = np.diff(offv)
    assert (deg == 0).any(), "config no longer produces sink vertices"
    W, L, seed = 40, 15, 7
    ref = _oracle(offv, adjv, cfg.n, W, L, seed)
    visited_sink = (deg[ref[:, :-1]] == 0)
    assert visited_sink.any(), "no walk ever visited a sink"
    res = external_walks(cfg, str(tmp_path), num_walkers=W, length=L, seed=seed)
    np.testing.assert_array_equal(np.asarray(res.walks), ref)


def test_external_walks_seed_sensitivity(tmp_path):
    cfg = GraphConfig(scale=8, nb=2, chunk_edges=256, edge_factor=4,
                      shuffle_variant="external")
    _external_graph(cfg, str(tmp_path))
    a = np.asarray(external_walks(cfg, str(tmp_path), num_walkers=16, length=8,
                                  seed=1, out_name="wa.npy").walks)
    b = np.asarray(external_walks(cfg, str(tmp_path), num_walkers=16, length=8,
                                  seed=2, out_name="wb.npy").walks)
    assert (a != b).any()


# ---------------------------------------------------------------------------
# bounded memory + sequential I/O
# ---------------------------------------------------------------------------


def test_external_walks_bounded_memory_and_sequential(tmp_path):
    """Peak resident rows are O(chunk_edges + walkers_per_bucket) — the bound
    has no n in it, and the measured peak at 4x the graph size is no larger
    than at 1x.  All walk I/O is sequential."""
    chunk, nb, W, L = 256, 4, 64, 8
    peaks = {}
    for scale in (10, 12):
        cfg = GraphConfig(scale=scale, nb=nb, chunk_edges=chunk, edge_factor=2,
                          shuffle_variant="external")
        d = str(tmp_path / f"s{scale}")
        _external_graph(cfg, d)
        gauge, ledger = MemoryGauge(), IOLedger()
        res = external_walks(cfg, d, num_walkers=W, length=L, seed=0,
                             ledger=ledger, gauge=gauge)
        assert res.walks.shape == (W, L + 1)
        wpb = -(-W // nb)
        assert gauge.peak_rows <= 4 * (chunk + wpb)
        assert gauge.peak_rows < cfg.n
        assert ledger.rand_reads == 0 == ledger.rand_writes
        peaks[scale] = gauge.peak_rows
    # independence of graph size: 4x the vertices, same working set
    assert peaks[12] <= peaks[10]


def test_walk_phase_ledger_deltas_sum_to_total(tmp_path):
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=256, edge_factor=2,
                      shuffle_variant="external")
    _external_graph(cfg, str(tmp_path))
    res = external_walks(cfg, str(tmp_path), num_walkers=20, length=5, seed=0)
    report = res.orchestrator.report()
    assert [r["phase"] for r in report][:2] == ["walk_init", "walk_hop_0000"]
    for field in ("seq_reads", "seq_writes", "bytes_read", "bytes_written"):
        assert sum(r[field] for r in report) == getattr(res.ledger, field)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


def test_external_walks_checkpoint_resume_mid_corpus(tmp_path):
    """Kill the pipeline inside hop 3, resume, and require the corpus to be
    byte-for-byte the uninterrupted one — hops before the crash replay from
    the checkpoint, the crashed hop reruns over its pre-cleaned stores."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=2,
                      shuffle_variant="external")
    kw = dict(num_walkers=23, length=6, seed=9, checkpoint=True)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    _external_graph(cfg, d1)
    _external_graph(cfg, d2)
    full = np.asarray(external_walks(cfg, d1, **kw).walks).copy()

    orig = _KERNELS["walk_hop"]

    def crashing_hop(pcfg, workdir, j, t, wcfg, **kws):
        if t == 3 and j == 2:
            raise RuntimeError("injected mid-walk crash")
        return orig(pcfg, workdir, j, t, wcfg, **kws)

    _KERNELS["walk_hop"] = crashing_hop
    try:
        with pytest.raises(RuntimeError, match="injected"):
            external_walks(cfg, d2, **kw)
    finally:
        _KERNELS["walk_hop"] = orig

    res = external_walks(cfg, d2, **kw)
    statuses = {r["phase"]: r["status"] for r in res.orchestrator.report()}
    for done_phase in ("walk_init", "walk_hop_0000", "walk_hop_0001",
                      "walk_hop_0002"):
        assert statuses[done_phase] == "resumed", statuses
    assert statuses["walk_hop_0003"] == "done", statuses
    np.testing.assert_array_equal(np.asarray(res.walks), full)


def test_external_walks_checkpoint_invalidated_on_walk_config_change(tmp_path):
    """A walk checkpoint taken under a different (seed, W, L) must not be
    resumed — and it must not disturb the GENERATOR's own checkpoint, which
    lives in a separate state file."""
    cfg = GraphConfig(scale=9, nb=2, chunk_edges=256, edge_factor=2,
                      shuffle_variant="external", checkpoint_phases=True)
    offv, adjv = _external_graph(cfg, str(tmp_path))
    external_walks(cfg, str(tmp_path), num_walkers=16, length=5, seed=1,
                   checkpoint=True)
    res = external_walks(cfg, str(tmp_path), num_walkers=16, length=5, seed=2,
                         checkpoint=True)
    assert all(r["status"] == "done" for r in res.orchestrator.report())
    np.testing.assert_array_equal(
        np.asarray(res.walks), _oracle(offv, adjv, cfg.n, 16, 5, 2))
    # the generator still resumes from its own phases.json
    g = StreamingGenerator(cfg, str(tmp_path))
    g.run()
    assert {r["phase"]: r["status"] for r in g.orchestrator.report()}[
        "shuffle"] == "resumed"


# ---------------------------------------------------------------------------
# partitioned mode
# ---------------------------------------------------------------------------


def test_partitioned_walk_corpus_matches_oracle(tmp_path):
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=2,
                      shuffle_variant="external")
    part = PartitionedGenerator(cfg, str(tmp_path), max_workers=0)
    csr, _ = part.run()
    offv, adjv = concat_bucket_csr(csr)
    walks = np.asarray(part.walk_corpus(31, 9, seed=4))
    np.testing.assert_array_equal(walks, _oracle(offv, adjv, cfg.n, 31, 9, 4))


# ---------------------------------------------------------------------------
# loader
# ---------------------------------------------------------------------------


def test_external_walk_loader_matches_walk_loader(tmp_path):
    """Same CSR layout, same LoaderConfig => identical batches while the
    corpus covers the step range; beyond it the loader wraps (still pure)."""
    cfg = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=2,
                      shuffle_variant="external")
    offv, adjv = _external_graph(cfg, str(tmp_path))
    lcfg = LoaderConfig(batch_size=4, seq_len=12, vocab=64, seed=3)
    host_ld = WalkLoader(cfg, None, lcfg, host_csr=(offv, adjv))
    ext_ld = ExternalWalkLoader(cfg, str(tmp_path), lcfg, num_walkers=12,
                                checkpoint=False)
    for step in range(3):                       # 3 * 4 == num_walkers
        a, b = host_ld.batch(step), ext_ld.batch(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))
    # wrap-around: step 3 re-serves walkers 0..3
    np.testing.assert_array_equal(np.asarray(ext_ld.batch(3)["tokens"]),
                                  np.asarray(ext_ld.batch(0)["tokens"]))


# Hypothesis property tests for the frontier sort->join->partition round
# trips live in tests/test_walks_property.py (module-level importorskip —
# keeping them separate means THIS module still runs without hypothesis).
