"""Skew-aware shard map: migration resume, stale-route fencing, and the
rebalanced / elastic cluster acceptance scenarios.

The contract under test: the ShardMap changes *where* bytes live, never
*what* they are — a rebalanced (or elastically grown) cluster run is
bit-identical to the static map; a mid-migration kill resumes without
re-sending completed files; frames routed under a stale map are refused.
"""

import hashlib
import json
import os

import numpy as np
import pytest

import repro
from repro.core.blockstore import BlockStore, IOLedger, split_counter_key
from repro.core.cluster import (
    ClusterGenerator,
    ClusterSpec,
    LocalExecBackend,
    bucket_file_relpaths,
    migrate_bucket_files,
)
from repro.core.corpus import ShardedWalks, shard_name
from repro.core.phases import PartitionedGenerator, PhaseOrchestrator
from repro.core.shardmap import ShardMap, plan_rebalance
from repro.core.transport import (
    ExchangeServer,
    SocketTransport,
    TransportError,
    store_bucket,
)
from repro.core.types import GraphConfig

_SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
_ENV = {"PYTHONPATH": _SRC}

CFG = GraphConfig(scale=9, nb=4, chunk_edges=256, edge_factor=4,
                  shuffle_variant="external")
W, L, WSEED = 17, 5, 3

# Synthetic per-bucket load profile forcing a deterministic plan on the
# contiguous 2-host split of nb=4 (host0 owns {0,1}, host1 owns {2,3}):
# bucket 0 dominates, so the greedy planner ships it to the cold host and
# backfills the cold buckets the other way — the straggler host ends up
# holding only the cold remainder.
SKEW_LOADS = {0: 1 << 30, 1: 1 << 24, 2: 1 << 20, 3: 1 << 20}


def _csr_sha(csr):
    h = hashlib.sha256()
    for o, a in csr:
        h.update(np.asarray(o).tobytes())
        h.update(np.asarray(a).tobytes())
    return h.hexdigest()


@pytest.fixture(scope="module")
def single_host_ref(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("ref"))
    with PartitionedGenerator(CFG, d, max_workers=0) as part:
        csr, _ = part.run()
        walks = np.asarray(part.walk_corpus(W, L, seed=WSEED)).copy()
        sha = _csr_sha(csr)
    return {"workdir": d, "csr_sha": sha, "walks": walks}


# ---------------------------------------------------------------------------
# IOLedger per-bucket counters (the rebalancer's skew signal)
# ---------------------------------------------------------------------------


def test_ledger_bucket_counters_flatten_and_merge():
    a = IOLedger()
    a.bucket(3, 100, rows=10)
    a.bucket(3, 50, rows=5)
    a.bucket(0, 7, rows=1)
    d = a.as_dict()
    assert d["bucket_bytes[0]"] == 7 and d["bucket_bytes[3]"] == 150
    assert d["bucket_rows[3]"] == 15
    b = IOLedger()
    b.write(9)
    b.merge(d)
    assert b.bucket_bytes == {0: 7, 3: 150}
    assert b.bucket_rows == {0: 1, 3: 15}
    assert b.bytes_written == 9
    # flattened keys parse back; plain keys pass through
    assert split_counter_key("bucket_bytes[12]") == ("bucket_bytes", 12)
    assert split_counter_key("bytes_read") == ("bytes_read", None)
    # unknown keys are ignored (forward compatibility), not an error
    b.merge({"not_a_counter": 5, "bucket_bytes[1]": 1})
    assert b.bucket_bytes[1] == 1


def test_blockstore_names_carry_bucket_attribution(tmp_path):
    assert store_bucket("owned_b003_sorted") == 3
    assert store_bucket("rl2_b000") == 0
    assert store_bucket("walks_b012.npy") == 12
    assert store_bucket("graph_manifest.json") is None
    ledger = IOLedger()
    st = BlockStore(str(tmp_path), "edges_b001", ledger)
    st.append_run(np.arange(4), np.arange(4))
    assert ledger.rows_written == 4


# ---------------------------------------------------------------------------
# migration: file discovery + resumable micro-phases
# ---------------------------------------------------------------------------


def _seed_host_workdir(workdir):
    """A host workdir shaped like a real run: bucket stores at top level,
    a namespaced job subdir, CSR files, a corpus shard, and distractors."""
    os.makedirs(workdir, exist_ok=True)
    ledger = IOLedger()
    st = BlockStore(workdir, "owned_b001", ledger)
    st.append_run(np.arange(8), np.arange(8) + 1)
    st.append_run(np.arange(3), np.arange(3) * 2)
    os.makedirs(os.path.join(workdir, "jobA"), exist_ok=True)
    st2 = BlockStore(os.path.join(workdir, "jobA"), "rl0_b001", ledger,
                     columns=("v",))
    st2.append_run(np.arange(5))
    np.save(os.path.join(workdir, "csr_offv_001.npy"), np.arange(6))
    np.save(os.path.join(workdir, "csr_adjv_001.npy"), np.arange(9))
    np.save(os.path.join(workdir, shard_name("walks.npy", 1)),
            np.arange(12).reshape(3, 4))
    # distractors that must NOT migrate with bucket 1
    st3 = BlockStore(workdir, "owned_b000", ledger)
    st3.append_run(np.arange(2), np.arange(2))
    np.save(os.path.join(workdir, "csr_offv_000.npy"), np.arange(2))
    with open(os.path.join(workdir, "host_phases.json"), "w") as f:
        json.dump({}, f)


def test_bucket_file_relpaths_spans_namespaces_and_csr(tmp_path):
    wd = str(tmp_path)
    _seed_host_workdir(wd)
    rels = bucket_file_relpaths(wd, 1)
    assert "csr_offv_001.npy" in rels and "csr_adjv_001.npy" in rels
    assert shard_name("walks.npy", 1) in rels
    assert sum(r.startswith("owned_b001/") for r in rels) == 2
    assert sum(r.startswith("jobA/rl0_b001/") for r in rels) == 1
    # bucket 0's store and CSR file stay put; checkpoint state never moves
    assert not any("b000" in r.split("/")[0] or r.startswith("csr_offv_000")
                   for r in rels)
    assert not any(r.endswith(".json") for r in rels)


def test_migrate_resumes_without_resending_completed_files(tmp_path):
    """The acceptance criterion, file-granular: kill the migration after N
    files, resume, and the completed files are never re-sent."""
    src_dir, dst_dir = str(tmp_path / "src"), str(tmp_path / "dst")
    _seed_host_workdir(src_dir)
    os.makedirs(dst_dir, exist_ok=True)
    all_rels = bucket_file_relpaths(src_dir, 1)
    originals = {}
    for rel in all_rels:
        with open(os.path.join(src_dir, *rel.split("/")), "rb") as f:
            originals[rel] = f.read()
    srv = ExchangeServer(dst_dir)
    try:
        class _Dies(SocketTransport):
            budget = 2

            def send_file(self, addr, src_path, rel_path, **kw):
                if _Dies.budget <= 0:
                    raise TransportError("injected mid-migration crash")
                _Dies.budget -= 1
                return super().send_file(addr, src_path, rel_path, **kw)

        tr = _Dies(src_dir, IOLedger(), peers=(srv.addr,))
        orch = PhaseOrchestrator(src_dir, IOLedger(), checkpoint=True,
                                 state_name="host_phases.json")
        with pytest.raises(TransportError, match="injected"):
            migrate_bucket_files(src_dir, 1, srv.addr, tr, orch=orch,
                                 key="mig:1:0")
        tr.close()
        done = [r for r in all_rels
                if not os.path.exists(os.path.join(src_dir, *r.split("/")))]
        assert len(done) == 2    # sent+unlinked before the injected crash

        # resume: fresh transport + fresh orchestrator (state reloads)
        sent_rels = []

        class _Records(SocketTransport):
            def send_file(self, addr, src_path, rel_path, **kw):
                sent_rels.append(rel_path)
                return super().send_file(addr, src_path, rel_path, **kw)

        tr2 = _Records(src_dir, IOLedger(), peers=(srv.addr,))
        orch2 = PhaseOrchestrator(src_dir, IOLedger(), checkpoint=True,
                                  state_name="host_phases.json")
        out = migrate_bucket_files(src_dir, 1, srv.addr, tr2, orch=orch2,
                                   key="mig:1:0")
        tr2.close()
        assert set(sent_rels) == set(all_rels) - set(done)
        assert out["files"] == len(all_rels) - len(done)
    finally:
        srv.stop()
    # destination holds every file of bucket 1, bit-identical
    for rel, blob in originals.items():
        with open(os.path.join(dst_dir, *rel.split("/")), "rb") as f:
            assert f.read() == blob, rel
        assert not os.path.exists(os.path.join(src_dir, *rel.split("/")))
    # emptied bucket-1 store dirs are gone; bucket 0 data untouched
    assert not os.path.exists(os.path.join(src_dir, "owned_b001"))
    assert os.path.exists(os.path.join(src_dir, "owned_b000"))
    assert os.path.exists(os.path.join(src_dir, "csr_offv_000.npy"))


# ---------------------------------------------------------------------------
# stale-route fencing
# ---------------------------------------------------------------------------


def test_stale_routed_frames_refused(tmp_path):
    srv = ExchangeServer(str(tmp_path / "recv"))
    os.makedirs(str(tmp_path / "send"), exist_ok=True)
    np.save(str(tmp_path / "send" / "csr_offv_001.npy"), np.arange(4))
    try:
        srv.set_min_map_version(2)
        # versioned sender below the ratchet: DATA and MIGRATE both refused
        old = SocketTransport(str(tmp_path / "send"), IOLedger(),
                              peers=(srv.addr,), map_version=1)
        with pytest.raises(TransportError, match="stale shard-map route"):
            old.channel(0, "edges_b000").append_run(np.arange(2), np.arange(2))
        old.close()
        old2 = SocketTransport(str(tmp_path / "send"), IOLedger(),
                               peers=(srv.addr,), map_version=1)
        with pytest.raises(TransportError, match="stale shard-map route"):
            old2.send_file(srv.addr,
                           str(tmp_path / "send" / "csr_offv_001.npy"),
                           "csr_offv_001.npy")
        old2.close()
        # current-version sender passes; unversioned (legacy) sender passes
        cur = SocketTransport(str(tmp_path / "send"), IOLedger(),
                              peers=(srv.addr,), map_version=2)
        cur.channel(0, "edges_b000").append_run(np.arange(2), np.arange(2))
        cur.close()
        legacy = SocketTransport(str(tmp_path / "send"), IOLedger(),
                                 peers=(srv.addr,))
        legacy.channel(0, "edges_b000").append_run(np.arange(2), np.arange(2))
        legacy.close()
        # the ratchet is monotone: it never lowers
        srv.set_min_map_version(1)
        assert srv.min_map_version == 2
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# acceptance: rebalanced 2-host parity, elastic admission, kill-resume
# ---------------------------------------------------------------------------


def _rebalanced_cluster(tmp_path, name, backend=None, **kw):
    spec = ClusterSpec.local(2, str(tmp_path / name), nb=CFG.nb)
    gen = ClusterGenerator(
        CFG.with_(transport="socket"), spec, str(tmp_path / name / "ctrl"),
        backend=backend if backend is not None else LocalExecBackend(env=_ENV),
        checkpoint=True, rebalance=True, **kw)
    # Deterministic skew baseline: the run's own accounting adds on top, but
    # this dominates, so the first barrier's plan is known in advance.
    gen.controller.bucket_loads.update(SKEW_LOADS)
    return spec, gen


@pytest.mark.slow
def test_rebalanced_run_bit_identical_and_serves_from_new_owner(
        tmp_path, single_host_ref):
    spec, gen = _rebalanced_cluster(tmp_path, "rb")
    try:
        manifest_path, _ = gen.run()
        ctl = gen.controller
        assert ctl.shard_map.version > 0, "rebalance never committed"
        moved = [b for b in range(CFG.nb)
                 if ctl.owner_of(b) != spec.owner_of(b)]
        assert moved, "skew profile should force at least one move"
        # parity: bit-identical CSR + corpus vs the single-host oracle
        walks = gen.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks),
                                      single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
        # the manifest names the LIVE owner, whose workdir holds the files
        m = json.load(open(manifest_path))
        for entry in m["buckets"]:
            assert entry["host"] == ctl.owner_of(entry["bucket"])
            assert os.path.exists(os.path.join(entry["workdir"],
                                               entry["offv"]))
        # a moved bucket's walk shard lives ONLY on its new owner
        for b in moved:
            new_dir = spec.hosts[ctl.owner_of(b)].workdir
            old_dir = spec.hosts[spec.owner_of(b)].workdir
            assert os.path.exists(os.path.join(new_dir,
                                               shard_name("walks.npy", b)))
            assert not os.path.exists(os.path.join(old_dir,
                                                   shard_name("walks.npy", b)))
        # the migration actually ran as dispatched tasks
        assert any(e["key"].startswith("rebalance[") and e["ok"]
                   for e in ctl.task_log), [e["key"] for e in ctl.task_log][:8]
        np.testing.assert_array_equal(
            np.asarray(ShardedWalks(walks.manifest_path)),
            single_host_ref["walks"])
    finally:
        gen.close()


@pytest.mark.slow
def test_admitted_host_receives_shards_and_serves_phases(tmp_path,
                                                         single_host_ref):
    """Elastic admission: a third host joins after rendezvous, the next
    barrier's rebalance fills it (empty hosts attract moves), and it serves
    CSR + walk phases — output still bit-identical."""
    name = "adm"
    spec = ClusterSpec.local(2, str(tmp_path / name), nb=CFG.nb)
    gen = ClusterGenerator(
        CFG.with_(transport="socket"), spec, str(tmp_path / name / "ctrl"),
        backend=LocalExecBackend(env=_ENV), checkpoint=True, rebalance=True)
    try:
        ctl = gen.controller
        hid = ctl.admit_host(str(tmp_path / name / "host2"))
        assert hid == 2
        ctl.wait_for_hosts(timeout=60.0)
        # balanced-looking load on hosts 0/1 + an empty host 2: the greedy
        # planner's dst tie-break (highest id) fills the late joiner first
        ctl.bucket_loads.update({0: 1 << 26, 1: 1 << 25,
                                 2: 1 << 25, 3: 1 << 26})
        gen.run()
        assert ctl.spec.num_hosts == 3
        owners = {ctl.owner_of(b) for b in range(CFG.nb)}
        assert 2 in owners, "admitted host never received a shard"
        walks = gen.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks),
                                      single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
        # host 2 did real work after admission
        assert any(e["host"] == 2 and e["ok"] for e in ctl.task_log)
        moved_to_2 = [b for b in range(CFG.nb) if ctl.owner_of(b) == 2]
        for b in moved_to_2:
            assert os.path.exists(os.path.join(
                str(tmp_path / name / "host2"), shard_name("walks.npy", b)))
    finally:
        gen.close()


class _KillHost0First(LocalExecBackend):
    """Host 0 (the migration SOURCE under SKEW_LOADS) dies hard partway
    through its first launch — including, with the task budget below, inside
    the rebalance window."""

    def __init__(self, max_tasks):
        super().__init__(env=_ENV)
        self.max_tasks = max_tasks

    def host_args(self, host, attempt):
        if host.host_id == 0 and attempt == 0:
            return ["--max-tasks", str(self.max_tasks)]
        return []


@pytest.mark.slow
def test_rebalanced_run_survives_host_kill(tmp_path, single_host_ref):
    """Kill the migration-source host mid-run (restart budget 1): the
    controller revives it, the host's checkpointed micro-phases skip every
    file already acked, and the rebalanced output stays bit-identical."""
    spec, gen = _rebalanced_cluster(tmp_path, "kr",
                                    backend=_KillHost0First(max_tasks=6),
                                    max_restarts=1)
    try:
        gen.run()
        assert gen.controller.restarts[0] == 1, gen.controller.restarts
        assert gen.controller.shard_map.version > 0
        walks = gen.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks),
                                      single_host_ref["walks"])
        assert _csr_sha(gen.load_csr()) == single_host_ref["csr_sha"]
    finally:
        gen.close()


@pytest.mark.slow
def test_committed_rebalance_restores_on_controller_relaunch(
        tmp_path, single_host_ref):
    """Controller relaunch AFTER a committed rebalance: the fresh controller
    seeds the contiguous map, but the checkpointed commit phase restores the
    moved ownership before any later phase routes — the resumed run replays
    from checkpoints and stays bit-identical."""
    spec, gen = _rebalanced_cluster(tmp_path, "cr")
    try:
        gen.run()
        committed = gen.controller.shard_map.to_json()
        assert committed["version"] > 0
    finally:
        gen.close()
    # relaunch WITHOUT the rebalance flag: restore must not depend on it
    gen2 = ClusterGenerator(
        CFG.with_(transport="socket"), spec, str(tmp_path / "cr" / "ctrl"),
        backend=LocalExecBackend(env=_ENV), checkpoint=True)
    try:
        gen2.run()
        assert gen2.controller.shard_map.owners == committed["owners"]
        assert gen2.controller.shard_map.version >= committed["version"]
        walks = gen2.walk_corpus(W, L, seed=WSEED)
        np.testing.assert_array_equal(np.asarray(walks),
                                      single_host_ref["walks"])
        assert _csr_sha(gen2.load_csr()) == single_host_ref["csr_sha"]
    finally:
        gen2.close()


# ---------------------------------------------------------------------------
# planner sanity (the hypothesis laws live in test_cluster_property.py)
# ---------------------------------------------------------------------------


def test_plan_rebalance_offloads_straggler_deterministically():
    smap = ShardMap.contiguous(4, 2)
    moves = plan_rebalance(smap, SKEW_LOADS)
    # hot bucket to the cold host, cold buckets backfill the other way —
    # and each bucket moves AT MOST once per plan (one barrier dispatch)
    assert moves == [(0, 0, 1), (2, 1, 0), (3, 1, 0)]
    assert len({b for b, _, _ in moves}) == len(moves)
    assert plan_rebalance(smap, SKEW_LOADS) == moves   # pure function
    # the plan strictly shrinks the load spread
    owner = list(smap.owners)
    for b, _, d in moves:
        owner[b] = d
    def spread(ow):
        hl = [0, 0]
        for b, v in SKEW_LOADS.items():
            hl[ow[b]] += v
        return max(hl) - min(hl)
    assert spread(owner) < spread(smap.owners)
    # an admitted empty host attracts the move instead (dst tie-break)
    smap3 = ShardMap.contiguous(4, 2)
    smap3.admit_host()
    assert all(dst == 2 for _, _, dst in plan_rebalance(smap3, SKEW_LOADS))
    # no loads, no moves; single host, no moves
    assert plan_rebalance(smap, {}) == []
    assert plan_rebalance(ShardMap.contiguous(4, 1), SKEW_LOADS) == []
