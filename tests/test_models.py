"""Per-architecture smoke tests (reduced same-family configs, CPU) + serving
consistency: prefill+decode must reproduce the train-path forward logits."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, arch_ids, get_config, get_smoke_config
from repro.models.registry import get_model, init_all, input_specs

SMALL = dataclasses.replace(SHAPES["train_4k"], seq_len=24, global_batch=2)


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params, _ = init_all(cfg)
    batch = input_specs(cfg, SMALL, mode="init")
    logits, aux = api.forward(cfg, params, batch)
    assert logits.shape[0] == SMALL.global_batch
    assert logits.shape[1] == SMALL.seq_len
    assert logits.shape[2] == cfg.vocab_padded
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux["lb_loss"]))


@pytest.mark.parametrize("arch", arch_ids())
def test_prefill_decode_matches_forward(arch):
    """Serving path correctness: running prefill(t[:-1]) then decode(t[-1])
    must produce the same last-token logits as forward(t) — cache write
    indices, RoPE offsets and masks all have to line up for this to hold."""
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params, _ = init_all(cfg)
    B, S = 2, 24   # > llava's 16 image tokens so vlm text length stays positive
    rng = np.random.default_rng(0)
    batch = input_specs(cfg, dataclasses.replace(SMALL, seq_len=S), mode="init")
    logits_full, _ = api.forward(cfg, params, batch)

    cache = api.init_cache(cfg, B, 32)
    pre = {k: (v[:, :-1] if k == "tokens" else v) for k, v in batch.items()
           if k != "labels"}
    _, cache = api.prefill(cfg, params, pre, cache)
    last = batch["tokens"][:, -1:]
    logits_dec, _ = api.decode_step(cfg, params, last, cache)

    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", arch_ids())
def test_full_config_matches_assignment(arch):
    """The FULL configs must carry the exact published numbers."""
    spec = {
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64, family="hybrid"),
        "minitron-8b": dict(num_layers=32, d_model=4096, num_heads=32,
                            num_kv_heads=8, d_ff=16384, vocab_size=256000,
                            family="dense"),
        "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064,
                            qkv_bias=True, family="dense"),
        "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=32, d_ff=13440, vocab_size=92416,
                               family="dense"),
        "internlm2-1.8b": dict(num_layers=24, d_model=2048, num_heads=16,
                               num_kv_heads=8, d_ff=8192, vocab_size=92544,
                               family="dense"),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096, num_heads=64,
                                    num_kv_heads=4, moe_d_ff=1536,
                                    vocab_size=151936, num_experts=128,
                                    experts_per_tok=8, family="moe"),
        "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048, num_heads=16,
                                     num_kv_heads=16, moe_d_ff=1408,
                                     vocab_size=102400, num_experts=64,
                                     experts_per_tok=6, kv_lora_rank=512,
                                     num_shared_experts=2, family="moe"),
        "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024, num_heads=16,
                                      num_kv_heads=16, d_ff=8192,
                                      vocab_size=256206, family="encdec"),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                                      num_kv_heads=8, d_ff=14336,
                                      vocab_size=32000, family="vlm"),
        "mamba2-780m": dict(num_layers=48, d_model=1536, d_ff=0,
                            vocab_size=50280, ssm_state=128, family="ssm"),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_smoke_configs_are_reduced():
    for arch in arch_ids():
        full, smoke = get_config(arch), get_smoke_config(arch)
        assert smoke.family == full.family
        assert smoke.num_layers < full.num_layers
        assert smoke.d_model < full.d_model
        assert smoke.vocab_size < full.vocab_size


def test_moe_dense_vs_smoke_balance():
    """MoE smoke: router aux losses behave (lb_loss near 1 for uniform-ish)."""
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    api = get_model(cfg)
    params, _ = init_all(cfg)
    batch = input_specs(cfg, SMALL, mode="init")
    _, aux = api.forward(cfg, params, batch)
    assert 0.5 < float(aux["lb_loss"]) / cfg.num_layers < 4.0


def test_mamba2_decode_state_is_o1():
    """SSM cache size must not depend on max_len."""
    cfg = get_smoke_config("mamba2-780m")
    api = get_model(cfg)
    c1 = api.init_cache(cfg, 2, 64, mode="shape")
    c2 = api.init_cache(cfg, 2, 4096, mode="shape")
    s1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
    s2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
    assert s1 == s2


def test_vocab_padding_masked():
    """Padded logit columns must be -inf-like so they never win sampling."""
    cfg = get_smoke_config("seamless-m4t-large-v2").with_(vocab_size=250)
    assert cfg.vocab_padded == 256
    api = get_model(cfg)
    params, _ = init_all(cfg)
    batch = input_specs(cfg, dataclasses.replace(SMALL, seq_len=8), mode="init")
    logits, _ = api.forward(cfg, params, batch)
    pad_cols = np.asarray(logits[..., 250:], np.float32)
    assert (pad_cols <= -1e8).all()
