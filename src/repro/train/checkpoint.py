"""Fault-tolerant sharded checkpointing.

Protocol (per checkpoint step):
  1. write every leaf to   <dir>/tmp.step_<N>/<leaf>.npy
  2. write manifest.json   (leaf names, shapes, dtypes, step, framework rev)
  3. fsync + atomic rename tmp.step_<N> -> step_<N>

A reader only trusts directories with a valid manifest whose listed files all
exist with the right shapes — a crash mid-save leaves a tmp.* directory that
is ignored and GC'd, never a half-trusted checkpoint (the paper-era
equivalent: torn writes to the SSD edgelist).  keep=k older checkpoints are
retained for corrupt-latest fallback.

Elastic re-mesh: leaves are stored as *logical* (unsharded) arrays, so
restore(..., shardings=...) can lay the same state onto ANY mesh — grow or
shrink the cluster between runs (restore_resharded below, tested in
tests/test_fault.py).

Async: save(..., blocking=False) snapshots to host (device_get) then writes
on a daemon thread — training continues during the disk I/O, the classic
checkpoint/compute overlap.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return ".".join(parts) or "root"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [_leaf_name(p) for p, _ in flat]
    assert len(set(names)) == len(names), "leaf name collision"
    return names, [l for _, l in flat], treedef


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3,
         blocking: bool = True, extra: Optional[Dict] = None) -> str:
    """Write checkpoint for `step`.  Returns the final directory path."""
    names, leaves, _ = _flatten(state)
    # snapshot to host before returning (async-safe: device buffers may be
    # donated/overwritten by the next step)
    host = [np.asarray(jax.device_get(l)) for l in leaves]

    def _write():
        tmp = os.path.join(ckpt_dir, f"tmp.step_{step:08d}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": {},
                    "extra": extra or {}}
        for name, arr in zip(names, host):
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {"shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)
        return final

    if blocking:
        return _write()
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    save._last_thread = t  # tests join() this
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def wait_for_async_saves():
    t = getattr(save, "_last_thread", None)
    if t is not None:
        t.join()


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    # stale tmp dirs from crashed saves
    for d in os.listdir(ckpt_dir):
        if d.startswith("tmp.step_"):
            full = os.path.join(ckpt_dir, d)
            if time.time() - os.path.getmtime(full) > 60:
                shutil.rmtree(full, ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.isfile(os.path.join(ckpt_dir, d, MANIFEST)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def _valid(ckpt_dir: str, step: int) -> bool:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        for name, meta in manifest["leaves"].items():
            p = os.path.join(d, name + ".npy")
            if not os.path.isfile(p):
                return False
            arr = np.load(p, mmap_mode="r")
            if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
                return False
        return True
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose manifest fully validates (corrupt-latest fallback)."""
    for s in reversed(all_steps(ckpt_dir)):
        if _valid(ckpt_dir, s):
            return s
    return None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Load checkpoint `step` into the structure of `like`.

    shardings: optional pytree (congruent with `like`) of NamedShardings —
    pass the CURRENT mesh's shardings to re-shard onto a different topology
    than the one that saved (elastic re-mesh restore).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    names, like_leaves, treedef = _flatten(like)
    sh_leaves = (treedef.flatten_up_to(shardings) if shardings is not None
                 else [None] * len(names))
    leaves = []
    for name, ref_leaf, sh in zip(names, like_leaves, sh_leaves):
        arr = np.load(os.path.join(d, name + ".npy"))
        assert arr.shape == tuple(ref_leaf.shape), (name, arr.shape, ref_leaf.shape)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=ref_leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str, like: Any, shardings: Optional[Any] = None):
    """(state, step) from the newest valid checkpoint, or (None, None)."""
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None
    return restore(ckpt_dir, s, like, shardings), s
