"""Gradient compression for cross-pod reduction.

Two compressors, both with error feedback (EF — the residual of each step's
compression is added back before the next step's, so compression error does
not accumulate as bias; Karimireddy et al. 2019):

  int8   per-tensor symmetric quantization (4x traffic vs fp32 / 2x vs bf16)
  topk   keep the largest-|g| fraction per tensor, send (values, indices)

Placement: on real multi-pod hardware the expensive hop is the cross-pod DCN
all-reduce; `podwise_psum` in launch/train.py wraps the train step in
shard_map over the "pod" axis (auto over data/model), quantizing before the
pod psum.  On the CPU dry-run the same code path lowers — the roofline
collective-bytes delta (§Perf) is how we demonstrate the win.  When applied
*inside* a fully-auto jit step (`compressed_grads`), it faithfully simulates
the numerics (EF included) so convergence effects can be tested anywhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.collectives import axis_size


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"        # "none" | "int8" | "topk"
    topk_frac: float = 0.01   # fraction of entries kept by "topk"
    ef: bool = True           # error feedback on/off


# ---------------------------------------------------------------------------
# per-leaf codecs
# ---------------------------------------------------------------------------


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g -> (int8 codes, fp32 scale). scale = max|g|/127, per tensor."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_mask(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Boolean mask of the largest-|g| `frac` of entries (>=1 entry)."""
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.abs(g) >= thresh


# ---------------------------------------------------------------------------
# pytree-level API with error feedback
# ---------------------------------------------------------------------------


def compress_state_init(cfg: Optional[CompressionConfig], params, mode: str = "init"):
    """EF residual buffers (zeros, param-shaped fp32).  Empty tuple if off."""
    if cfg is None or cfg.kind == "none" or not cfg.ef:
        return ()
    if mode == "shape":
        return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _codec_roundtrip(cfg: CompressionConfig, g: jnp.ndarray) -> jnp.ndarray:
    if cfg.kind == "int8":
        q, s = quantize_int8(g)
        return dequantize_int8(q, s)
    if cfg.kind == "topk":
        return g * topk_mask(g, cfg.topk_frac)
    raise ValueError(cfg.kind)


def compressed_grads(cfg: CompressionConfig, grads, ef_state):
    """Apply codec (+EF) leaf-wise.  Returns (decoded grads, new EF state)."""
    if cfg.kind == "none":
        return grads, ef_state

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + (e if cfg.ef else 0.0)
        dec = _codec_roundtrip(cfg, g32)
        new_e = (g32 - dec) if cfg.ef else e
        return dec, new_e

    if not ef_state:
        dec = jax.tree.map(lambda g: _codec_roundtrip(cfg, g.astype(jnp.float32)), grads)
        return dec, ef_state
    out = jax.tree.map(leaf, grads, ef_state)
    dec = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return dec, new_ef


# ---------------------------------------------------------------------------
# pod-axis compressed psum (used under shard_map over "pod")
# ---------------------------------------------------------------------------


def podwise_psum_int8(grads, axis: str = "pod"):
    """Mean over `axis` in int8: agree on a GLOBAL per-tensor scale with one
    scalar pmax, quantize against it, psum the codes (int32: no overflow up
    to 127*npods), dequantize once.  Per-element error is bounded by half a
    quantum regardless of how pod gradients differ.

    4x cheaper on the wire than fp32 (the extra pmax is one scalar per
    tensor).  Must run inside shard_map over `axis`.
    """
    def leaf(g):
        g = g.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
        qsum = jax.lax.psum(q, axis)
        npods = axis_size(axis)
        return qsum.astype(jnp.float32) * scale / npods

    return jax.tree.map(leaf, grads)
