"""Fault tolerance + straggler mitigation for the training runtime.

Mechanisms (each unit-tested with simulated failures, tests/test_fault.py):

  * run_with_restarts  — supervisor loop: run the train function; on any
    WorkerFailure (or crash exception from user code), restore the newest
    valid checkpoint and continue.  Survives corrupt-latest checkpoints
    (falls back one step) and mid-save crashes (tmp dirs never trusted).
  * HeartbeatMonitor   — per-worker heartbeat timestamps; workers silent for
    > timeout are declared dead; on death the caller re-meshes (elastic) via
    checkpoint.restore(..., shardings on the smaller mesh).
  * StragglerPolicy    — per-step worker timings -> microbatch reassignment
    plan: workers slower than `slow_factor` x median shed microbatches to the
    fastest workers (the grad-accum loop consumes the plan; data order is
    deterministic because assignment is a pure function of the timing vector).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from . import checkpoint as ckpt_lib


class WorkerFailure(RuntimeError):
    """Raised (or injected by tests) when a worker dies mid-step."""


# ---------------------------------------------------------------------------
# Restart supervision
# ---------------------------------------------------------------------------


def run_with_restarts(
    train_fn: Callable[[object, int], object],
    *,
    ckpt_dir: str,
    init_state,
    total_steps: int,
    save_every: int,
    max_restarts: int = 10,
    shardings=None,
    keep: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
):
    """Run `state = train_fn(state, step)` for steps [resume..total_steps).

    Checkpoints every `save_every`; on failure restores the newest valid
    checkpoint and retries from its step.  Returns the final state.
    """
    state, start = ckpt_lib.restore_latest(ckpt_dir, init_state, shardings)
    if state is None:
        state, start = init_state, -1
    step = start + 1
    restarts = 0
    while step < total_steps:
        try:
            state = train_fn(state, step)
            if (step + 1) % save_every == 0 or step + 1 == total_steps:
                ckpt_lib.save(ckpt_dir, step, state, keep=keep)
            step += 1
        except WorkerFailure as e:  # pragma: no cover - exercised via tests
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(step, e)
            state, last = ckpt_lib.restore_latest(ckpt_dir, init_state, shardings)
            if state is None:
                state, last = init_state, -1
            step = last + 1
    return state


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    """Tracks worker liveness from heartbeat timestamps.

    On a real cluster each worker posts heartbeats to shared storage / the
    coordinator; here it is an in-process registry with an injectable clock
    so tests can advance time deterministically.
    """

    def __init__(self, workers: Sequence[int], timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self._clock = clock
        self._last: Dict[int, float] = {w: clock() for w in workers}

    def beat(self, worker: int):
        self._last[worker] = self._clock()

    def dead(self) -> List[int]:
        now = self._clock()
        return [w for w, t in sorted(self._last.items()) if now - t > self.timeout]

    def alive(self) -> List[int]:
        now = self._clock()
        return [w for w, t in sorted(self._last.items()) if now - t <= self.timeout]


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    slow_factor: float = 1.5      # slower than this x median => straggler
    min_share: int = 1            # stragglers keep at least this many microbatches

    def plan(self, step_times: Sequence[float], microbatches: int) -> List[int]:
        """Per-worker microbatch counts for the NEXT step.

        Work is shifted from stragglers to the fastest workers proportionally
        to measured throughput (1/time); totals always sum to `microbatches`.
        """
        t = np.asarray(step_times, dtype=np.float64)
        n = len(t)
        assert microbatches >= n * self.min_share
        med = np.median(t)
        straggler = t > self.slow_factor * med
        if not straggler.any():
            base = microbatches // n
            plan = [base] * n
            for i in range(microbatches - base * n):
                plan[i] += 1
            return plan
        # throughput-proportional assignment, floor at min_share for stragglers
        speed = 1.0 / np.maximum(t, 1e-9)
        raw = speed / speed.sum() * microbatches
        plan = np.maximum(np.floor(raw).astype(int), self.min_share)
        # fix the total: give leftovers to the fastest, take from the slowest
        order_fast = list(np.argsort(t))
        i = 0
        while plan.sum() < microbatches:
            plan[order_fast[i % n]] += 1
            i += 1
        order_slow = order_fast[::-1]
        i = 0
        while plan.sum() > microbatches:
            w = order_slow[i % n]
            if plan[w] > self.min_share:
                plan[w] -= 1
            i += 1
        return [int(x) for x in plan]
