from . import checkpoint, compression, fault, optim, step  # noqa: F401
from .optim import OptimConfig, OptState  # noqa: F401
from .step import TrainState, init_state, make_train_step, state_shardings  # noqa: F401
