"""Optimizer: AdamW with decoupled weight decay, global-norm clipping,
warmup+cosine schedule, and optional fp32 master weights for bf16 params.

Self-contained (no optax): state is a pytree congruent with params, so the
FSDP/TP sharding of every parameter is inherited leaf-by-leaf by its Adam
moments (and master copy) — exactly how ZeRO shards optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_fp32: bool = True   # keep fp32 master copy when params are low-precision
    moments_dtype: str = "float32"   # "bfloat16" halves mu/nu memory (8-bit-Adam-lite)
    schedule: str = "warmup_cosine"  # "warmup_cosine" | "constant"

    @property
    def jmoments(self):
        return jnp.dtype(self.moments_dtype)


class OptState(NamedTuple):
    mu: Any            # first moment, fp32, congruent with params
    nu: Any            # second moment, fp32
    master: Any        # fp32 master copy (or None-leaves when disabled)
    count: jnp.ndarray # int32 step counter


def _f32(x):
    return x.astype(jnp.float32)


def schedule(cfg: OptimConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Learning rate at `step` (traced-friendly)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    # cosine decay from lr to lr*min_lr_ratio over the post-warmup span
    span = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / span, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decayed


def init(cfg: OptimConfig, params) -> OptState:
    mdt = cfg.jmoments
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    if cfg.master_fp32:
        master = jax.tree.map(_f32, params)
    else:
        master = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=master,
        count=jnp.zeros((), jnp.int32),
    )


def init_abstract(cfg: OptimConfig, params) -> OptState:
    """ShapeDtypeStruct mirror of init() — used by the dry-run (no allocation)."""
    def z(p):
        return jax.ShapeDtypeStruct(p.shape, cfg.jmoments)

    master = (jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
              if cfg.master_fp32
              else jax.tree.map(lambda p: jax.ShapeDtypeStruct((), jnp.float32), params))
    return OptState(
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
        master=master,
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(_f32(g) ** 2) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


_NO_DECAY_SUBSTR = ("ln", "norm", "bias", "scale", "length")


def _decay_mask(path: Tuple) -> bool:
    s = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()
    return not any(t in s for t in _NO_DECAY_SUBSTR)


def apply_updates(cfg: OptimConfig, params, grads, state: OptState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12)) if cfg.clip_norm > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    mdt = cfg.jmoments

    def leaf(path, p, g, mu, nu, master):
        g = _f32(g) * clip
        mu = cfg.b1 * _f32(mu) + (1.0 - cfg.b1) * g
        nu = cfg.b2 * _f32(nu) + (1.0 - cfg.b2) * (g * g)
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        base = master if cfg.master_fp32 else _f32(p)
        if _decay_mask(path):
            update = update + cfg.weight_decay * base
        new_master = base - lr * update
        new_p = new_master.astype(p.dtype)
        new_master_out = new_master if cfg.master_fp32 else master
        return new_p, mu.astype(mdt), nu.astype(mdt), new_master_out

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    treedef = flat[1]
    ps = [l for _, l in flat[0]]
    gs = treedef.flatten_up_to(grads)
    mus = treedef.flatten_up_to(state.mu)
    nus = treedef.flatten_up_to(state.nu)
    masters = treedef.flatten_up_to(state.master)

    outs = [leaf(path, p, g, mu, nu, ma)
            for path, p, g, mu, nu, ma in zip(paths, ps, gs, mus, nus, masters)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    new_master = jax.tree_util.tree_unflatten(treedef, [o[3] for o in outs])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(new_mu, new_nu, new_master, count), metrics
