"""Train-step assembly: loss, gradient accumulation, sharded jit.

make_train_step() returns a jit'd (state, batch) -> (state, metrics) whose
in/out shardings are derived from the ParamFactory logical-axis specs +
distributed/sharding.py rules.  Gradient accumulation is a lax.scan over
microbatches (XLA overlaps each microbatch's reduce with the next one's
compute — the compute/comm-overlap trick), and the optional cross-pod
gradient compression hook (train/compression.py) runs between accumulation
and the optimizer.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import batch_shardings, param_shardings
from ..models.nn import DistContext, ParamFactory
from ..models.registry import ModelApi, get_model
from . import optim as optim_lib
from .compression import CompressionConfig, compress_state_init, compressed_grads


class TrainState(NamedTuple):
    params: Any
    opt: optim_lib.OptState
    comp: Any          # compression error-feedback state (possibly empty tuple)
    step: jnp.ndarray  # int32


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, ignore: int = -100):
    """Mean token cross-entropy; labels == `ignore` are masked out."""
    mask = (labels != ignore)
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels_safe[..., None], axis=-1
    )[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom, denom


def make_loss_fn(cfg: ModelConfig, api: Optional[ModelApi] = None,
                 lb_coef: float = 1e-2, z_coef: float = 0.0):
    api = api or get_model(cfg)

    def loss_fn(params, batch, dist: Optional[DistContext]):
        logits, aux = api.forward(cfg, params, batch, dist)
        xent, ntok = softmax_xent(logits, batch["labels"])
        loss = xent
        if cfg.num_experts:
            loss = loss + lb_coef * aux["lb_loss"]
        if z_coef:
            loss = loss + z_coef * aux["z_loss"]
        metrics = {"loss": xent, "ntok": ntok.astype(jnp.float32),
                   "lb_loss": aux["lb_loss"], "dropped": aux["dropped"]}
        return loss, metrics

    return loss_fn


# ---------------------------------------------------------------------------
# Step builder
# ---------------------------------------------------------------------------


def _split_microbatches(batch: Dict[str, jnp.ndarray], accum: int):
    def resh(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} % accum {accum} != 0"
        return x.reshape((accum, b // accum) + x.shape[1:])

    return jax.tree.map(resh, batch)


def make_train_step(
    cfg: ModelConfig,
    ocfg: optim_lib.OptimConfig,
    dist: Optional[DistContext] = None,
    *,
    accum_steps: int = 1,
    compression: Optional[CompressionConfig] = None,
    lb_coef: float = 1e-2,
) -> Callable:
    """(state, batch) -> (state, metrics).  Pure function of its inputs —
    jit it yourself (launch/dryrun.py and launch/train.py attach shardings)."""
    loss_fn = make_loss_fn(cfg, lb_coef=lb_coef)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if accum_steps == 1:
            (_, metrics), grads = grad_fn(state.params, batch, dist)
        else:
            micro = _split_microbatches(batch, accum_steps)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state.params)
            zero_m = {"loss": 0.0, "ntok": 0.0, "lb_loss": 0.0, "dropped": 0.0}
            zero_m = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), zero_m)

            def body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(state.params, mb, dist)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m / accum_steps, metrics)

        comp_state = state.comp
        if compression is not None and compression.kind != "none":
            grads, comp_state = compressed_grads(compression, grads, comp_state)

        params, opt, om = optim_lib.apply_updates(ocfg, state.params, grads, state.opt)
        metrics = dict(metrics, **om)
        return TrainState(params, opt, comp_state, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# State init + shardings (shared by launch/train.py and launch/dryrun.py)
# ---------------------------------------------------------------------------


def init_state(cfg: ModelConfig, ocfg: optim_lib.OptimConfig,
               mode: str = "init", seed: int = 0,
               compression: Optional[CompressionConfig] = None):
    """(state, factory). mode="shape" -> all-ShapeDtypeStruct state (dry-run)."""
    f = ParamFactory(mode=mode, key=jax.random.PRNGKey(seed),
                     dtype=cfg.jdtype)
    params = get_model(cfg).init_params(cfg, f)
    if mode == "shape":
        opt = optim_lib.init_abstract(ocfg, params)
        step = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        opt = optim_lib.init(ocfg, params)
        step = jnp.zeros((), jnp.int32)
    comp = compress_state_init(compression, params, mode=mode)
    return TrainState(params, opt, comp, step), f


def state_shardings(state: TrainState, factory: ParamFactory, dist: DistContext):
    """NamedShardings for a TrainState: params by their logical axes; Adam
    moments and master copy inherit the param sharding (ZeRO); scalars are
    replicated."""
    p_sh = param_shardings(factory.specs, state.params, dist)
    rep = NamedSharding(dist.mesh, P())

    def like_params(tree):
        return jax.tree.map(
            lambda leaf, sh: sh if leaf.ndim > 0 else rep, tree, p_sh)

    opt_sh = optim_lib.OptState(
        mu=like_params(state.opt.mu),
        nu=like_params(state.opt.nu),
        master=like_params(state.opt.master),
        count=rep,
    )
    comp_sh = jax.tree.map(
        lambda leaf: rep, state.comp) if state.comp else state.comp
    if state.comp:
        # error-feedback buffers are param-shaped: inherit param sharding
        try:
            comp_sh = like_params(state.comp)
        except ValueError:
            pass
    return TrainState(p_sh, opt_sh, comp_sh, rep)


def batch_sharding_tree(batch, dist: DistContext):
    return batch_shardings(batch, dist)
