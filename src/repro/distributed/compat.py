"""jax version-compat surface — every shim the repo needs, in one place.

Version floor: the repo runs on **jax >= 0.4.37** (the CPU container pins
jax 0.4.37 / jaxlib 0.4.36).  Three names this codebase leans on graduated
to public API only after that floor, so each gets a fallback here:

  shard_map   `jax.shard_map` exists from jax 0.4.38; on 0.4.37 the public
              entry point is still `jax.experimental.shard_map.shard_map`.
              Semantics are identical for everything this repo does (single
              named axis, explicit in/out specs).
  axis_size   `lax.axis_size(axis)` appeared alongside the new shard_map;
              the fallback `lax.psum(1, axis)` is the classic idiom — a
              literal psum is constant-folded to the axis size at trace
              time, so there is no runtime collective.
  pvary       `lax.pvary` belongs to the varying-type system newer
              shard_maps use to type cross-axis data flow.  Older
              shard_map has no such types, so identity is the correct
              (and only possible) fallback.

Import these names from here (or from `distributed.collectives`, which
re-exports them) — never from `jax` / `jax.lax` directly, so the
version-floor logic stays in exactly one module.  When the floor moves to
>= 0.4.38 the fallbacks become dead branches and this file collapses to
three aliases (ROADMAP: "jax compat shim consolidation").
"""

from __future__ import annotations

import jax
from jax import lax

JAX_VERSION_FLOOR = (0, 4, 37)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised on jax <= 0.4.37 only
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]

if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:  # pragma: no cover - jax <= 0.4.37
    def axis_size(axis: str) -> int:
        # psum of a Python literal is constant-folded to the axis size.
        return lax.psum(1, axis)

if hasattr(lax, "pvary"):
    pvary = lax.pvary
else:  # pragma: no cover - jax <= 0.4.37
    def pvary(x, axis_names):
        # Older shard_map has no varying-type system; identity is correct.
        return x

__all__ = ["JAX_VERSION_FLOOR", "axis_size", "pvary", "shard_map"]
