"""Logical-axis -> mesh-axis sharding rules for the model zoo.

The production mesh is (pod, data, model) / (data, model); model code only
speaks logical axes (nn.py), and this module decides the mapping per
(config x shape-kind), including the divisibility-driven fallbacks:

  * TP: heads/ff/vocab/experts -> "model"
  * DP: batch -> ("pod", "data")
  * FSDP (ZeRO-3): param "embed" (d_model) dims -> "data"; optimizer state
    inherits param sharding, so Adam moments shard over data x model
  * KV cache: kv_heads -> "model" when divisible, else the cache SEQUENCE
    dim -> "model" (flash-decoding-style partitioning, XLA inserts the
    partial-softmax collectives); B < dp_size (long_500k, B=1) additionally
    re-points kv_seq at "data" so the 9x500k Zamba2 site caches actually fit

These rules are the principal §Perf hillclimbing lever: experiments swap
rule dicts, never model code.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models.nn import DistContext


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def make_rules(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: Optional[ShapeSpec] = None,
    *,
    fsdp: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    model = mesh.shape["model"]
    dp = _dp_axes(mesh)
    rules: Dict[str, Any] = {
        "layers": None,
        "batch": dp,
        # residual-stream sequence dim: None = Megatron "TP" (activations
        # replicated over model between blocks; XLA all-reduces into that
        # layout); "model" = sequence parallelism (reduce-scatter/all-gather
        # pairs, ~half the TP collective bytes) — a §Perf lever.
        "seq": None,
        "heads": "model",
        "kv_heads": "model" if (cfg.num_kv_heads % model == 0 and cfg.num_kv_heads > 0) else None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        # FSDP (ZeRO-3) shards params/opt-state over ALL dp axes — on the
        # multi-pod mesh that is ("pod","data") = 32-way, halving per-chip
        # state vs data-only
        "embed": dp if fsdp else None,
    }
    # KV cache sequence dim: shard over "model" when heads can't be; shard
    # over "data" when the batch can't fill the dp axes (B=1 long-context).
    if rules["kv_heads"] is None:
        rules["kv_seq"] = "model"
    else:
        rules["kv_seq"] = None
    if shape is not None and shape.kind == "decode" and shape.global_batch < dp_size(mesh):
        rules["batch"] = None
        rules["kv_seq"] = "data"
    if overrides:
        rules.update(overrides)
    return rules


def make_dist(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: Optional[ShapeSpec] = None,
    *,
    fsdp: bool = True,
    moe_dispatch: Optional[str] = None,
    overrides: Optional[Dict[str, Any]] = None,
) -> DistContext:
    rules = make_rules(cfg, mesh, shape, fsdp=fsdp, overrides=overrides)
    if moe_dispatch is None:
        moe_dispatch = "alltoall" if cfg.num_experts else "dense"
    return DistContext(mesh=mesh, rules=rules, moe_dispatch=moe_dispatch)


# ---------------------------------------------------------------------------
# shardings for param / cache / batch pytrees
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(factory_specs: Dict[str, tuple], params, dist: DistContext):
    """Map ParamFactory.specs (path -> logical axes) onto the params tree."""
    def per_leaf(path, leaf):
        p = _path_str(path)
        axes = factory_specs.get(p)
        if axes is None:
            raise KeyError(f"no spec recorded for param {p!r}")
        return dist.sharding(axes)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


_CACHE_AXES_BY_NAME: Dict[str, Dict[int, tuple]] = {
    # rank -> logical axes
    "k": {5: ("layers", "batch", "kv_heads", "kv_seq", None)},
    "v": {5: ("layers", "batch", "kv_heads", "kv_seq", None)},
    "c_kv": {4: ("layers", "batch", "kv_seq", None)},
    "k_rope": {5: ("layers", "batch", None, "kv_seq", None)},
    "length": {1: (None,), 0: ()},
}


def _ssm_state_axes(rank: int, which: str) -> tuple:
    """conv [.., B, C, w-1] / ssm [.., B, H, P, N]; leading dims are layer
    stacks.  Shard channels/heads over model, batch over dp."""
    if which == "conv":
        base = ("batch", "ff", None)
    else:
        base = ("batch", "heads", None, None)
    lead = (None,) * (rank - len(base))
    return lead + base


def cache_shardings(cache, dist: DistContext):
    def per_leaf(path, leaf):
        name, seq_idx = None, None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.SequenceKey) and seq_idx is None:
                seq_idx = k.idx
            if isinstance(k, jax.tree_util.DictKey):
                name = str(k.key)
                break
        rank = len(leaf.shape)
        if name in _CACHE_AXES_BY_NAME and rank in _CACHE_AXES_BY_NAME[name]:
            axes = _CACHE_AXES_BY_NAME[name][rank]
        elif name in ("states", "mamba"):
            # tuple (conv_state, ssm_state) under this key
            axes = _ssm_state_axes(rank, "conv" if seq_idx == 0 else "ssm")
        else:
            axes = (None,) * rank
        return dist.sharding(axes)

    return jax.tree_util.tree_map_with_path(per_leaf, cache)


def batch_shardings(batch, dist: DistContext):
    def per_leaf(leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return dist.sharding(axes)

    return jax.tree.map(per_leaf, batch)
