"""Collective building blocks.

The paper's communication machinery is a *k:1 scatter-gather* pattern
(§III-A): every node buckets outgoing records per destination, ships packets
when full, and one collector thread per node appends arriving packets.  On a
TPU mesh the same pattern is a **fixed-capacity bucketed all_to_all**:

    bucket-by-destination  ->  all_to_all  ->  concatenate-what-arrived

Because XLA requires static shapes, "send packet when full" becomes a
per-destination buffer of `capacity` records plus a validity mask; overflow
is *counted and reported*, never silently dropped (tests assert zero drops at
the configured capacity factor).  This one primitive serves three masters:

  * core/redistribute.py  — the paper's redistribute step,
  * core/relabel.py       — the optimized (non-ring) relabel variant,
  * models/moe.py         — MoE expert dispatch (tokens -> expert owners),

which is the concrete sense in which the paper's scatter-gather pattern is a
first-class framework primitive.

Everything here runs *inside* shard_map over a single named axis.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Version compat
# ---------------------------------------------------------------------------

# The version-floor shims (shard_map / axis_size / pvary for jax 0.4.37) live
# in distributed/compat.py; re-exported here because historically every module
# imported them from this file — both import paths stay valid.
from .compat import axis_size, pvary, shard_map  # noqa: F401

# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------


def flat_mesh(n_shards: Optional[int] = None, axis: str = "shards") -> jax.sharding.Mesh:
    """A 1-D mesh over the first `n_shards` devices (default: all).

    The graph pipeline treats every chip as one of the paper's "compute
    nodes" (nb = number of shards); model code uses the 2-D/3-D production
    mesh from launch/mesh.py instead.
    """
    devs = jax.devices()
    if n_shards is None:
        n_shards = len(devs)
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), (axis,))


# ---------------------------------------------------------------------------
# Bucketing (the scatter side)
# ---------------------------------------------------------------------------


class Buckets(NamedTuple):
    """Result of bucketing N records into k fixed-capacity destination rows."""

    data: jnp.ndarray      # [k, capacity, ...]  bucketed payload
    valid: jnp.ndarray     # [k, capacity] bool  slot occupied?
    position: jnp.ndarray  # [N] int32  (dest, slot) flattened index each record went to
                           #            (= dest*capacity + slot; capacity*k if dropped)
    dropped: jnp.ndarray   # [] int32   records that exceeded capacity (counted, not lost silently)


def bucket_by_destination(data: jnp.ndarray, dest: jnp.ndarray, k: int, capacity: int,
                          valid: Optional[jnp.ndarray] = None) -> Buckets:
    """Stable bucket of `data` rows by `dest` in [0, k) with fixed capacity.

    Paper Alg. 8 lines 2-7 ("append to elp_d; if full, send") under static
    shapes.  Stability (records to the same destination keep their relative
    order) is what lets the sorted-merge redistribute variant (§III-B7) ship
    pre-sorted runs.  Rows with valid=False are discarded silently (they
    consume no capacity and are not counted as drops) — used by callers that
    carry fixed-size buffers with dead slots (data/walks.py).
    """
    n = dest.shape[0]
    dest = dest.astype(jnp.int32)
    if valid is not None:
        dest = jnp.where(valid, dest, k)                          # sentinel group
    # Rank of each record within its destination group, via stable sort:
    order = jnp.argsort(dest, stable=True)                       # [N]
    sorted_dest = dest[order]
    # start offset of each destination group among the sorted records
    group_start = jnp.searchsorted(sorted_dest, jnp.arange(k, dtype=jnp.int32), side="left")
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - group_start[jnp.minimum(sorted_dest, k - 1)]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)  # rank within dest group
    keep = (rank < capacity) & (dest < k)
    slot = jnp.where(keep, dest * capacity + rank, k * capacity)  # overflow -> scratch slot
    flat_shape = (k * capacity + 1,) + data.shape[1:]
    flat = jnp.zeros(flat_shape, data.dtype).at[slot].set(data, mode="drop")
    occupied = jnp.zeros((k * capacity + 1,), jnp.bool_).at[slot].set(True, mode="drop")
    dropped = jnp.sum((rank >= capacity) & (dest < k)).astype(jnp.int32)
    return Buckets(
        data=flat[:-1].reshape((k, capacity) + data.shape[1:]),
        valid=occupied[:-1].reshape(k, capacity),
        position=slot,
        dropped=dropped,
    )


def unbucket(buckets_data: jnp.ndarray, position: jnp.ndarray, fill=0) -> jnp.ndarray:
    """Inverse of bucket_by_destination for the *return trip*: gather each
    record's (possibly transformed) payload back to its original position.

    Dropped records receive `fill`.
    """
    k, capacity = buckets_data.shape[:2]
    flat = buckets_data.reshape((k * capacity,) + buckets_data.shape[2:])
    pad = jnp.full((1,) + flat.shape[1:], fill, flat.dtype)
    flat = jnp.concatenate([flat, pad], axis=0)
    return flat[position]


# ---------------------------------------------------------------------------
# The k:1 scatter-gather collective
# ---------------------------------------------------------------------------


class ExchangeResult(NamedTuple):
    data: jnp.ndarray      # [k, capacity, ...] row j = records sent to me by shard j
    valid: jnp.ndarray     # [k, capacity] bool
    position: jnp.ndarray  # [N] local bucketing positions (for the return trip)
    dropped: jnp.ndarray   # [] int32  GLOBAL dropped count (psum'd)


def capacity_all_to_all(
    data: jnp.ndarray,
    dest: jnp.ndarray,
    *,
    axis: str,
    capacity: int,
    valid: Optional[jnp.ndarray] = None,
) -> ExchangeResult:
    """Bucket records by destination shard and exchange them (k:1 pattern).

    Must be called inside shard_map over `axis`.  `data` is [N, ...] local
    records, `dest` [N] destination shard ids in [0, k).  Rows with
    valid=False are discarded without consuming capacity.
    """
    k = axis_size(axis)
    b = bucket_by_destination(data, dest, k, capacity, valid=valid)
    recv = lax.all_to_all(b.data, axis, split_axis=0, concat_axis=0, tiled=False)
    recv_valid = lax.all_to_all(b.valid, axis, split_axis=0, concat_axis=0, tiled=False)
    dropped = lax.psum(b.dropped, axis)
    return ExchangeResult(recv, recv_valid, b.position, dropped)


def return_all_to_all(
    results: jnp.ndarray,
    position: jnp.ndarray,
    *,
    axis: str,
    fill=0,
) -> jnp.ndarray:
    """Return trip of capacity_all_to_all: send per-record results back to the
    shard that asked, and scatter them to the original record order.

    `results` is [k, capacity, ...] aligned with ExchangeResult.data.
    """
    back = lax.all_to_all(results, axis, split_axis=0, concat_axis=0, tiled=False)
    return unbucket(back, position, fill=fill)


# ---------------------------------------------------------------------------
# Ring streaming (the paper's permute_server, as a collective schedule)
# ---------------------------------------------------------------------------


def ring_shift(x: jnp.ndarray, axis: str, shift: int = 1) -> jnp.ndarray:
    """Rotate shard-local blocks around the ring: shard i receives the block
    of shard (i + shift) mod k.

    This is the paper's `get_permute_range` remote fetch turned into a
    static collective schedule: instead of every shard *pulling* chunk s from
    its owner (random access across the interconnect), the chunks *stream*
    past every shard in nb rounds — sequential access on the ICI, the exact
    analogue of the paper turning random disk I/O into sequential scans.
    """
    k = axis_size(axis)
    perm = [(i, (i - shift) % k) for i in range(k)]  # (source, destination)
    return lax.ppermute(x, axis, perm)


# ---------------------------------------------------------------------------
# Sorted-merge helpers (paper §III-B7)
# ---------------------------------------------------------------------------


def merge_two_sorted(a: jnp.ndarray, b: jnp.ndarray, a_payload=None, b_payload=None):
    """Merge two sorted arrays in O(n) sequential-access style using
    searchsorted ranks (no comparison sort).

    Returns merged keys (and merged payloads if given).  This is the TPU
    analogue of the paper's streaming sorted-merge: every element's final
    position is computed by a binary search + add, all memory access patterns
    are sequential scans or monotone gathers.
    """
    na, nb_ = a.shape[0], b.shape[0]
    pos_a = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(b, a, side="left").astype(jnp.int32)
    pos_b = jnp.arange(nb_, dtype=jnp.int32) + jnp.searchsorted(a, b, side="right").astype(jnp.int32)
    out = jnp.zeros((na + nb_,), a.dtype)
    out = out.at[pos_a].set(a).at[pos_b].set(b)
    if a_payload is None:
        return out
    pay = jnp.zeros((na + nb_,) + a_payload.shape[1:], a_payload.dtype)
    pay = pay.at[pos_a].set(a_payload).at[pos_b].set(b_payload)
    return out, pay


def merge_sorted_runs(keys: jnp.ndarray, payload: Optional[jnp.ndarray] = None):
    """K-way merge of k sorted runs [k, run_len] via log2(k) pairwise rounds.

    O(m log k) work with sequential access — cheaper than re-sorting
    (O(m log m)) and faithful to the paper's sorted-merge redistribute.
    k must be a power of two (mesh axis sizes are).
    """
    k, run = keys.shape
    assert (k & (k - 1)) == 0, f"k={k} must be a power of two"
    flatp = payload
    while k > 1:
        halves = keys.reshape(k // 2, 2, -1)
        if flatp is not None:
            ph = flatp.reshape((k // 2, 2, halves.shape[-1]) + flatp.shape[2:])
        merged_k, merged_p = [], []
        for i in range(k // 2):
            if flatp is None:
                merged_k.append(merge_two_sorted(halves[i, 0], halves[i, 1]))
            else:
                mk, mp = merge_two_sorted(halves[i, 0], halves[i, 1], ph[i, 0], ph[i, 1])
                merged_k.append(mk)
                merged_p.append(mp)
        keys = jnp.stack(merged_k)
        if flatp is not None:
            flatp = jnp.stack(merged_p)
        k //= 2
    if payload is None:
        return keys[0]
    return keys[0], flatp[0]
