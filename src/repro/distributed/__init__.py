"""Distributed substrate: mesh-flattening helpers, the capacity-bucketed
all_to_all (the paper's k:1 scatter-gather pattern as a JAX collective),
sharding rules for the model zoo, gradient compression, and fault tolerance.
"""
