"""Pallas TPU kernel: R-MAT edge generation (paper Alg. 5 hot loop).

The edge generator is the pipeline's compute hot spot: `scale` levels of
(2 hashes + 2 compares + 2 shifted adds) per edge, fully data-parallel.  On
the paper's CPUs this was the per-core pthread loop; on TPU it is a VPU
kernel: edges are laid out as (rows, 128) tiles, each grid step produces one
(BLOCK_ROWS, 128) tile of src and dst in VMEM, the level walk is unrolled
`scale` times (static), and the counter-based RNG (core.rmat.mix32) needs no
state — every tile derives its randomness from the global edge index, so
tiles are generated independently and identically at any grid decomposition
(bit-exact vs the jnp oracle, tested).

LANE=128 matches the VPU lane count; BLOCK_ROWS=8 gives 8x128 int32 tiles =
4 KiB per ref, a comfortable VMEM working set (3 live tiles + temporaries).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.hostgen import FEISTEL_ROUNDS, feistel_round_key_np
from ..core.types import GraphConfig, quadrant_thresholds

LANE = 128
BLOCK_ROWS = 8
TILE = LANE * BLOCK_ROWS


def _mix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform(seed: int, idx, stream: int):
    s = jnp.uint32((seed ^ (stream * 0x9E3779B9)) & 0xFFFFFFFF)
    return _mix32(_mix32(idx + s) ^ s)


def _rmat_kernel(o_src_ref, o_dst_ref, *, seed: int, scale: int, thresholds, start: int):
    t_src, t_dst0, t_dst1 = thresholds
    i = pl.program_id(0)
    # global edge index of each slot in this tile
    row = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, LANE), 0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, LANE), 1)
    idx = jnp.uint32(start) + i.astype(jnp.uint32) * jnp.uint32(TILE) + row * jnp.uint32(LANE) + lane
    src = jnp.zeros((BLOCK_ROWS, LANE), jnp.uint32)
    dst = jnp.zeros((BLOCK_ROWS, LANE), jnp.uint32)
    for level in range(scale):  # static unroll of the quadtree walk
        r1 = _uniform(seed, idx, 2 * level)
        r2 = _uniform(seed, idx, 2 * level + 1)
        src_bit = r1 < jnp.uint32(t_src)
        t_d = jnp.where(src_bit, jnp.uint32(t_dst1), jnp.uint32(t_dst0))
        dst_bit = r2 < t_d
        src = (src << 1) | src_bit.astype(jnp.uint32)
        dst = (dst << 1) | dst_bit.astype(jnp.uint32)
    o_src_ref[...] = src.astype(jnp.int32)
    o_dst_ref[...] = dst.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg", "start", "count", "interpret"))
def rmat_edges_pallas(cfg: GraphConfig, start: int, count: int, interpret: bool = True):
    """Generate `count` edges with global ids [start, start+count).

    count must be a multiple of TILE (ops.py pads otherwise).
    """
    assert count % TILE == 0, f"count={count} must be a multiple of {TILE}"
    rows = count // LANE
    grid = rows // BLOCK_ROWS
    kernel = functools.partial(
        _rmat_kernel,
        seed=cfg.seed,
        scale=cfg.scale,
        thresholds=quadrant_thresholds(cfg),
        start=start,
    )
    out_shape = jax.ShapeDtypeStruct((rows, LANE), jnp.int32)
    src, dst = pl.pallas_call(
        kernel,
        grid=(grid,),
        out_specs=(
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ),
        out_shape=(out_shape, out_shape),
        interpret=interpret,
    )()
    return src.reshape(-1), dst.reshape(-1)


def _feistel_kernel(x_ref, o_ref, *, key: int, nbits: int, rounds: int):
    """Keyed Feistel permutation tile (twin of hostgen.feistel_perm_np).

    Round keys are Python-int constants folded at trace time (the SAME
    numpy derivation the host family uses, so the three implementations
    share one key schedule by construction); the round loop is a static
    unroll like the R-MAT level walk — per element it is `rounds` mix32
    evaluations plus shifts/xors, pure VPU work."""
    lo_bits = nbits // 2
    x = x_ref[...].astype(jnp.uint32)
    L = x >> lo_bits
    R = x & jnp.uint32((1 << lo_bits) - 1)
    wL, wR = nbits - lo_bits, lo_bits
    for i in range(rounds):  # static unroll
        rk = jnp.uint32(int(feistel_round_key_np(key, i)))
        F = _mix32(R ^ rk)
        L, R, wL, wR = R, (L ^ F) & jnp.uint32((1 << wL) - 1), wR, wL
    o_ref[...] = ((L << lo_bits) | R).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("key", "nbits", "rounds", "interpret"))
def feistel_perm_pallas(x: jnp.ndarray, key: int, nbits: int,
                        rounds: int = FEISTEL_ROUNDS,
                        interpret: bool = True) -> jnp.ndarray:
    """Permute int32 ids through the keyed Feistel bijection on
    [0, 2**nbits), as (BLOCK_ROWS, 128) VMEM tiles.

    Power-of-two domains only (the pipeline's n = 2**scale case — cycle
    walking is data-dependent control flow and stays on the host/jnp
    paths); nbits <= 31 so outputs fit int32.  x.size must be a multiple
    of TILE (callers pad, as with rmat_edges_pallas).  Bit-exact vs
    shuffle.feistel_perm and hostgen.feistel_perm_np (tested).
    """
    assert x.size % TILE == 0, f"size={x.size} must be a multiple of {TILE}"
    assert 1 <= nbits <= 31, f"int32 lanes need 1 <= nbits <= 31, got {nbits}"
    rows = x.size // LANE
    grid = rows // BLOCK_ROWS
    kernel = functools.partial(_feistel_kernel, key=key, nbits=nbits,
                               rounds=rounds)
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=(pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),),
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        interpret=interpret,
    )(x.reshape(rows, LANE))
    return out.reshape(-1)
