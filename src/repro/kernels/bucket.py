"""Pallas TPU kernel: per-destination histogram (redistribute planning).

The scatter side of the k:1 pattern needs, per shard, the count of records
bound for each destination (paper Alg. 8's packet bookkeeping; also the MoE
router's expert-load statistics).  TPUs have no scatter-atomics, so the
kernel computes the histogram as a *compare-and-reduce*: each grid step
loads one (BLOCK_ROWS, 128) tile of destination ids, builds the one-hot
comparison against the destination iota, and accumulates the per-destination
sums into a VMEM accumulator that persists across grid steps (output block
index_map is constant; initialized at step 0, read back after the last
step).  Sequential access only — the same random->sequential conversion the
paper applies to CSR.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 8
TILE = LANE * BLOCK_ROWS


def _bucket_kernel(dest_ref, o_ref, *, k: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dest = dest_ref[...]  # [BLOCK_ROWS, LANE] int32
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)  # [1, k]
    onehot = (dest.reshape(-1, 1) == ids).astype(jnp.int32)  # [TILE, k]
    o_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)  # [1, k]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def bucket_hist_pallas(dest: jnp.ndarray, k: int, interpret: bool = True) -> jnp.ndarray:
    """Histogram of `dest` (int32 in [0, k)) -> counts [k] int32.

    |dest| must be a multiple of TILE (ops.py pads with k, an out-of-range
    sentinel that never matches the iota).
    """
    n = dest.shape[0]
    assert n % TILE == 0, f"n={n} must be a multiple of {TILE}"
    grid = n // TILE
    counts = pl.pallas_call(
        functools.partial(_bucket_kernel, k=k),
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, k), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(dest.reshape(-1, LANE))
    return counts[0]
