"""Public jit'd wrappers over the Pallas kernels with oracle dispatch.

Call sites use these, never the kernels directly.  `mode` selects:

  "xla"        pure-jnp reference path (ref.py) — default everywhere the
               dry-run lowers on the CPU backend (Pallas TPU kernels do not
               lower for CPU targets; interpret mode is for testing only)
  "interpret"  Pallas kernel executed by the interpreter (CPU correctness)
  "tpu"        Pallas kernel compiled for TPU (the production target)

Wrappers own the padding to kernel tile multiples so kernels stay branch-free.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .bucket import TILE as BUCKET_TILE, bucket_hist_pallas
from .flash_attention import flash_attention_pallas
from .relabel_gather import TILE as RELABEL_TILE, relabel_gather_pallas
from .rmat import TILE as RMAT_TILE, rmat_edges_pallas

DEFAULT_MODE = "xla"


def _pad_to(x: jnp.ndarray, tile: int, fill) -> jnp.ndarray:
    n = x.shape[0]
    pad = (-n) % tile
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


def rmat_edges(cfg, start: int, count: int, mode: str = DEFAULT_MODE):
    if mode == "xla":
        return ref.rmat_ref(cfg, start, count)
    padded = count + ((-count) % RMAT_TILE)
    s, d = rmat_edges_pallas(cfg, start, padded, interpret=(mode == "interpret"))
    return s[:count], d[:count]


def bucket_hist(dest: jnp.ndarray, k: int, mode: str = DEFAULT_MODE) -> jnp.ndarray:
    if mode == "xla":
        return ref.bucket_hist_ref(dest, k)
    padded = _pad_to(dest.astype(jnp.int32), BUCKET_TILE, k)  # k never matches
    return bucket_hist_pallas(padded, k, interpret=(mode == "interpret"))


def relabel_gather(keys: jnp.ndarray, pv_chunk: jnp.ndarray, base, mode: str = DEFAULT_MODE) -> jnp.ndarray:
    if mode == "xla":
        return ref.relabel_gather_ref(keys, pv_chunk, base)
    n = keys.shape[0]
    padded = _pad_to(keys.astype(jnp.int32), RELABEL_TILE, -1)  # -1 never in range
    out = relabel_gather_pallas(
        padded, pv_chunk.astype(jnp.int32), jnp.asarray(base), interpret=(mode == "interpret")
    )
    return out[:n].astype(keys.dtype)


def flash_attention(q, k, v, causal: bool = True, scale=None, mode: str = DEFAULT_MODE):
    if mode == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, interpret=(mode == "interpret")
    )
