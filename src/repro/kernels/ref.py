"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Each function is the mathematical spec of the matching kernel in this
package; tests sweep shapes/dtypes and assert (bit-exact for the integer
kernels, allclose for attention) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.rmat import counter_uniform_u32
from ..core.types import GraphConfig, quadrant_thresholds


def rmat_ref(cfg: GraphConfig, start: int, count: int):
    """Oracle for kernels/rmat.py — identical math to core.rmat."""
    from ..core.rmat import rmat_edge_block

    return rmat_edge_block(cfg, jnp.uint32(start), count)


def bucket_hist_ref(dest: jnp.ndarray, k: int) -> jnp.ndarray:
    """Oracle for kernels/bucket.py: histogram of destination ids."""
    return jnp.zeros((k,), jnp.int32).at[dest].add(1)


def relabel_gather_ref(keys: jnp.ndarray, pv_chunk: jnp.ndarray, base: int) -> jnp.ndarray:
    """Oracle for kernels/relabel_gather.py: masked merge-join gather.

    keys outside [base, base+|pv_chunk|) pass through unchanged.
    """
    local = keys - base
    in_range = (local >= 0) & (local < pv_chunk.shape[0])
    idx = jnp.clip(local, 0, pv_chunk.shape[0] - 1)
    return jnp.where(in_range, pv_chunk[idx], keys)


def flash_attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for kernels/flash_attention.py: naive softmax GQA attention."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)) * scale
    if causal:
        Skv = k.shape[2]
        # queries are the LAST Sq positions of the Skv context
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vq.astype(jnp.float32)).astype(q.dtype)
