"""Pallas TPU kernel: causal GQA flash attention (forward).

The assigned LM architectures are dominated by attention at the 32k-prefill
and 500k-decode shapes, so this is the framework's model-side compute hot
spot.  Blocked online-softmax in the canonical TPU form:

  grid = (batch*q_heads, q_blocks, kv_blocks)   kv innermost ("arbitrary")
  q block (1, bq, D) and out block revisit the same VMEM tile across the kv
  loop; running (max, sum, acc) live in VMEM scratch; init at kv==0, final
  normalization at kv==last.  Causal blocks strictly above the diagonal are
  predicated off with pl.when (TPU skips the MXU work, the paper-style
  "don't touch what you don't need" discipline applied to compute).

GQA is handled in the index maps: query head h reads kv head h // group —
no jnp.repeat materialization (the XLA reference pays that gather; the
kernel reads the shared KV block straight from VMEM).

Numerics follow the standard flash recipe in f32 accumulation; tests sweep
(Sq, Skv, heads, D, dtype) and assert allclose vs ref.flash_attention_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, bq: int, bk: int, sq: int, skv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal frontier: queries are the LAST sq positions of the skv context
    offset = skv - sq
    block_needed = True
    if causal:
        block_needed = ki * bk <= qi * bq + (bq - 1) + offset

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # [bq, D]
        k = k_ref[0].astype(jnp.float32)                      # [bk, D]
        v = v_ref[0].astype(jnp.float32)                      # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                             # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)            # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with everything masked stay at -inf; exp guard keeps them 0
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        p = jnp.where(m_new == NEG_INF, 0.0, jnp.exp(s - m_new))  # [bq, bk]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        norm = jnp.where(l > 0.0, 1.0 / l, 0.0)
        o_ref[0] = (acc_ref[...] * norm).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)

    def kv_index(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, sq=Sq, skv=Skv
        ),
        grid=(B * Hq, Sq // bq, Skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D)
