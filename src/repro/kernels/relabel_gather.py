"""Pallas TPU kernel: the merge-join gather at the heart of relabel (Alg. 6).

One ring round of the relabel phase is: given edges sorted by endpoint and
the current pv chunk resident locally, replace every endpoint that falls in
the chunk's range.  The paper does this as a sort-merge-join with cursor
advancement; on TPU the chunk sits in VMEM and the join is a *masked gather*
whose indices are monotone (the edges are sorted), i.e. sequential access —
the exact property the paper's chunk-sort buys.

BlockSpec tiling = the paper's mmc chunking: each grid step processes one
(BLOCK_ROWS, 128) tile of endpoint ids against the full pv chunk (the chunk
is the paper's bounded buffer; its block index_map is constant so it is
loaded into VMEM once and reused across all edge tiles).  `base` arrives via
scalar prefetch (SMEM) so one compiled kernel serves all nb ring rounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
BLOCK_ROWS = 8
TILE = LANE * BLOCK_ROWS


def _relabel_kernel(base_ref, keys_ref, pv_ref, o_ref):
    base = base_ref[0]
    keys = keys_ref[...]                    # [BLOCK_ROWS, LANE] int32
    pv = pv_ref[...]                        # [1, B] pv chunk, resident
    B = pv.shape[1]
    local = keys - base
    in_range = (local >= 0) & (local < B)
    idx = jnp.clip(local, 0, B - 1)
    gathered = jnp.take(pv[0], idx.reshape(-1), axis=0).reshape(keys.shape)
    o_ref[...] = jnp.where(in_range, gathered, keys)


@functools.partial(jax.jit, static_argnames=("interpret",))
def relabel_gather_pallas(
    keys: jnp.ndarray, pv_chunk: jnp.ndarray, base: jnp.ndarray, interpret: bool = True
) -> jnp.ndarray:
    """Relabel keys in [base, base+B) through pv_chunk; others pass through.

    |keys| must be a multiple of TILE (ops.py pads with -1, never in range).
    """
    n = keys.shape[0]
    assert n % TILE == 0, f"n={n} must be a multiple of {TILE}"
    B = pv_chunk.shape[0]
    out = pl.pallas_call(
        _relabel_kernel,
        grid=(n // TILE,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # base scalar
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (0, 0)),         # chunk resident in VMEM
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // LANE, LANE), jnp.int32),
        interpret=interpret,
    )(base.reshape(1).astype(jnp.int32), keys.reshape(-1, LANE), pv_chunk.reshape(1, B))
    return out.reshape(-1)
