"""Decoder-only LM assembly: dense GQA, MoE (qwen3), MLA+MoE (deepseek).

Layers are stacked along a leading "layers" dim and iterated with lax.scan
(small HLO at any depth: the 94-layer MoE compiles as one block).  A
`first_k_dense` prefix (deepseek) is kept unstacked outside the scan.
Remat policy "block" checkpoints each scanned block.

The decode cache is a pytree stacked the same way ([L, ...]) and threaded
through the scan as xs/ys, so prefill/decode share the block code.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (
    attention, decode_positions, embed, init_attention, init_embed, init_mla,
    init_mlp, init_rmsnorm, init_unembed, mla_attention, mlp, rmsnorm, unembed,
)
from .moe import init_moe, moe_ffn
from .nn import DistContext, ParamFactory, shard

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "dropped": 0}


def _is_moe_layer(cfg, layer_idx: int) -> bool:
    return cfg.num_experts > 0 and layer_idx >= cfg.first_k_dense


def _init_block(f: ParamFactory, path: str, cfg, moe: bool, lead=()):
    p = {
        "ln1": init_rmsnorm(f, f"{path}/ln1", cfg.d_model, lead),
        "ln2": init_rmsnorm(f, f"{path}/ln2", cfg.d_model, lead),
    }
    if cfg.kv_lora_rank:
        p["attn"] = init_mla(f, f"{path}/attn", cfg, lead)
    else:
        p["attn"] = init_attention(f, f"{path}/attn", cfg, lead)
    if moe:
        p["ffn"] = init_moe(f, f"{path}/ffn", cfg, lead)
    else:
        p["ffn"] = init_mlp(f, f"{path}/ffn", cfg.d_model, cfg.d_ff, lead)
    return p


def _block(p, cfg, x, positions, dist, cache=None, moe: bool = False):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, new_cache = mla_attention(p["attn"], cfg, h, positions, dist, kv_cache=cache)
    else:
        a, new_cache = attention(p["attn"], cfg, h, positions, dist, kv_cache=cache)
    x = shard(x + a, ("batch", "seq", None), dist)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        f, aux = moe_ffn(p["ffn"], cfg, h, dist)
    else:
        f, aux = mlp(p["ffn"], h, dist), ZERO_AUX
    x = shard(x + f, ("batch", "seq", None), dist)
    return x, new_cache, aux


def init_params(cfg, f: ParamFactory) -> Dict[str, Any]:
    n_prefix = cfg.first_k_dense if cfg.num_experts else 0
    n_scan = cfg.num_layers - n_prefix
    p = {
        "embed": init_embed(f, "embed", cfg, cfg.d_model),
        "prefix": [
            # path "prefix/<i>/..." matches the pytree path (list index), so
            # factory.specs line up with tree_map_with_path in sharding.py
            _init_block(f, f"prefix/{i}", cfg, moe=False) for i in range(n_prefix)
        ],
        "blocks": _init_block(
            f, "blocks", cfg, moe=cfg.num_experts > 0, lead=(n_scan,)
        ),
        "ln_f": init_rmsnorm(f, "ln_f", cfg.d_model),
        "unembed": init_unembed(f, "unembed", cfg.d_model, cfg),
    }
    return p


def _accumulate(acc, aux):
    return {k: acc[k] + aux[k] for k in acc}


def _scan_blocks(params, cfg, x, positions, dist, caches=None):
    """Run the stacked blocks.  caches: None or pytree with leading L dim."""
    moe = cfg.num_experts > 0

    def body(carry, inp):
        x, aux_acc = carry
        p_l, cache_l = inp
        x, new_cache, aux = _block(p_l, cfg, x, positions, dist, cache_l, moe=moe)
        return (x, _accumulate(aux_acc, aux)), new_cache

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body

    init_aux = {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body_fn, (x, init_aux), (params["blocks"], caches)
        )
    else:
        n = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        new_list = []
        carry = (x, init_aux)
        for i in range(n):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            c_l = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            carry, nc = body_fn(carry, (p_l, c_l))
            new_list.append(nc)
        x, aux = carry
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if caches is not None else None
        )
    return x, aux, new_caches


def forward(cfg, params, batch, dist: Optional[DistContext] = None):
    """Train-path forward: tokens [B,S] -> logits [B,S,V].  Returns (logits, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)
    positions = jnp.arange(S)
    aux_total = {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}
    for p_l in params["prefix"]:
        x, _, aux = _block(p_l, cfg, x, positions, dist, None, moe=False)
        aux_total = _accumulate(aux_total, aux)
    x, aux, _ = _scan_blocks(params, cfg, x, positions, dist, None)
    aux_total = _accumulate(aux_total, aux)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, aux_total


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, mode: str = "init"):
    """Stacked decode cache.  GQA: k/v [L,B,Hkv,Smax,hd]; MLA: c_kv+k_rope."""
    n_prefix = cfg.first_k_dense if cfg.num_experts else 0
    n_scan = cfg.num_layers - n_prefix
    dt = cfg.jdtype

    def make(shape, dtype=dt):
        if mode == "shape":
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    def layer_cache(lead):
        if cfg.kv_lora_rank:
            return {
                "c_kv": make((*lead, batch, max_len, cfg.kv_lora_rank)),
                "k_rope": make((*lead, batch, 1, max_len, cfg.qk_rope_dim)),
                "length": make((*lead,), jnp.int32) if lead else make((), jnp.int32),
            }
        hd = cfg.hd
        return {
            "k": make((*lead, batch, cfg.num_kv_heads, max_len, hd)),
            "v": make((*lead, batch, cfg.num_kv_heads, max_len, hd)),
            "length": make((*lead,), jnp.int32) if lead else make((), jnp.int32),
        }

    return {
        "prefix": [layer_cache(()) for _ in range(n_prefix)],
        "blocks": layer_cache((n_scan,)),
    }


def _run_with_cache(cfg, params, tokens, cache, dist, positions, last_only: bool):
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)
    aux_total = {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}
    new_prefix = []
    for p_l, c_l in zip(params["prefix"], cache["prefix"]):
        x, nc, aux = _block(p_l, cfg, x, positions, dist, c_l, moe=False)
        new_prefix.append(nc)
        aux_total = _accumulate(aux_total, aux)
    x, aux, new_blocks = _scan_blocks(params, cfg, x, positions, dist, cache["blocks"])
    aux_total = _accumulate(aux_total, aux)
    if last_only:
        x = x[:, -1:]  # unembed only the sampled position (prefill: huge saving)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {"prefix": new_prefix, "blocks": new_blocks}, aux_total


def prefill(cfg, params, batch, cache, dist: Optional[DistContext] = None):
    """Process the prompt, filling the cache.  Returns (last-token logits, cache)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)
    logits, new_cache, _ = _run_with_cache(
        cfg, params, tokens, cache, dist, positions, last_only=True
    )
    return logits, new_cache


def decode_step(cfg, params, tokens, cache, dist: Optional[DistContext] = None):
    """One token per sequence.  tokens [B,1].  Returns (logits [B,1,V], cache)."""
    length = cache["blocks"]["length"][0]  # stacked [L,...]; all entries equal
    positions = decode_positions(length, tokens.shape[1])
    logits, new_cache, _ = _run_with_cache(
        cfg, params, tokens, cache, dist, positions, last_only=False
    )
    return logits, new_cache
