"""Parameter factory + sharding plumbing for the model zoo.

Design goals:
  * one definition site per parameter: shape, logical axes, and initializer
    are declared together, so the dry-run (ShapeDtypeStruct, no allocation)
    and real initialization can never drift apart;
  * logical axis names, not mesh axes, in model code — the mapping to mesh
    axes lives in distributed/sharding.py and is swappable per experiment
    (that mapping is a primary hillclimbing lever in §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass
class DistContext:
    """Mesh + logical->mesh axis rules, threaded through model apply fns.

    None-able: model code calls `shard(x, axes, dist)` which no-ops when
    dist is None (single-device smoke tests).
    """

    mesh: Mesh
    rules: Dict[str, Any]          # logical axis name -> mesh axis (or tuple, or None)
    moe_dispatch: str = "dense"    # "dense" | "alltoall" (EP via shard_map)
    attn_mode: str = "xla"         # kernels.ops mode for attention

    def spec(self, axes: Axes) -> P:
        parts = []
        for a in axes:
            r = self.rules.get(a) if a is not None else None
            parts.append(r)
        return P(*parts)

    def sharding(self, axes: Axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


def shard(x: jnp.ndarray, axes: Axes, dist: Optional[DistContext]) -> jnp.ndarray:
    """with_sharding_constraint under logical axis names (no-op if dist None)."""
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(x, dist.sharding(axes))


class ParamFactory:
    """Creates parameters and records their logical-axes spec by path.

    mode="init"   allocate + initialize real arrays (tests, examples)
    mode="shape"  return ShapeDtypeStruct only (dry-run: no host allocation;
                  512-device lowering never touches real memory)
    """

    def __init__(self, mode: str = "init", key: Optional[jax.Array] = None,
                 dtype=jnp.float32):
        assert mode in ("init", "shape")
        self.mode = mode
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.dtype = dtype
        self.specs: Dict[str, Axes] = {}

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, path: str, shape: Tuple[int, ...], axes: Axes,
              init: str = "normal", scale: float = 1.0, dtype=None):
        assert len(shape) == len(axes), f"{path}: shape {shape} vs axes {axes}"
        dtype = dtype or self.dtype
        self.specs[path] = axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, dtype)
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale / (fan_in ** 0.5)
            return (jax.random.normal(self._next_key(), shape) * std).astype(dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "embed":
            return (jax.random.normal(self._next_key(), shape) * scale).astype(dtype)
        raise ValueError(init)

    def param_shardings(self, dist: DistContext) -> Dict[str, NamedSharding]:
        return {p: dist.sharding(a) for p, a in self.specs.items()}


def tree_from_paths(flat: Dict[str, Any]) -> Dict[str, Any]:
    """'a/b/c' -> nested dicts (params trees are nested for readability)."""
    out: Dict[str, Any] = {}
    for path, v in flat.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def paths_from_tree(tree: Dict[str, Any], prefix: str = "") -> Dict[str, Any]:
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(paths_from_tree(v, path))
        else:
            out[path] = v
    return out


def specs_as_tree(factory: ParamFactory) -> Dict[str, Any]:
    return tree_from_paths(dict(factory.specs))
