"""Mixture-of-Experts layer: top-k router + shared experts + EP dispatch.

The EP dispatch path is the paper's machinery verbatim: tokens are records,
experts' owners are destination shards, and the exchange is the same
fixed-capacity bucketed all_to_all (distributed/collectives.py) that powers
the graph redistribute step.  One primitive, two workloads — the sense in
which the paper's k:1 scatter-gather is a first-class framework feature.

Two dispatch modes (DistContext.moe_dispatch):

  "dense"     no EP: every device computes every expert on a capacity-
              gathered token block.  Exact for smoke tests / single device;
              compute scales with num_experts, so only for small configs.

  "alltoall"  expert parallelism over the "model" mesh axis.  Inside
              shard_map, the sequence dim is sharded over "model" (each
              model-rank owns distinct tokens), tokens are bucketed by the
              owner of their routed expert and exchanged (capacity
              all_to_all), each rank runs its local experts as batched
              einsums, results return and combine with router weights.
              Top-k assignments are uniform-ish after routing, the same
              load regime as post-relabel redistribute; capacity_factor
              absorbs the skew, drops are surfaced in aux stats.

Aux outputs: load-balance loss (Switch-style), router z-loss, drop count.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.collectives import capacity_all_to_all, return_all_to_all, shard_map
from .nn import DistContext, ParamFactory, shard


def init_moe(f: ParamFactory, path: str, cfg, lead=()):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    la = ("layers",) * len(lead)
    p = {
        "router": f.param(f"{path}/router", (*lead, d, E), (*la, "embed", None)),
        "w_gate": f.param(f"{path}/w_gate", (*lead, E, d, ff), (*la, "experts", "embed", None)),
        "w_up": f.param(f"{path}/w_up", (*lead, E, d, ff), (*la, "experts", "embed", None)),
        "w_down": f.param(f"{path}/w_down", (*lead, E, ff, d), (*la, "experts", None, "embed")),
    }
    if cfg.num_shared_experts:
        sff = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": f.param(f"{path}/shared/w_gate", (*lead, d, sff), (*la, "embed", "ff")),
            "w_up": f.param(f"{path}/shared/w_up", (*lead, d, sff), (*la, "embed", "ff")),
            "w_down": f.param(f"{path}/shared/w_down", (*lead, sff, d), (*la, "ff", "embed")),
        }
    return p


def _route(p, cfg, x_tokens: jnp.ndarray):
    """x_tokens [T, d] -> (weights [T,k], experts [T,k], aux dict)."""
    logits = (x_tokens @ p["router"]).astype(jnp.float32)     # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.experts_per_tok)
    if cfg.norm_topk_prob:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance aux + router z-loss
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                              # mean prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(experts, E, dtype=jnp.float32), axis=1), axis=0
    )                                                         # mean assignment per expert
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights.astype(x_tokens.dtype), experts, {"lb_loss": lb_loss, "z_loss": z_loss}


def _expert_ffn(w_gate, w_up, w_down, x):
    """Batched per-expert SwiGLU: x [E, C, d] with stacked weights [E, ...]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", x, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_dense(p, cfg, x_tokens, weights, experts):
    """Every-device-every-expert reference: capacity-gather tokens per expert.

    capacity = ceil(T*k/E)*4 keeps smoke-scale drops at zero; the dense path
    exists for correctness, not perf.
    """
    T = x_tokens.shape[0]
    E, k = cfg.num_experts, cfg.experts_per_tok
    cap = max(8, (T * k * 4) // E)
    flat_expert = experts.reshape(-1)                        # [T*k]
    flat_tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_expert, stable=True)
    se, st = flat_expert[order], flat_tok[order]
    start = jnp.searchsorted(se, jnp.arange(E))
    rank = jnp.arange(T * k) - start[se]
    slot = jnp.where(rank < cap, se * cap + rank, E * cap)
    gather_tok = jnp.zeros((E * cap + 1,), jnp.int32).at[slot].set(st.astype(jnp.int32), mode="drop")
    valid = jnp.zeros((E * cap + 1,), jnp.bool_).at[slot].set(True, mode="drop")
    gx = x_tokens[gather_tok[:-1]].reshape(E, cap, -1)       # [E, C, d]
    gx = gx * valid[:-1].reshape(E, cap, 1).astype(gx.dtype)
    out = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], gx).reshape(E * cap, -1)
    # scatter-combine with router weights
    flat_w = weights.reshape(-1)[order]
    src_pos = jnp.where(rank < cap, slot, E * cap)
    contrib = out[jnp.clip(src_pos, 0, E * cap - 1)] * flat_w[:, None]
    contrib = jnp.where((rank < cap)[:, None], contrib, 0)
    y = jnp.zeros_like(x_tokens).at[st].add(contrib.astype(x_tokens.dtype))
    dropped = jnp.sum(rank >= cap)
    return y, dropped


def _bucket_local(recv, local_e, e_local: int, cap2: int):
    """Stable-bucket received tokens by local expert -> [e_local, cap2, d]."""
    order = jnp.argsort(local_e, stable=True)
    se = local_e[order]
    start = jnp.searchsorted(se, jnp.arange(e_local, dtype=se.dtype))
    rank = jnp.arange(se.shape[0], dtype=jnp.int32) - start[jnp.clip(se, 0, e_local - 1)]
    ok = (se < e_local) & (rank < cap2)
    slot = jnp.where(ok, se * cap2 + rank, e_local * cap2)
    gx = jnp.zeros((e_local * cap2 + 1, recv.shape[-1]), recv.dtype).at[slot].set(
        recv[order], mode="drop"
    )
    return gx[:-1].reshape(e_local, cap2, -1), order, slot, ok


def _moe_alltoall(p_local, cfg, x_tokens, weights, experts, axis: str, ep: int, capacity: int):
    """EP dispatch inside shard_map.  x_tokens [T_loc, d] distinct per rank;
    p_local holds this rank's expert slab [E_local, ...].

    The routed expert id rides along as an extra payload column (f32 holds
    small ints exactly) so dispatch is ONE exchange, not two.

    cfg.moe_dispatch_int8: ship the token activations as int8 with one f32
    scale per row (DeepSeek-V3-style quantized dispatch) — ~2x less a2a
    traffic than bf16 at <0.8% relative activation error (tested), applied
    on BOTH directions of the exchange.  This is payload compression of the
    paper's k:1 scatter-gather records.
    """
    T, d = x_tokens.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    e_local = E // ep
    flat_expert = experts.reshape(-1).astype(jnp.int32)       # [T*k]
    xk = jnp.repeat(x_tokens, k, axis=0)                      # [T*k, d]

    def q8(rows):
        amax = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        return jnp.clip(jnp.round(rows / scale), -127, 127).astype(jnp.int8), scale

    if cfg.moe_dispatch_int8:
        q, scale = q8(xk)
        # int8 tokens ride in one exchange; (scale, expert) in a narrow f32 one
        ex = capacity_all_to_all(q, flat_expert // e_local, axis=axis, capacity=capacity)
        side = jnp.concatenate(
            [scale, (flat_expert % e_local).astype(jnp.float32)[:, None]], axis=-1)
        ex_side = capacity_all_to_all(side, flat_expert // e_local, axis=axis, capacity=capacity)
        recv_tok = (ex.data.reshape(-1, d).astype(jnp.float32)
                    * ex_side.data.reshape(-1, 2)[:, :1]).astype(x_tokens.dtype)
        recv_e = ex_side.data.reshape(-1, 2)[:, 1]
        recv_valid = ex.valid.reshape(-1)
    else:
        payload = jnp.concatenate(
            [xk, (flat_expert % e_local).astype(x_tokens.dtype)[:, None]], axis=-1)
        ex = capacity_all_to_all(payload, flat_expert // e_local, axis=axis, capacity=capacity)
        recv = ex.data.reshape(-1, d + 1)                     # [ep*cap, d+1]
        recv_tok, recv_e = recv[:, :d], recv[:, -1]
        recv_valid = ex.valid.reshape(-1)

    local_e = jnp.where(recv_valid, recv_e.astype(jnp.int32), e_local)
    cap2 = max(8, int(recv_tok.shape[0] * 2 // max(e_local, 1)))
    gx, order, slot, ok = _bucket_local(recv_tok, local_e, e_local, cap2)
    out = _expert_ffn(p_local["w_gate"], p_local["w_up"], p_local["w_down"], gx)
    out_flat = out.reshape(e_local * cap2, d)
    # un-bucket back to received-slot order
    res = jnp.zeros((recv_tok.shape[0], d), x_tokens.dtype).at[order].set(
        jnp.where(ok[:, None], out_flat[jnp.clip(slot, 0, e_local * cap2 - 1)], 0)
    )
    if cfg.moe_dispatch_int8:
        rq, rscale = q8(res)
        back_q = return_all_to_all(
            rq.reshape(ex.data.shape[0], ex.data.shape[1], d), ex.position, axis=axis)
        back_s = return_all_to_all(
            rscale.reshape(ex.data.shape[0], ex.data.shape[1], 1), ex.position, axis=axis)
        back = back_q.astype(jnp.float32) * back_s
    else:
        back = return_all_to_all(
            res.reshape(ex.data.shape[0], ex.data.shape[1], d), ex.position, axis=axis)
    y = jnp.sum(back.reshape(T, k, d).astype(jnp.float32)
                * weights[..., None].astype(jnp.float32), axis=1)
    return y.astype(x_tokens.dtype), ex.dropped


def _moe_gather_ep(p_local, cfg, x_tokens, weights, experts, axis: str, ep: int):
    """Gather-style EP for small token counts (decode): tokens are
    REPLICATED over the model axis; each rank computes only the assignments
    routed to its local experts and the partial outputs psum over the axis.
    Communication = one psum of [T, d] — cheaper than all_to_all when T is
    a decode batch."""
    T, d = x_tokens.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    e_local = E // ep
    r = jax.lax.axis_index(axis)
    flat_expert = experts.reshape(-1).astype(jnp.int32)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    mine = (flat_expert // e_local) == r
    local_e = jnp.where(mine, flat_expert % e_local, e_local)
    cap = max(8, int(2 * T * k // ep))
    payload = jnp.concatenate(
        [x_tokens[flat_tok], flat_tok.astype(x_tokens.dtype)[:, None]], axis=-1
    )
    gx, order, slot, ok = _bucket_local(payload, local_e, e_local, cap)
    out = _expert_ffn(p_local["w_gate"], p_local["w_up"], p_local["w_down"], gx[..., :d])
    tok_ids = gx[..., d].astype(jnp.int32).reshape(-1)
    out_flat = out.reshape(e_local * cap, d)
    gw = weights.reshape(-1)[order]
    valid_slots = jnp.zeros((e_local * cap + 1,), jnp.bool_).at[slot].set(ok, mode="drop")[:-1]
    contrib = jnp.where(valid_slots[:, None], out_flat, 0)
    # weight each slot by its router weight (scatter weights into slots)
    wslots = jnp.zeros((e_local * cap + 1,), weights.dtype).at[slot].set(
        jnp.where(ok, gw, 0), mode="drop"
    )[:-1]
    y_partial = jnp.zeros((T, d), x_tokens.dtype).at[tok_ids].add(
        (contrib * wslots[:, None]).astype(x_tokens.dtype), mode="drop"
    )
    dropped = jax.lax.psum(jnp.sum(mine & ~_in_capacity(local_e, e_local, cap)), axis)
    return jax.lax.psum(y_partial, axis), dropped


def _in_capacity(local_e, e_local, cap):
    order = jnp.argsort(local_e, stable=True)
    se = local_e[order]
    start = jnp.searchsorted(se, jnp.arange(e_local, dtype=se.dtype))
    rank = jnp.arange(se.shape[0], dtype=jnp.int32) - start[jnp.clip(se, 0, e_local - 1)]
    ok_sorted = rank < cap
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return ok_sorted[inv]


def moe_ffn(p, cfg, x: jnp.ndarray, dist: Optional[DistContext]) -> Tuple[jnp.ndarray, dict]:
    """Full MoE sublayer on [B, S, d].  Returns (y, aux)."""
    B, S, d = x.shape

    if dist is not None and dist.moe_dispatch == "alltoall":
        mesh = dist.mesh
        axis = "model"
        ep = mesh.shape[axis]
        assert cfg.num_experts % ep == 0, (cfg.num_experts, ep)
        dp_axes = tuple(a for a in mesh.axis_names if a != axis)
        use_a2a = S % ep == 0 and S >= ep  # decode (S=1): gather-EP instead
        aux_specs = {"lb_loss": P(), "z_loss": P(), "dropped": P()}

        if use_a2a:
            cap = int(
                cfg.moe_capacity_factor
                * (B // _axes_size(mesh, dp_axes)) * (S // ep) * cfg.experts_per_tok / ep
            ) + 8

            def per_shard(p_shard, xs):
                Bl, Sl, _ = xs.shape
                toks = xs.reshape(Bl * Sl, d)
                w, e, aux = _route(p_shard, cfg, toks)
                y, dropped = _moe_alltoall(p_shard, cfg, toks, w, e, axis, ep, cap)
                # reduce so P() out_specs replication is statically true:
                # lb/z vary over every axis (tokens sharded over data AND
                # model); dropped is already psum'd over `axis` inside the
                # exchange, so only the dp axes remain.
                aux = {**{k: jax.lax.pmean(v, tuple(mesh.axis_names))
                          for k, v in aux.items()},
                       "dropped": jax.lax.psum(dropped, dp_axes).astype(jnp.float32)}
                return y.reshape(Bl, Sl, d), aux

            x_spec = P(dp_axes, axis, None)
        else:

            def per_shard(p_shard, xs):
                Bl, Sl, _ = xs.shape
                toks = xs.reshape(Bl * Sl, d)
                w, e, aux = _route(p_shard, cfg, toks)
                y, dropped = _moe_gather_ep(p_shard, cfg, toks, w, e, axis, ep)
                # tokens are replicated over `axis` here: aux is invarying
                # over model already, dropped was psum'd over model inside
                aux = {**{k: jax.lax.pmean(v, dp_axes) for k, v in aux.items()},
                       "dropped": jax.lax.psum(dropped, dp_axes).astype(jnp.float32)}
                return y.reshape(Bl, Sl, d), aux

            x_spec = P(dp_axes, None, None)  # replicated over model axis

        specs_p = {
            "router": P(*(None,) * p["router"].ndim),
            "w_gate": _expert_spec(p["w_gate"], axis),
            "w_up": _expert_spec(p["w_up"], axis),
            "w_down": _expert_spec(p["w_down"], axis),
        }
        routed_p = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
        fn = shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(specs_p, x_spec),
            out_specs=(x_spec, aux_specs),
        )
        y, aux = fn(routed_p, x)
    else:
        toks = x.reshape(B * S, d)
        w, e, aux = _route(p, cfg, toks)
        y, dropped = _moe_dense(p, cfg, toks, w, e)
        aux = {**aux, "dropped": dropped}
        y = y.reshape(B, S, d)

    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + h @ sp["w_down"]
    return y, aux


def _expert_spec(w, axis: str) -> P:
    return P(axis, *(None,) * (w.ndim - 1))


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
