"""Encoder-decoder backbone (seamless-m4t family).

Encoder: bidirectional attention over precomputed frame embeddings (the
audio frontend is a STUB per the assignment — input_specs() supplies
[B, S_enc, d_model] features).  Decoder: causal self-attention +
cross-attention to the encoder output.  The decode cache holds self-attn
KV plus the cross KV computed ONCE at prefill.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import (
    _chunked_attention, apply_rope, decode_positions, embed, init_attention,
    init_embed, init_mlp, init_rmsnorm, init_unembed, mlp, rmsnorm, unembed,
)
from .nn import DistContext, ParamFactory, shard

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "dropped": 0}


def _init_cross(f, path, cfg, lead=()):
    # cross-attention reuses the attention parameter layout
    return init_attention(f, path, cfg, lead)


def init_params(cfg, f: ParamFactory):
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    return {
        "enc": {
            "ln1": init_rmsnorm(f, "enc/ln1", cfg.d_model, (Le,)),
            "attn": init_attention(f, "enc/attn", cfg, (Le,)),
            "ln2": init_rmsnorm(f, "enc/ln2", cfg.d_model, (Le,)),
            "mlp": init_mlp(f, "enc/mlp", cfg.d_model, cfg.d_ff, (Le,)),
        },
        "enc_ln_f": init_rmsnorm(f, "enc_ln_f", cfg.d_model),
        "embed": init_embed(f, "embed", cfg, cfg.d_model),
        "dec": {
            "ln1": init_rmsnorm(f, "dec/ln1", cfg.d_model, (Ld,)),
            "self_attn": init_attention(f, "dec/self_attn", cfg, (Ld,)),
            "ln_x": init_rmsnorm(f, "dec/ln_x", cfg.d_model, (Ld,)),
            "cross": _init_cross(f, "dec/cross", cfg, (Ld,)),
            "ln2": init_rmsnorm(f, "dec/ln2", cfg.d_model, (Ld,)),
            "mlp": init_mlp(f, "dec/mlp", cfg.d_model, cfg.d_ff, (Ld,)),
        },
        "ln_f": init_rmsnorm(f, "ln_f", cfg.d_model),
        "unembed": init_unembed(f, "unembed", cfg.d_model, cfg),
    }


def _self_attn(p, cfg, x, positions, dist, causal, kv_cache=None):
    from .layers import attention

    return attention(p, cfg, x, positions, dist, kv_cache=kv_cache, causal=causal)


def _cross_attn(p, cfg, x, enc_kv, dist):
    """x [B,S,d] attends (non-causally) to precomputed encoder K/V."""
    B, S, d = x.shape
    hd = cfg.hd
    Hq = cfg.num_heads
    q = (x @ p["wq"]).reshape(B, S, Hq, hd).transpose(0, 2, 1, 3)
    q = shard(q, ("batch", "heads", None, None), dist)
    out = _chunked_attention(
        q, enc_kv["k"], enc_kv["v"], causal=False, q_chunk=cfg.attn_q_chunk, dist=dist
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    return out @ p["wo"]


def encode(cfg, params, enc_embeds, dist):
    x = enc_embeds.astype(cfg.jdtype)
    Se = x.shape[1]
    positions = jnp.arange(Se)

    def body(x, p_l):
        h = rmsnorm(p_l["ln1"], x, cfg.norm_eps)
        a, _ = _self_attn(p_l["attn"], cfg, h, positions, dist, causal=False)
        x = shard(x + a, ("batch", "seq", None), dist)
        h = rmsnorm(p_l["ln2"], x, cfg.norm_eps)
        x = shard(x + mlp(p_l["mlp"], h, dist), ("batch", "seq", None), dist)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def _enc_kv(p_cross, cfg, enc_out):
    """Per-layer cross K/V from the encoder output (positions not roped —
    cross attention is position-free here)."""
    B, Se, d = enc_out.shape
    hd, Hkv = cfg.hd, cfg.num_kv_heads
    k = (enc_out @ p_cross["wk"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ p_cross["wv"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def _decoder(cfg, params, tokens, enc_out, dist, caches=None, positions=None):
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])

    def body(x, inp):
        p_l, cache_l = inp
        h = rmsnorm(p_l["ln1"], x, cfg.norm_eps)
        a, new_self = _self_attn(
            p_l["self_attn"], cfg, h, positions, dist, causal=True,
            kv_cache=None if cache_l is None else cache_l["self"],
        )
        x = shard(x + a, ("batch", "seq", None), dist)
        h = rmsnorm(p_l["ln_x"], x, cfg.norm_eps)
        if enc_out is not None:       # train / prefill: compute cross K/V now
            ekv = _enc_kv(p_l["cross"], cfg, enc_out)
        else:                         # decode: reuse the prefill-cached cross K/V
            ekv = cache_l["cross"]
        x = shard(x + _cross_attn(p_l["cross"], cfg, h, ekv, dist), ("batch", "seq", None), dist)
        h = rmsnorm(p_l["ln2"], x, cfg.norm_eps)
        x = shard(x + mlp(p_l["mlp"], h, dist), ("batch", "seq", None), dist)
        new_cache = None if cache_l is None else {"self": new_self, "cross": ekv}
        return x, new_cache

    body_fn = jax.checkpoint(body) if (cfg.remat == "block" and caches is None) else body
    x, new_caches = jax.lax.scan(body_fn, x, (params["dec"], caches))
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new_caches


def forward(cfg, params, batch, dist: Optional[DistContext] = None):
    """Train: batch = {enc_embeds [B,Se,d], tokens [B,Sd], labels [B,Sd]}."""
    enc_out = encode(cfg, params, batch["enc_embeds"], dist)
    x, _ = _decoder(cfg, params, batch["tokens"], enc_out, dist)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}


def init_cache(cfg, batch: int, max_len: int, mode: str = "init", enc_len: int = 0):
    Ld = cfg.num_layers
    hd = cfg.hd
    dt = cfg.jdtype
    enc_len = enc_len or max_len

    def make(shape, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype) if mode == "shape" else jnp.zeros(shape, dtype)

    return {
        "self": {
            "k": make((Ld, batch, cfg.num_kv_heads, max_len, hd)),
            "v": make((Ld, batch, cfg.num_kv_heads, max_len, hd)),
            "length": make((Ld,), jnp.int32),
        },
        "cross": {
            "k": make((Ld, batch, cfg.num_kv_heads, enc_len, hd)),
            "v": make((Ld, batch, cfg.num_kv_heads, enc_len, hd)),
        },
    }


def prefill(cfg, params, batch, cache, dist: Optional[DistContext] = None):
    """Encode + decoder prefill.  batch needs enc_embeds and tokens."""
    enc_out = encode(cfg, params, batch["enc_embeds"], dist)
    x, new_caches = _decoder(
        cfg, params, batch["tokens"], enc_out, dist, caches=cache
    )
    logits = unembed(params["unembed"], x[:, -1:], dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, new_caches


def decode_step(cfg, params, tokens, cache, dist: Optional[DistContext] = None):
    length = cache["self"]["length"][0]
    positions = decode_positions(length, tokens.shape[1])
    x, new_caches = _decoder(
        cfg, params, tokens, None, dist, caches=cache, positions=positions
    )
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, new_caches
