"""Mamba2 block (SSD — state-space duality, Dao & Gu 2024).

Chunked SSD forward: the sequence is cut into chunks of Q=cfg.ssm_chunk;
within a chunk the recurrence is computed as masked matmuls (MXU work),
across chunks a lax.scan carries the [H, P, N] state — O(S*Q) instead of
O(S^2) attention, which is why the ssm/hybrid archs are the only ones that
run the long_500k cell.

All decays are exp of non-positive numbers (A < 0, dt > 0), so the chunked
form is overflow-safe by construction.

State for decode: conv_state [B, channels, w-1] + ssm_state [B, H, P, N];
one decode step is O(d_in * (N + w)) — independent of context length, the
property the long_500k cell exercises.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .nn import DistContext, ParamFactory, shard
from .layers import rmsnorm


def _pick_chunk(S: int, Q: int) -> int:
    """Largest divisor of S that is <= Q (chunking is internal math: any
    divisor partitions the recurrence exactly).  Irregular S (tests) costs
    a bigger intra-chunk matmul, never correctness."""
    if S % Q == 0:
        return Q
    for q in range(min(Q, S), 0, -1):
        if S % q == 0:
            return q
    return S


def ssm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, H, conv_ch


def init_mamba2(f: ParamFactory, path: str, cfg, lead=()):
    d = cfg.d_model
    d_in, H, conv_ch = ssm_dims(cfg)
    N, w = cfg.ssm_state, cfg.ssm_conv
    la = ("layers",) * len(lead)
    proj_out = 2 * d_in + 2 * cfg.ssm_groups * N + H
    return {
        "w_in": f.param(f"{path}/w_in", (*lead, d, proj_out), (*la, "embed", "ff")),
        "conv_w": f.param(f"{path}/conv_w", (*lead, conv_ch, w), (*la, "ff", None), scale=0.5),
        "conv_b": f.param(f"{path}/conv_b", (*lead, conv_ch), (*la, "ff"), init="zeros"),
        "dt_bias": f.param(f"{path}/dt_bias", (*lead, H), (*la, "heads"), init="zeros"),
        "A_log": f.param(f"{path}/A_log", (*lead, H), (*la, "heads"), init="zeros"),
        "D": f.param(f"{path}/D", (*lead, H), (*la, "heads"), init="ones"),
        "norm": f.param(f"{path}/norm", (*lead, d_in), (*la, "ff"), init="ones"),
        "w_out": f.param(f"{path}/w_out", (*lead, d_in, d), (*la, "ff", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    d_in, H, _ = ssm_dims(cfg)
    GN = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * GN]
    dt = zxbcdt[..., 2 * d_in + 2 * GN :]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, state=None):
    """Depthwise causal conv along S.  xBC [B,S,C], conv_w [C,w].

    state [B, C, w-1] (previous inputs) for streaming; returns (out, new_state).
    """
    B, S, C = xBC.shape
    w = conv_w.shape[-1]
    xt = xBC.transpose(0, 2, 1)                               # [B, C, S]
    if state is None:
        pad = jnp.zeros((B, C, w - 1), xt.dtype)
    else:
        pad = state.astype(xt.dtype)
    full = jnp.concatenate([pad, xt], axis=-1)                # [B, C, S+w-1]
    out = jax.lax.conv_general_dilated(
        full,
        conv_w[:, None, :].astype(xt.dtype),                  # [C, 1, w] depthwise
        window_strides=(1,),
        padding="VALID",
        feature_group_count=C,
        dimension_numbers=("NCH", "OIH", "NCH"),
    ) + conv_b[None, :, None].astype(xt.dtype)
    new_state = full[..., -(w - 1):]
    return jax.nn.silu(out).transpose(0, 2, 1), new_state


def mamba2_forward(
    p, cfg, x: jnp.ndarray, dist: Optional[DistContext],
    *, initial_state=None, return_state: bool = False,
):
    """x [B,S,d] -> y [B,S,d].  S must be a multiple of ssm_chunk (pipeline
    pads).  If return_state, also returns (conv_state, ssm_state)."""
    B, S, d = x.shape
    d_in, H, conv_ch = ssm_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    Q = _pick_chunk(S, cfg.ssm_chunk)
    nC = S // Q

    zxbcdt = x @ p["w_in"]
    zxbcdt = shard(zxbcdt, ("batch", None, "ff"), dist)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    conv_in_state = initial_state[0] if initial_state is not None else None
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_in_state)
    xh = xBC[..., :d_in].reshape(B, S, H, P)
    Bm = xBC[..., d_in : d_in + N]                            # [B,S,N] (G=1)
    Cm = xBC[..., d_in + N :]                                 # [B,S,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [H]
    dA = dt * A                                               # [B,S,H] (<= 0)

    # chunk views
    xc = xh.reshape(B, nC, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nC, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nC, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nC, Q, H)
    dAc = dA.reshape(B, nC, Q, H)
    cum = jnp.cumsum(dAc, axis=2)                             # [B,c,Q,H]

    # --- intra-chunk (quadratic within Q) ---
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,c,Q,Q]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,c,i,j,H]
    ii = jnp.arange(Q)
    mask = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    att = CB[..., None] * jnp.where(mask, decay, 0.0)         # [B,c,i,j,H]
    xdt = xc * dtc[..., None]                                 # [B,c,Q,H,P]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xdt)

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # [B,c,Q,H]
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xdt)  # [B,c,H,P,N]

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # [B,c,H]
    s0 = (
        initial_state[1].astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def scan_body(s_prev, inp):
        st, dec = inp                                         # [B,H,P,N], [B,H]
        s_next = dec[..., None, None] * s_prev + st
        return s_next, s_prev

    s_last, s_prevs = jax.lax.scan(
        scan_body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                # [B,c,H,P,N]
    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, s_prevs) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(B, S, H, P) + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))                # gated
    y = rmsnorm({"scale": p["norm"]}, y.astype(x.dtype), cfg.norm_eps)
    out = y @ p["w_out"]
    if return_state:
        return out, (conv_state, s_last.astype(x.dtype))
    return out


def mamba2_step(p, cfg, x: jnp.ndarray, state) -> Tuple[jnp.ndarray, Tuple]:
    """One decode step.  x [B,1,d]; state = (conv_state [B,C,w-1],
    ssm_state [B,H,P,N]).  O(1) in context length."""
    B = x.shape[0]
    d_in, H, conv_ch = ssm_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    conv_state, s = state

    zxbcdt = x @ p["w_in"]                                    # [B,1,*]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xh = xBC[:, 0, :d_in].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC[:, 0, d_in : d_in + N].astype(jnp.float32)       # [B,N]
    Cm = xBC[:, 0, d_in + N :].astype(jnp.float32)            # [B,N]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                      # [B,H]

    s = s.astype(jnp.float32)
    s_new = dA[..., None, None] * s + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, s_new) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm({"scale": p["norm"]}, y.astype(x.dtype), cfg.norm_eps)
    return y @ p["w_out"], (conv_state, s_new.astype(x.dtype))


def init_ssm_state(cfg, batch: int, factory_mode: str = "init", dtype=None):
    d_in, H, conv_ch = ssm_dims(cfg)
    dtype = dtype or cfg.jdtype
    shapes = {
        "conv": ((batch, conv_ch, cfg.ssm_conv - 1), dtype),
        "ssm": ((batch, H, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    }
    if factory_mode == "shape":
        return tuple(jax.ShapeDtypeStruct(s, d) for s, d in shapes.values())
    return tuple(jnp.zeros(s, d) for s, d in shapes.values())
