"""Composable LM stack for the ten assigned architectures.

Families: dense (GQA), moe (top-k routed + shared experts, MLA optional),
ssm (Mamba2 SSD), hybrid (Zamba2), encdec (Seamless backbone), vlm (LLaVA
backbone).  All models share the same protocol (models.registry):

  init_params(cfg, factory)                -> params pytree (+ recorded specs)
  forward(cfg, params, batch, dist)        -> logits          (train path)
  init_cache(cfg, batch, max_len, factory) -> decode cache
  prefill(cfg, params, batch, cache, dist) -> (logits, cache)
  decode_step(cfg, params, tokens, cache, dist) -> (logits, cache)
"""
