"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

54 mamba layers in 9 groups of 6; after each group the *same* transformer
block (attention + MLP, one weight copy — Zamba2's parameter-sharing trick)
is applied.  Each of the 9 call sites keeps its own KV cache (the weights
are shared, the activations are not).

Implementation: outer lax.scan over the 9 groups (mamba params stacked
[9, 6, ...], site caches stacked [9, ...]); inner scan over the 6 mamba
layers.  The shared block's params are closure captures — scan-invariant,
hoisted by XLA, the in-memory footprint of exactly one block.

Simplifications vs the HF checkpoint (recorded in DESIGN.md): no per-site
LoRA adapters on the shared block, and no concatenation of the original
embedding into the shared-block input.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import (
    attention, decode_positions, embed, init_attention, init_embed, init_mlp,
    init_rmsnorm, init_unembed, mlp, rmsnorm, unembed,
)
from .nn import DistContext, ParamFactory, shard
from .ssm import init_mamba2, init_ssm_state, mamba2_forward, mamba2_step

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "dropped": 0}


def _groups(cfg):
    every = cfg.shared_attn_every
    assert cfg.num_layers % every == 0, (cfg.num_layers, every)
    return cfg.num_layers // every, every


def init_params(cfg, f: ParamFactory):
    n_groups, every = _groups(cfg)
    return {
        "embed": init_embed(f, "embed", cfg, cfg.d_model),
        "mamba": {
            "ln": init_rmsnorm(f, "mamba/ln", cfg.d_model, (n_groups, every)),
            "mix": init_mamba2(f, "mamba/mix", cfg, (n_groups, every)),
        },
        "shared": {
            "ln1": init_rmsnorm(f, "shared/ln1", cfg.d_model),
            "attn": init_attention(f, "shared/attn", cfg),
            "ln2": init_rmsnorm(f, "shared/ln2", cfg.d_model),
            "mlp": init_mlp(f, "shared/mlp", cfg.d_model, cfg.d_ff),
        },
        "ln_f": init_rmsnorm(f, "ln_f", cfg.d_model),
        "unembed": init_unembed(f, "unembed", cfg.d_model, cfg),
    }


def _shared_block(p, cfg, x, positions, dist, cache=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = attention(p["attn"], cfg, h, positions, dist, kv_cache=cache)
    x = shard(x + a, ("batch", "seq", None), dist)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = shard(x + mlp(p["mlp"], h, dist), ("batch", "seq", None), dist)
    return x, new_cache


def _mamba_layer_fwd(cfg, dist, collect_state: bool):
    def fn(x, p_l, state_l):
        h = rmsnorm(p_l["ln"], x, cfg.norm_eps)
        if collect_state:
            out, new_state = mamba2_forward(
                p_l["mix"], cfg, h, dist, initial_state=state_l, return_state=True
            )
            return x + out, new_state
        return x + mamba2_forward(p_l["mix"], cfg, h, dist), None

    return fn


def forward(cfg, params, batch, dist: Optional[DistContext] = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)
    positions = jnp.arange(S)
    layer_fwd = _mamba_layer_fwd(cfg, dist, collect_state=False)
    shared = params["shared"]

    def inner(x, p_l):
        x, _ = layer_fwd(x, p_l, None)
        return x, None

    def outer(x, p_g):
        x, _ = jax.lax.scan(inner, x, p_g)
        x, _ = _shared_block(shared, cfg, x, positions, dist, None)
        return x, None

    outer_fn = jax.checkpoint(outer) if cfg.remat == "block" else outer
    x, _ = jax.lax.scan(outer_fn, x, params["mamba"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, mode: str = "init"):
    n_groups, every = _groups(cfg)
    dt = cfg.jdtype
    hd = cfg.hd

    def make(shape, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype) if mode == "shape" else jnp.zeros(shape, dtype)

    conv, ssm = init_ssm_state(cfg, batch, "shape")
    def stack_state(s):
        return make((n_groups, every, *s.shape), s.dtype)

    return {
        "mamba": (stack_state(conv), stack_state(ssm)),
        "sites": {
            "k": make((n_groups, batch, cfg.num_kv_heads, max_len, hd)),
            "v": make((n_groups, batch, cfg.num_kv_heads, max_len, hd)),
            "length": make((n_groups,), jnp.int32),
        },
    }


def _run_cached(cfg, params, tokens, cache, dist, positions):
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)
    B, S = tokens.shape
    decode = S == 1
    layer_fwd = _mamba_layer_fwd(cfg, dist, collect_state=True)
    shared = params["shared"]

    def inner(carry, inp):
        x = carry
        p_l, state_l = inp
        if decode:
            h = rmsnorm(p_l["ln"], x, cfg.norm_eps)
            out, new_state = mamba2_step(p_l["mix"], cfg, h, state_l)
            return x + out, new_state
        x, new_state = layer_fwd(x, p_l, state_l)
        return x, new_state

    def outer(x, inp):
        p_g, state_g, site_cache = inp
        x, new_states = jax.lax.scan(inner, x, (p_g, state_g))
        x, new_site = _shared_block(shared, cfg, x, positions, dist, site_cache)
        return x, (new_states, new_site)

    x, (new_mamba, new_sites) = jax.lax.scan(
        outer, x, (params["mamba"], cache["mamba"], cache["sites"])
    )
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, {"mamba": new_mamba, "sites": new_sites}


def prefill(cfg, params, batch, cache, dist: Optional[DistContext] = None):
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x, new_cache = _run_cached(cfg, params, tokens, cache, dist, positions)
    logits = unembed(params["unembed"], x[:, -1:], dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, new_cache


def decode_step(cfg, params, tokens, cache, dist: Optional[DistContext] = None):
    length = cache["sites"]["length"][0]
    positions = decode_positions(length, tokens.shape[1])
    x, new_cache = _run_cached(cfg, params, tokens, cache, dist, positions)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, new_cache
