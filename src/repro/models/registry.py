"""Family -> model implementation dispatch + input specs per (arch, shape).

input_specs() produces either real random batches (mode="init", smoke
tests/examples) or ShapeDtypeStructs (mode="shape", dry-run: nothing is
allocated — the assignment's requirement that FULL configs are exercised
only via lowering).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from . import encdec, hybrid, ssm_lm, transformer, vlm
from .nn import ParamFactory


class ModelApi(NamedTuple):
    init_params: Callable
    forward: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable


_FAMILIES: Dict[str, ModelApi] = {
    "dense": ModelApi(transformer.init_params, transformer.forward,
                      transformer.init_cache, transformer.prefill, transformer.decode_step),
    "moe": ModelApi(transformer.init_params, transformer.forward,
                    transformer.init_cache, transformer.prefill, transformer.decode_step),
    "ssm": ModelApi(ssm_lm.init_params, ssm_lm.forward,
                    ssm_lm.init_cache, ssm_lm.prefill, ssm_lm.decode_step),
    "hybrid": ModelApi(hybrid.init_params, hybrid.forward,
                       hybrid.init_cache, hybrid.prefill, hybrid.decode_step),
    "encdec": ModelApi(encdec.init_params, encdec.forward,
                       encdec.init_cache, encdec.prefill, encdec.decode_step),
    "vlm": ModelApi(vlm.init_params, vlm.forward,
                    vlm.init_cache, vlm.prefill, vlm.decode_step),
}


def get_model(cfg: ModelConfig) -> ModelApi:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# input specs per (arch, shape)
# ---------------------------------------------------------------------------


def _mk(shape, dtype, mode, rng, high=None):
    if mode == "shape":
        return jax.ShapeDtypeStruct(shape, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(0, high or 2, size=shape), dtype)
    return jnp.asarray(rng.standard_normal(shape) * 0.02, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mode: str = "shape", seed: int = 0):
    """Batch pytree for one cell.

    train  -> {tokens, labels} (+ enc_embeds / patch_embeds per family)
    prefill-> {tokens} (+ family extras)
    decode -> {tokens [B,1]}  (the KV cache is a separate argument)
    """
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    V = cfg.vocab_size
    dt = cfg.jdtype
    batch: Dict[str, Any] = {}

    if shape.kind == "decode":
        batch["tokens"] = _mk((B, 1), jnp.int32, mode, rng, V)
        return batch

    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        batch["tokens"] = _mk((B, S - n_img), jnp.int32, mode, rng, V)
        batch["patch_embeds"] = _mk((B, n_img, cfg.d_model), dt, mode, rng)
    elif cfg.family == "encdec":
        batch["tokens"] = _mk((B, S), jnp.int32, mode, rng, V)
        batch["enc_embeds"] = _mk((B, S, cfg.d_model), dt, mode, rng)
    else:
        batch["tokens"] = _mk((B, S), jnp.int32, mode, rng, V)

    if shape.kind == "train":
        if cfg.family == "vlm":
            # image positions carry label -100 (masked); text shifts by one
            lab = _mk((B, S), jnp.int32, mode, rng, V)
            batch["labels"] = lab
        else:
            batch["labels"] = _mk(
                (B, S) if cfg.family != "encdec" else (B, S), jnp.int32, mode, rng, V
            )
    return batch


def init_all(cfg: ModelConfig, mode: str = "init", seed: int = 0):
    """(params, factory-with-specs) for a config."""
    f = ParamFactory(mode=mode, key=jax.random.PRNGKey(seed), dtype=cfg.jdtype)
    params = get_model(cfg).init_params(cfg, f)
    return params, f
