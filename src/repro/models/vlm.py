"""VLM backbone (llava-next-mistral family): patch embeddings + causal LM.

The vision frontend (CLIP-L/336 + anyres tiling + projector) is a STUB per
the assignment: input_specs() supplies precomputed patch embeddings
[B, num_image_tokens, d_model].  The model prepends them to the token
embeddings and runs the standard dense decoder (transformer.py); loss masks
the image positions (labels < 0).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from . import transformer
from .layers import embed, unembed
from .nn import DistContext, ParamFactory


def init_params(cfg, f: ParamFactory):
    return transformer.init_params(cfg, f)


def _splice(cfg, params, batch, dist):
    """[patch_embeds | token_embeds] -> x [B, n_img + S_text, d]."""
    tok = embed(params["embed"], batch["tokens"], dist).astype(cfg.jdtype)
    patches = batch["patch_embeds"].astype(cfg.jdtype)
    return jnp.concatenate([patches, tok], axis=1)


def forward(cfg, params, batch, dist: Optional[DistContext] = None):
    x = _splice(cfg, params, batch, dist)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    aux0 = {k: jnp.asarray(v, jnp.float32) for k, v in transformer.ZERO_AUX.items()}
    aux = aux0
    from .layers import rmsnorm

    for p_l in params["prefix"]:
        x, _, a = transformer._block(p_l, cfg, x, positions, dist, None, moe=False)
    x, aux, _ = transformer._scan_blocks(params, cfg, x, positions, dist, None)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, aux


def init_cache(cfg, batch: int, max_len: int, mode: str = "init"):
    return transformer.init_cache(cfg, batch, max_len, mode)


def prefill(cfg, params, batch, cache, dist: Optional[DistContext] = None):
    """Prompt = image patches + text tokens; fills the cache with both."""
    x = _splice(cfg, params, batch, dist)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    from .layers import rmsnorm

    new_prefix = []
    for p_l, c_l in zip(params["prefix"], cache["prefix"]):
        x, nc, _ = transformer._block(p_l, cfg, x, positions, dist, c_l, moe=False)
        new_prefix.append(nc)
    x, _, new_blocks = transformer._scan_blocks(params, cfg, x, positions, dist, cache["blocks"])
    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


def decode_step(cfg, params, tokens, cache, dist: Optional[DistContext] = None):
    return transformer.decode_step(cfg, params, tokens, cache, dist)
