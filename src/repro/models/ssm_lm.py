"""Pure Mamba2 LM (mamba2-780m family): attention-free, O(1)-state decode."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import embed, init_embed, init_rmsnorm, init_unembed, rmsnorm, unembed
from .nn import DistContext, ParamFactory
from .ssm import init_mamba2, init_ssm_state, mamba2_forward, mamba2_step

ZERO_AUX = {"lb_loss": 0.0, "z_loss": 0.0, "dropped": 0}


def init_params(cfg, f: ParamFactory):
    L = cfg.num_layers
    return {
        "embed": init_embed(f, "embed", cfg, cfg.d_model),
        "layers": {
            "ln": init_rmsnorm(f, "layers/ln", cfg.d_model, (L,)),
            "mix": init_mamba2(f, "layers/mix", cfg, (L,)),
        },
        "ln_f": init_rmsnorm(f, "ln_f", cfg.d_model),
        "unembed": init_unembed(f, "unembed", cfg.d_model, cfg),
    }


def forward(cfg, params, batch, dist: Optional[DistContext] = None):
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)

    def body(x, p_l):
        h = rmsnorm(p_l["ln"], x, cfg.norm_eps)
        return x + mamba2_forward(p_l["mix"], cfg, h, dist), None

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {k: jnp.asarray(v, jnp.float32) for k, v in ZERO_AUX.items()}


def init_cache(cfg, batch: int, max_len: int, mode: str = "init"):
    """SSM 'cache' = per-layer (conv_state, ssm_state) + position counter.

    Note max_len never appears: decode state is O(1) in context length —
    this is why the long_500k cell is an SSM/hybrid-only shape."""
    L = cfg.num_layers
    conv, ssm = init_ssm_state(cfg, batch, "shape")

    def make(s, d):
        return jax.ShapeDtypeStruct(s, d) if mode == "shape" else jnp.zeros(s, d)

    return {
        "states": (make((L, *conv.shape), conv.dtype), make((L, *ssm.shape), ssm.dtype)),
        "length": make((), jnp.int32),
    }


def prefill(cfg, params, batch, cache, dist: Optional[DistContext] = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)

    def body(x, inp):
        p_l, st_l = inp
        h = rmsnorm(p_l["ln"], x, cfg.norm_eps)
        out, new_state = mamba2_forward(
            p_l["mix"], cfg, h, dist, initial_state=st_l, return_state=True
        )
        return x + out, new_state

    x, new_states = jax.lax.scan(body, x, (params["layers"], cache["states"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x[:, -1:], dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {"states": new_states, "length": cache["length"] + S}


def decode_step(cfg, params, tokens, cache, dist: Optional[DistContext] = None):
    x = embed(params["embed"], tokens, dist).astype(cfg.jdtype)

    def body(x, inp):
        p_l, st_l = inp
        h = rmsnorm(p_l["ln"], x, cfg.norm_eps)
        out, new_state = mamba2_step(p_l["mix"], cfg, h, st_l)
        return x + out, new_state

    x, new_states = jax.lax.scan(body, x, (params["layers"], cache["states"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["unembed"], x, dist, fp32=cfg.logits_fp32, valid_vocab=cfg.vocab_size)
    return logits, {"states": new_states, "length": cache["length"] + 1}
