"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, GQA attention (+bias), MLA.

Conventions:
  activations [B, S, d];  attention tensors [B, H, S, hd];
  params are nested dicts from ParamFactory (one definition site per param);
  every activation that crosses a layer boundary passes through
  nn.shard(...) with *logical* axes so the mesh mapping is swappable.

Attention is the blockwise-XLA implementation (lax.map over query chunks,
full-row softmax per chunk) — memory O(bq * S) instead of O(S^2), which is
what lets the 32k-prefill cells compile inside 16 GB HBM.  The Pallas flash
kernel (kernels/flash_attention.py) is the TPU fast path behind the same
call signature (dist.attn_mode).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .nn import DistContext, ParamFactory, shard


# ---------------------------------------------------------------------------
# norms / rope / mlp
# ---------------------------------------------------------------------------


def init_rmsnorm(f: ParamFactory, path: str, d: int, lead=()):
    lead_axes = ("layers",) * len(lead)
    return {"scale": f.param(f"{path}/scale", (*lead, d), (*lead_axes, None), init="ones")}


def rmsnorm(p, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)).astype(dt)


def decode_positions(length: jnp.ndarray, S: int) -> jnp.ndarray:
    """Absolute positions of S new tokens given cache length (scalar or [B])."""
    if jnp.ndim(length) == 1:
        return length[:, None] + jnp.arange(S)[None, :]
    return length + jnp.arange(S)


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """NeoX/llama half-rotation RoPE.  x [B, H, S, hd], positions [S] or [B,S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    if angles.ndim == 2:                                # [S, hd/2] -> broadcast
        angles = angles[None, None]
    else:                                               # [B, S, hd/2]
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_mlp(f: ParamFactory, path: str, d: int, ff: int, lead=()):
    la = ("layers",) * len(lead)
    return {
        "w_gate": f.param(f"{path}/w_gate", (*lead, d, ff), (*la, "embed", "ff")),
        "w_up": f.param(f"{path}/w_up", (*lead, d, ff), (*la, "embed", "ff")),
        "w_down": f.param(f"{path}/w_down", (*lead, ff, d), (*la, "ff", "embed")),
    }


def mlp(p, x: jnp.ndarray, dist: Optional[DistContext]) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, ("batch", None, "ff"), dist)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(f: ParamFactory, path: str, cfg, lead=()):
    d, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    la = ("layers",) * len(lead)
    p = {
        "wq": f.param(f"{path}/wq", (*lead, d, Hq * hd), (*la, "embed", "heads")),
        "wk": f.param(f"{path}/wk", (*lead, d, Hkv * hd), (*la, "embed", "heads")),
        "wv": f.param(f"{path}/wv", (*lead, d, Hkv * hd), (*la, "embed", "heads")),
        "wo": f.param(f"{path}/wo", (*lead, Hq * hd, d), (*la, "heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = f.param(f"{path}/bq", (*lead, Hq * hd), (*la, "heads"), init="zeros")
        p["bk"] = f.param(f"{path}/bk", (*lead, Hkv * hd), (*la, "heads"), init="zeros")
        p["bv"] = f.param(f"{path}/bv", (*lead, Hkv * hd), (*la, "heads"), init="zeros")
    return p


def _chunked_attention(q, k, v, *, causal: bool, q_chunk: int, dist, offset=None) -> jnp.ndarray:
    """Blockwise GQA attention.

    q [B,Hq,Sq,hd], k [B,Hkv,Skv,hd], v [B,Hkv,Skv,hdv] -> [B,Hq,Sq,hdv].

    lax.map over query chunks keeps live logits at [B,Hq,bq,Skv] f32; the
    grouped einsum avoids materializing repeated KV.  `offset` anchors query
    positions: query i sits at absolute position offset+i and may attend to
    kpos <= offset+i.  offset may be a traced scalar (decode: cache length);
    default Skv-Sq (plain causal / last-Sq-queries).  Entries of k/v beyond
    the valid prefix are masked by the same inequality, so cache buffers can
    be passed whole.
    """
    if dist is not None and dist.attn_mode != "xla" and q.shape[-1] == v.shape[-1]:
        return kops.flash_attention(q, k, v, causal=causal, mode=dist.attn_mode)
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    group = Hq // Hkv
    scale = 1.0 / (hd ** 0.5)
    if offset is None:
        offset = Skv - Sq
    qg = q.reshape(B, Hkv, group, Sq, hd)

    bq = min(q_chunk, Sq)
    if Sq % bq != 0:
        bq = Sq  # irregular lengths: single chunk
    nq = Sq // bq

    offset = jnp.asarray(offset)

    def chunk(qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=3)
        logits = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qc, k, preferred_element_type=jnp.float32
        ) * scale
        if causal:
            kpos = jnp.arange(Skv)
            base = qi * bq + jnp.arange(bq)
            if offset.ndim == 0:
                qpos = base + offset                      # [bq]
                mask = kpos[None, :] <= qpos[:, None]     # [bq, Skv]
            else:                                         # per-sequence offsets [B]
                qpos = offset[:, None] + base[None, :]    # [B, bq]
                mask = (kpos[None, None, :] <= qpos[:, :, None])[:, None, None]  # [B,1,1,bq,Skv]
            logits = jnp.where(mask, logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhgqk,bhkd->bhgqd", w, v, preferred_element_type=jnp.float32).astype(q.dtype)

    if nq == 1:
        out = chunk(0)
    else:
        mapped = jax.lax.map(chunk, jnp.arange(nq))     # [nq, B, Hkv, group, bq, hdv]
        out = jnp.moveaxis(mapped, 0, 3)                # [B, Hkv, group, nq, bq, hdv]
    return out.reshape(B, Hq, Sq, hdv)


def attention(
    p, cfg, x: jnp.ndarray, positions: jnp.ndarray, dist,
    *, kv_cache=None, causal: bool = True,
):
    """Full attention sublayer.  x [B,S,d].

    kv_cache: None (train) or dict {k, v: [B,Hkv,Smax,hd], length: int32} —
    new K/V are written at [length, length+S) and attention runs against the
    whole valid prefix (decode: S=1).
    Returns (out [B,S,d], updated kv_cache or None).
    """
    B, S, d = x.shape
    hd = cfg.hd
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    q = shard(q, ("batch", "heads", None, None), dist)
    k = shard(k, ("batch", "kv_heads", None, None), dist)
    v = shard(v, ("batch", "kv_heads", None, None), dist)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        length = kv_cache["length"]
        if jnp.ndim(length) == 1:    # per-sequence lengths [B] (serve engine)
            upd = jax.vmap(lambda buf, new, st:
                           jax.lax.dynamic_update_slice_in_dim(buf, new, st, axis=1))
            kf = upd(kv_cache["k"], k, length)
            vf = upd(kv_cache["v"], v, length)
        else:
            kf = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, length, axis=2)
            vf = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, length, axis=2)
        new_cache = {"k": kf, "v": vf, "length": length + S}
        # attend over the whole buffer; offset=length masks the unwritten tail
        out = _chunked_attention(
            q, kf, vf, causal=True, q_chunk=cfg.attn_q_chunk, dist=dist, offset=length
        )
    else:
        out = _chunked_attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, dist=dist)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(f: ParamFactory, path: str, cfg, lead=()):
    d = cfg.d_model
    H = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    la = ("layers",) * len(lead)
    return {
        "wq": f.param(f"{path}/wq", (*lead, d, H * qk), (*la, "embed", "heads")),
        "w_dkv": f.param(f"{path}/w_dkv", (*lead, d, cfg.kv_lora_rank), (*la, "embed", None)),
        "w_kr": f.param(f"{path}/w_kr", (*lead, d, cfg.qk_rope_dim), (*la, "embed", None)),
        "kv_norm": f.param(f"{path}/kv_norm", (*lead, cfg.kv_lora_rank), (*la, None), init="ones"),
        "w_uk": f.param(f"{path}/w_uk", (*lead, cfg.kv_lora_rank, H * cfg.qk_nope_dim), (*la, None, "heads")),
        "w_uv": f.param(f"{path}/w_uv", (*lead, cfg.kv_lora_rank, H * cfg.v_head_dim), (*la, None, "heads")),
        "wo": f.param(f"{path}/wo", (*lead, H * cfg.v_head_dim, d), (*la, "heads", "embed")),
    }


def mla_attention(p, cfg, x: jnp.ndarray, positions: jnp.ndarray, dist, *, kv_cache=None):
    """MLA: KV compressed to [B,S,kv_lora] + shared rope key [B,S,qk_rope].

    The cache stores ONLY (c_kv, k_rope): 512+64 floats/token instead of
    2*H*hd=4096 — the paper-config's 6.4x KV-cache compression.  This
    implementation decompresses per block (naive); the absorbed-matmul
    decode variant is a §Perf optimization candidate.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rope_d, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, nope + rope_d).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm({"scale": p["kv_norm"]}, x @ p["w_dkv"], cfg.norm_eps)  # [B,S,R]
    k_rope = apply_rope((x @ p["w_kr"])[:, None], positions, cfg.rope_theta)  # [B,1,S,rope_d]

    new_cache = None
    if kv_cache is not None:
        length = kv_cache["length"]
        if jnp.ndim(length) == 1:    # per-sequence lengths [B] (serve engine)
            c_full = jax.vmap(lambda buf, new, st:
                              jax.lax.dynamic_update_slice_in_dim(buf, new, st, axis=0)
                              )(kv_cache["c_kv"], c_kv, length)
            kr_full = jax.vmap(lambda buf, new, st:
                               jax.lax.dynamic_update_slice_in_dim(buf, new, st, axis=1)
                               )(kv_cache["k_rope"], k_rope, length)
        else:
            c_full = jax.lax.dynamic_update_slice_in_dim(kv_cache["c_kv"], c_kv, length, axis=1)
            kr_full = jax.lax.dynamic_update_slice_in_dim(kv_cache["k_rope"], k_rope, length, axis=2)
        new_cache = {"c_kv": c_full, "k_rope": kr_full, "length": length + S}
        c_kv, k_rope = c_full, kr_full
        valid_len = length + S
    else:
        valid_len = None

    Sk = c_kv.shape[1]
    k_nope = (c_kv @ p["w_uk"]).reshape(B, Sk, H, nope).transpose(0, 2, 1, 3)
    v = (c_kv @ p["w_uv"]).reshape(B, Sk, H, vh).transpose(0, 2, 1, 3)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, H, Sk, rope_d))], axis=-1)
    k = shard(k, ("batch", "heads", None, None), dist)
    v = shard(v, ("batch", "heads", None, None), dist)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    qfull = shard(qfull, ("batch", "heads", None, None), dist)
    offset = None if valid_len is None else valid_len - S
    out = _chunked_attention(
        qfull, k, v, causal=True, q_chunk=cfg.attn_q_chunk, dist=dist, offset=offset
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vh)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embed(f: ParamFactory, path: str, cfg, d: int):
    vocab = cfg.vocab_padded if hasattr(cfg, "vocab_padded") else cfg
    return {
        "tokens": f.param(f"{path}/tokens", (vocab, d), ("vocab", "embed"), init="embed", scale=0.02),
    }


def embed(p, tokens: jnp.ndarray, dist) -> jnp.ndarray:
    out = jnp.take(p["tokens"], tokens, axis=0)
    return shard(out, ("batch", "seq", None), dist)


def init_unembed(f: ParamFactory, path: str, d: int, cfg):
    vocab = cfg.vocab_padded if hasattr(cfg, "vocab_padded") else cfg
    return {"w": f.param(f"{path}/w", (d, vocab), ("embed", "vocab"))}


def unembed(p, x: jnp.ndarray, dist, fp32: bool = True,
            valid_vocab: int = 0) -> jnp.ndarray:
    w = p["w"]
    if fp32:
        x, w = x.astype(jnp.float32), w.astype(jnp.float32)
    logits = x @ w
    if valid_vocab and valid_vocab < w.shape[-1]:
        # vocab-padding mask (elementwise: no resharding of the vocab dim)
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < valid_vocab, logits, jnp.asarray(-1e9, logits.dtype))
    return shard(logits, ("batch", None, "vocab"), dist)
