from .loader import ExternalWalkLoader, LoaderConfig, WalkLoader  # noqa: F401
from .walks import (  # noqa: F401
    concat_bucket_csr,
    distributed_walks,
    external_walks,
    host_walks,
    walks_to_tokens,
)
