from .loader import LoaderConfig, WalkLoader  # noqa: F401
from .walks import distributed_walks, host_walks, walks_to_tokens  # noqa: F401
