"""Random-walk corpus generation over generated graphs.

Three samplers over the same CSR:

  host_walks            numpy, sequential-access host sampler (the oracle,
                        and the loader's default on one host)
  distributed_walks     shard_map sampler where walkers MIGRATE between
                        shards with the paper's k:1 scatter-gather
                        (capacity_all_to_all): at every step each walker is
                        shipped to the shard that owns its current vertex
                        (the paper's "a core owns its range's vertices"),
                        which advances it one hop from its LOCAL CSR rows.
                        This is the redistribute phase run once per walk
                        step — the generator's communication machinery
                        reused verbatim by the training-data subsystem.
  external_walks        out-of-core sampler over the disk tier's CSR bucket
                        files: walker frontiers live in per-bucket BlockStore
                        runs; every hop external-sorts the frontier by
                        current vertex, sort-merge-joins it against the owned
                        bucket's offv/adjv (MonotoneLookup + a forward adjv
                        scan), and partitions the advanced walkers to their
                        new owner bucket (core/phases.py walk kernels).  The
                        CSR never materializes in RAM — peak resident rows
                        are O(chunk_edges), independent of graph size.

Walk semantics — the shared RNG contract, bit-identical across all three
samplers:

  * counter-based RNG keyed by (seed, walker_id, step): the value drawn for
    walker w at step t is hostgen.walk_rand_np(seed, w, t) (uint32), so a
    walk depends only on its id and seed, never on which sampler, shard,
    bucket, or process advanced it;
  * start vertex = start_vertex(seed, w, n) (the same counter RNG at step 0,
    salted with 0xA5A5);
  * a walker at a sink vertex (deg 0) teleports to rand % n, otherwise it
    follows adjv[offv[pos] + rand % deg] — within-row adjacency ORDER is
    therefore part of the contract: samplers agree bit-for-bit only on the
    same CSR layout (host vs external comparisons must assemble the host
    CSR from the same bucket files, see concat_bucket_csr).

Dtype contract: walk histories are int64 on the host side — host_walks and
external_walks emit int64 end-to-end, so vertex ids past 2**31 survive
round-tripping.  distributed_walks computes in cfg.vertex_dtype on device
(int32 by default; set vertex_dtype=int64 under jax x64 for larger graphs —
it refuses configs whose n overflows the dtype).  Tokenization:
token = vertex % vocab (stable, vocabulary-bounded).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..core.blockstore import IOLedger, MemoryGauge
from ..core.hostgen import mix32_np as _mix32_np
from ..core.hostgen import walk_rand_np, walk_start_np
from ..core.corpus import ShardedWalks
from ..core.phases import (
    _KERNELS,
    PhaseOrchestrator,
    PlainCfg,
    WalkCfg,
    drive_walks,
    plain_config,
    result_config_key,
)
from ..core.transport import make_transport
from ..core.types import GraphConfig, owner_of
from ..distributed.collectives import capacity_all_to_all, pvary, shard_map


def _mix32_jnp(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


# The numpy walk RNG lives in core/hostgen (jax-free, importable by worker
# processes running the external walk kernels); alias it so all samplers
# visibly share one stream.
_walk_rand_np = walk_rand_np


def _walk_rand_jnp(seed: int, walker: jnp.ndarray, step) -> jnp.ndarray:
    s = jnp.uint32(seed & 0xFFFFFFFF)
    stepc = jnp.uint32(step) * jnp.uint32(0x9E3779B9)
    return _mix32_jnp(_mix32_jnp(walker.astype(jnp.uint32) ^ s) + stepc)


def start_vertex(seed: int, walker: np.ndarray, n_or_B: int, base: int = 0,
                 dtype=None):
    """Deterministic start vertex of a walker (shared by all samplers).
    Numpy inputs follow the int64 history contract; jnp inputs take the
    device vertex dtype (`dtype`, default int32)."""
    if isinstance(walker, np.ndarray):
        return walk_start_np(seed, walker, n_or_B, base)
    dtype = jnp.int32 if dtype is None else dtype
    return (base + (_walk_rand_jnp(seed ^ 0xA5A5, walker, 0)
                    % jnp.uint32(n_or_B))).astype(dtype)


# ---------------------------------------------------------------------------
# host oracle
# ---------------------------------------------------------------------------


def host_walks(offv: np.ndarray, adjv: np.ndarray, starts: np.ndarray,
               length: int, seed: int, n: Optional[int] = None,
               walker_ids: Optional[np.ndarray] = None) -> np.ndarray:
    """[W, length+1] vertex walks.  starts [W]."""
    n = n if n is not None else offv.shape[0] - 1
    W = starts.shape[0]
    wid = (walker_ids if walker_ids is not None
           else np.arange(W)).astype(np.uint32)
    pos = starts.astype(np.int64).copy()
    hist = np.zeros((W, length + 1), np.int64)
    hist[:, 0] = pos
    for t in range(length):
        deg = (offv[pos + 1] - offv[pos]).astype(np.int64)
        r = _walk_rand_np(seed, wid, t + 1).astype(np.int64)
        sink = deg == 0
        idx = offv[pos] + np.where(sink, 0, r % np.maximum(deg, 1))
        nxt = np.where(sink, r % n, adjv[np.minimum(idx, adjv.shape[0] - 1)])
        pos = nxt.astype(np.int64)
        hist[:, t + 1] = pos
    return hist


# ---------------------------------------------------------------------------
# distributed sampler (walker redistribution = paper's scatter-gather)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "mesh", "length", "seed", "axis",
                                   "walkers_per_shard", "capacity_factor"))
def distributed_walks(
    cfg: GraphConfig,
    mesh: Mesh,
    offv: jnp.ndarray,       # [nb*(B+1)] sharded per-shard local offsets
    adjv: jnp.ndarray,       # [nb*cap_m] sharded adjacency
    *,
    length: int,
    seed: int = 0,
    walkers_per_shard: int = 64,
    capacity_factor: float = 4.0,
    axis: str = "shards",
):
    """Walk histories [nb*cap, length+1], validity [nb*cap], walker ids
    [nb*cap], global dropped count.

    Walkers start at deterministic vertices of the launching shard and hop;
    before every hop all walkers are redistributed to the owner shard of
    their current vertex via capacity_all_to_all, so each hop reads only
    LOCAL CSR rows (the external-memory discipline: every shard touches its
    own bucket, never random remote rows).  Hub-vertex skew can overflow the
    fixed per-pair capacity — overflowed walkers are counted, their rows
    marked invalid (tests assert zero drops at the configured factor).
    """
    B = cfg.bucket_size
    n = cfg.n
    W = walkers_per_shard
    k = mesh.shape[axis]
    # Device histories are computed in the configured vertex dtype; a config
    # whose ids overflow it would corrupt walks silently — refuse instead.
    # Guard against the CANONICALIZED dtype: with x64 disabled, a requested
    # int64 actually runs as int32, which is exactly the silent wrap this
    # refuses (int64-safe runs need vertex_dtype=int64 AND jax x64).
    vdt = cfg.vertex_dtype
    if cfg.n - 1 > jnp.iinfo(jax.dtypes.canonicalize_dtype(vdt)).max:
        raise ValueError(
            f"n={cfg.n} overflows vertex_dtype={np.dtype(vdt).name} "
            f"(canonicalized {np.dtype(jax.dtypes.canonicalize_dtype(vdt)).name}); "
            "use vertex_dtype=int64 with jax x64 enabled for graphs past 2**31")
    # per-(src,dst)-pair exchange capacity; every shard holds cap = cp*k rows
    cp = max(1, int(np.ceil(W * capacity_factor / k)))
    cap = cp * k

    def per_shard(offv_l, adjv_l):
        bid = lax.axis_index(axis)
        base = (bid * B).astype(vdt)
        wid = (bid * W + jnp.arange(W, dtype=jnp.int32)).astype(vdt)
        pos = start_vertex(seed, wid.astype(jnp.uint32), B, base, dtype=vdt)
        alive = jnp.ones((W,), vdt)

        def pad_to(x, fill=0):
            extra = cap - x.shape[0]
            return jnp.concatenate(
                [x, jnp.full((extra,) + x.shape[1:], fill, x.dtype)])

        pos, wid = pad_to(pos), pad_to(wid, -1)
        # alive starts axis-invariant but becomes axis-varying through the
        # exchange; mark it varying so the scan carry types match
        alive = pvary(pad_to(alive), (axis,))
        hist = jnp.zeros((cap, length + 1), vdt).at[:, 0].set(pos)

        def step(carry, t):
            pos, hist, alive, wid = carry
            payload = jnp.concatenate(
                [pos[:, None], wid[:, None], alive[:, None], hist], axis=1)
            ex = capacity_all_to_all(payload, owner_of(pos, B), axis=axis,
                                     capacity=cp, valid=alive == 1)
            rp = ex.data.reshape(-1, payload.shape[1])            # [cap, 3+L+1]
            rvalid = ex.valid.reshape(-1)
            rpos, rwid, ralive = rp[:, 0], rp[:, 1], rp[:, 2]
            rhist = rp[:, 3:]
            alive_now = (rvalid & (ralive == 1)).astype(vdt)
            # advance one hop from local CSR rows
            row = jnp.clip(rpos - bid * B, 0, B - 1)
            start, end = offv_l[row], offv_l[row + 1]
            deg = end - start
            r = _walk_rand_jnp(seed, rwid.astype(jnp.uint32), t + 1)
            sink = deg <= 0
            idx = start + jnp.where(
                sink, 0,
                (r % jnp.maximum(deg, 1).astype(jnp.uint32)).astype(vdt))
            nxt = jnp.where(sink, (r % jnp.uint32(n)).astype(vdt),
                            adjv_l[jnp.clip(idx, 0, adjv_l.shape[0] - 1)])
            nxt = jnp.where(alive_now == 1, nxt, 0)
            rhist = jax.vmap(
                lambda h, v: h.at[t + 1].set(v))(rhist, nxt)
            return (nxt, rhist, alive_now, rwid), ex.dropped

        (pos, hist, alive, wid), dropped = lax.scan(
            step, (pos, hist, alive, wid), jnp.arange(length, dtype=jnp.int32))
        # ex.dropped is already psum'd -> every shard holds the same global
        # per-step totals; sum over steps, report one copy per shard.
        return hist, alive, wid, jnp.sum(dropped)[None]

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
    )
    hist, alive, wid, dropped = fn(offv, adjv)
    return hist, alive == 1, wid, dropped[0]


def walks_to_tokens(walks: np.ndarray, vocab: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vertex walks [W, L+1] -> (tokens [W, L], labels [W, L]) next-token LM
    pairs; token = vertex % vocab."""
    toks = (walks % vocab).astype(np.int32)
    return toks[:, :-1], toks[:, 1:].copy()


# ---------------------------------------------------------------------------
# external sampler (the redistribute phase re-run once per hop, on disk)
# ---------------------------------------------------------------------------


class ExternalWalkResult(NamedTuple):
    """external_walks output: the sharded corpus plus the accounting objects
    tests and benchmarks assert against."""

    walks: "ShardedWalks"        # [W, length+1] int64 array-like (disk-backed
                                 # per-bucket shards + manifest, core/corpus.py)
    ledger: IOLedger
    gauge: MemoryGauge
    orchestrator: PhaseOrchestrator


def external_walks(cfg, workdir: str, *, num_walkers: int, length: int,
                   seed: int = 0, ledger: Optional[IOLedger] = None,
                   gauge: Optional[MemoryGauge] = None,
                   checkpoint: bool = False,
                   out_name: str = "walks.npy") -> ExternalWalkResult:
    """Out-of-core walk corpus [num_walkers, length+1] over the CSR bucket
    files in `workdir` (written by StreamingGenerator / PartitionedGenerator's
    CSR phase) — the graph never materializes in RAM, and neither does the
    corpus: the collect phase is SHARDED (one `{out}_b{j}.npy` per bucket +
    a manifest, core/corpus.py), and `result.walks` is an array-like view
    over the shards.

    Each hop is the paper's redistribute phase applied to walkers: sort the
    per-bucket frontier by current vertex, sort-merge-join it against the
    owned offv/adjv runs, partition advanced walkers to their new owner
    (core/phases.py walk kernels).  Bit-identical to host_walks on the
    assembled bucket CSR (concat_bucket_csr) with walker_ids arange(W) and
    the standard start_vertex starts.  With checkpoint=True each hop is a
    resumable phase (state in <workdir>/walk_phases.json, independent of the
    generator's checkpoint); phase-level ledger deltas and peak resident
    rows come back in the result.

    Runs the bucket kernels in-process; for real process parallelism use
    PartitionedGenerator.walk_corpus, which drives the same kernels through
    its worker pool.

    Every per-hop frontier sort and the history gather merge through
    cfg.merge_fanin-bounded cascades (blockstore.merge_runs via PlainCfg),
    so walking a store with millions of frontier runs never exceeds the
    open-file budget — identical corpus at any fan-in.
    """
    pcfg = cfg if isinstance(cfg, PlainCfg) else plain_config(cfg)
    ledger = IOLedger() if ledger is None else ledger
    gauge = MemoryGauge(budget_rows=pcfg.chunk_edges) if gauge is None else gauge
    wcfg = WalkCfg(num_walkers=num_walkers, length=length, seed=seed,
                   out_name=out_name)
    orch = PhaseOrchestrator(workdir, ledger, checkpoint=checkpoint,
                             state_name="walk_phases.json",
                             config_key=repr((result_config_key(pcfg), wcfg)),
                             keep_all=bool(getattr(cfg, "keep_phase_stores",
                                                   False)))

    # One transport for the whole corpus: the kernels' exchange AND the
    # drivers' pre-senders inbox sweeps go through it (fs by default;
    # a socket config with live peer_addrs works too — the partitioned
    # driver is the usual owner of that mode).
    with make_transport(pcfg, workdir, ledger, gauge) as tr:

        def inline_map(kernel: str, argss):
            # Outputs matter: the pooled-cascade hop plans its merge levels
            # from the counts the sort kernels return.
            return [_KERNELS[kernel](pcfg, workdir, *args, ledger=ledger,
                                     gauge=gauge, transport=tr)
                    for args in argss]

        path = drive_walks(pcfg, workdir, wcfg, inline_map, orch, transport=tr)
    return ExternalWalkResult(ShardedWalks(path), ledger, gauge, orch)


def concat_bucket_csr(csr) -> Tuple[np.ndarray, np.ndarray]:
    """Assemble per-bucket CSR [(offv_i, adjv_i)] into one host (offv, adjv).

    Oracle-side helper: within-row adjacency order is part of the walk
    contract, so host_walks must read the SAME layout external_walks joins
    against.  Materializes the CSR — tests and small graphs only.
    """
    parts = [np.zeros(1, np.int64)]
    total = 0
    for offv, _ in csr:
        offv = np.asarray(offv, np.int64)
        parts.append(offv[1:] + total)
        total += int(offv[-1])
    adjv = np.concatenate([np.asarray(a, np.int64) for _, a in csr])
    return np.concatenate(parts), adjv
