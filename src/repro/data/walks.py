"""Random-walk corpus generation over generated graphs.

Two samplers over the same CSR:

  host_walks            numpy, sequential-access host sampler (the oracle,
                        and the loader's default on one host)
  distributed_walks     shard_map sampler where walkers MIGRATE between
                        shards with the paper's k:1 scatter-gather
                        (capacity_all_to_all): at every step each walker is
                        shipped to the shard that owns its current vertex
                        (the paper's "a core owns its range's vertices"),
                        which advances it one hop from its LOCAL CSR rows.
                        This is the redistribute phase run once per walk
                        step — the generator's communication machinery
                        reused verbatim by the training-data subsystem.

Walk semantics (both samplers, bit-identical): counter-based RNG keyed by
(seed, walker_id, step); a walker at a sink vertex (deg 0) teleports to
hash(walker, step) % n.  Tokenization: token = vertex % vocab (stable,
vocabulary-bounded).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..core.hostgen import mix32_np as _mix32_np
from ..core.types import GraphConfig, owner_of
from ..distributed.collectives import capacity_all_to_all, pvary, shard_map


def _mix32_jnp(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _walk_rand_np(seed: int, walker: np.ndarray, step: int) -> np.ndarray:
    s = np.uint32(seed & 0xFFFFFFFF)
    return _mix32_np(_mix32_np(walker.astype(np.uint32) ^ s)
                     + np.uint32((step * 0x9E3779B9) & 0xFFFFFFFF))


def _walk_rand_jnp(seed: int, walker: jnp.ndarray, step) -> jnp.ndarray:
    s = jnp.uint32(seed & 0xFFFFFFFF)
    stepc = jnp.uint32(step) * jnp.uint32(0x9E3779B9)
    return _mix32_jnp(_mix32_jnp(walker.astype(jnp.uint32) ^ s) + stepc)


def start_vertex(seed: int, walker: np.ndarray, n_or_B: int, base: int = 0):
    """Deterministic start vertex of a walker (shared by both samplers)."""
    if isinstance(walker, np.ndarray):
        return base + (_walk_rand_np(seed ^ 0xA5A5, walker, 0) % np.uint32(n_or_B)).astype(np.int64)
    return (base + (_walk_rand_jnp(seed ^ 0xA5A5, walker, 0) % jnp.uint32(n_or_B))).astype(jnp.int32)


# ---------------------------------------------------------------------------
# host oracle
# ---------------------------------------------------------------------------


def host_walks(offv: np.ndarray, adjv: np.ndarray, starts: np.ndarray,
               length: int, seed: int, n: Optional[int] = None,
               walker_ids: Optional[np.ndarray] = None) -> np.ndarray:
    """[W, length+1] vertex walks.  starts [W]."""
    n = n if n is not None else offv.shape[0] - 1
    W = starts.shape[0]
    wid = (walker_ids if walker_ids is not None
           else np.arange(W)).astype(np.uint32)
    pos = starts.astype(np.int64).copy()
    hist = np.zeros((W, length + 1), np.int64)
    hist[:, 0] = pos
    for t in range(length):
        deg = (offv[pos + 1] - offv[pos]).astype(np.int64)
        r = _walk_rand_np(seed, wid, t + 1).astype(np.int64)
        sink = deg == 0
        idx = offv[pos] + np.where(sink, 0, r % np.maximum(deg, 1))
        nxt = np.where(sink, r % n, adjv[np.minimum(idx, adjv.shape[0] - 1)])
        pos = nxt.astype(np.int64)
        hist[:, t + 1] = pos
    return hist


# ---------------------------------------------------------------------------
# distributed sampler (walker redistribution = paper's scatter-gather)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "mesh", "length", "seed", "axis",
                                   "walkers_per_shard", "capacity_factor"))
def distributed_walks(
    cfg: GraphConfig,
    mesh: Mesh,
    offv: jnp.ndarray,       # [nb*(B+1)] sharded per-shard local offsets
    adjv: jnp.ndarray,       # [nb*cap_m] sharded adjacency
    *,
    length: int,
    seed: int = 0,
    walkers_per_shard: int = 64,
    capacity_factor: float = 4.0,
    axis: str = "shards",
):
    """Walk histories [nb*cap, length+1], validity [nb*cap], walker ids
    [nb*cap], global dropped count.

    Walkers start at deterministic vertices of the launching shard and hop;
    before every hop all walkers are redistributed to the owner shard of
    their current vertex via capacity_all_to_all, so each hop reads only
    LOCAL CSR rows (the external-memory discipline: every shard touches its
    own bucket, never random remote rows).  Hub-vertex skew can overflow the
    fixed per-pair capacity — overflowed walkers are counted, their rows
    marked invalid (tests assert zero drops at the configured factor).
    """
    B = cfg.bucket_size
    n = cfg.n
    W = walkers_per_shard
    k = mesh.shape[axis]
    # per-(src,dst)-pair exchange capacity; every shard holds cap = cp*k rows
    cp = max(1, int(np.ceil(W * capacity_factor / k)))
    cap = cp * k

    def per_shard(offv_l, adjv_l):
        bid = lax.axis_index(axis)
        base = (bid * B).astype(jnp.int32)
        wid = (bid * W + jnp.arange(W, dtype=jnp.int32)).astype(jnp.int32)
        pos = start_vertex(seed, wid.astype(jnp.uint32), B, base)
        alive = jnp.ones((W,), jnp.int32)

        def pad_to(x, fill=0):
            extra = cap - x.shape[0]
            return jnp.concatenate(
                [x, jnp.full((extra,) + x.shape[1:], fill, x.dtype)])

        pos, wid = pad_to(pos), pad_to(wid, -1)
        # alive starts axis-invariant but becomes axis-varying through the
        # exchange; mark it varying so the scan carry types match
        alive = pvary(pad_to(alive), (axis,))
        hist = jnp.zeros((cap, length + 1), jnp.int32).at[:, 0].set(pos)

        def step(carry, t):
            pos, hist, alive, wid = carry
            payload = jnp.concatenate(
                [pos[:, None], wid[:, None], alive[:, None], hist], axis=1)
            ex = capacity_all_to_all(payload, owner_of(pos, B), axis=axis,
                                     capacity=cp, valid=alive == 1)
            rp = ex.data.reshape(-1, payload.shape[1])            # [cap, 3+L+1]
            rvalid = ex.valid.reshape(-1)
            rpos, rwid, ralive = rp[:, 0], rp[:, 1], rp[:, 2]
            rhist = rp[:, 3:]
            alive_now = (rvalid & (ralive == 1)).astype(jnp.int32)
            # advance one hop from local CSR rows
            row = jnp.clip(rpos - bid * B, 0, B - 1)
            start, end = offv_l[row], offv_l[row + 1]
            deg = end - start
            r = _walk_rand_jnp(seed, rwid.astype(jnp.uint32), t + 1)
            sink = deg <= 0
            idx = start + jnp.where(
                sink, 0,
                (r % jnp.maximum(deg, 1).astype(jnp.uint32)).astype(jnp.int32))
            nxt = jnp.where(sink, (r % jnp.uint32(n)).astype(jnp.int32),
                            adjv_l[jnp.clip(idx, 0, adjv_l.shape[0] - 1)])
            nxt = jnp.where(alive_now == 1, nxt, 0)
            rhist = jax.vmap(
                lambda h, v: h.at[t + 1].set(v))(rhist, nxt)
            return (nxt, rhist, alive_now, rwid), ex.dropped

        (pos, hist, alive, wid), dropped = lax.scan(
            step, (pos, hist, alive, wid), jnp.arange(length, dtype=jnp.int32))
        # ex.dropped is already psum'd -> every shard holds the same global
        # per-step totals; sum over steps, report one copy per shard.
        return hist, alive, wid, jnp.sum(dropped)[None]

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
    )
    hist, alive, wid, dropped = fn(offv, adjv)
    return hist, alive == 1, wid, dropped[0]


def walks_to_tokens(walks: np.ndarray, vocab: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vertex walks [W, L+1] -> (tokens [W, L], labels [W, L]) next-token LM
    pairs; token = vertex % vocab."""
    toks = (walks % vocab).astype(np.int32)
    return toks[:, :-1], toks[:, 1:].copy()
