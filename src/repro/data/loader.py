"""Graph -> LM-batch loader with deterministic restart.

Batches are a PURE FUNCTION of (graph, loader config, step): batch(step)
derives its walker ids from the step index, so a job restored from a step-N
checkpoint consumes exactly the batches it would have seen without the
failure — no data-order drift across restarts (and no loader state to
checkpoint at all).  This is the data-side half of fault tolerance.

The loader samples with the host sampler by default (sequential CSR access,
memmap-friendly — the paper's external-memory tier); mesh/sharding hooks
place each global batch over the dp axes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.csr import CSRShards, csr_to_host
from ..core.types import GraphConfig
from .walks import host_walks, start_vertex, walks_to_tokens


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab: int = 512
    seed: int = 0


class WalkLoader:
    """Deterministic batches of random-walk token sequences."""

    def __init__(self, graph_cfg: GraphConfig, csr: CSRShards,
                 cfg: LoaderConfig, mesh: Optional[Mesh] = None):
        self.gcfg = graph_cfg
        self.cfg = cfg
        self.offv, self.adjv = csr_to_host(csr, graph_cfg)
        self.mesh = mesh
        self._sharding = (
            NamedSharding(mesh, P(tuple(a for a in mesh.axis_names if a != "model")))
            if mesh is not None else None)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """{tokens [B,S], labels [B,S]} for train step `step` (pure fn)."""
        c = self.cfg
        wid = (np.int64(step) * c.batch_size
               + np.arange(c.batch_size)).astype(np.uint32)
        starts = start_vertex(c.seed, wid, self.gcfg.n)
        walks = host_walks(self.offv, self.adjv, starts, c.seq_len,
                           c.seed, n=self.gcfg.n, walker_ids=wid)
        tokens, labels = walks_to_tokens(walks, c.vocab)
        out = {"tokens": tokens, "labels": labels}
        if self._sharding is not None:
            out = {k: jax.device_put(v, self._sharding) for k, v in out.items()}
        else:
            out = {k: jnp.asarray(v) for k, v in out.items()}
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
