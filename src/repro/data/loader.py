"""Graph -> LM-batch loader with deterministic restart.

Batches are a PURE FUNCTION of (graph, loader config, step): batch(step)
derives its walker ids from the step index, so a job restored from a step-N
checkpoint consumes exactly the batches it would have seen without the
failure — no data-order drift across restarts (and no loader state to
checkpoint at all).  This is the data-side half of fault tolerance.

Two loaders share that contract:

  WalkLoader          samples each batch on demand with the host sampler
                      (sequential CSR access over a resident/memmapped CSR);
  ExternalWalkLoader  streams batches out of a SHARDED external_walks corpus
                      (per-bucket shard files + manifest, core/corpus.py)
                      built from the disk tier's CSR bucket files — neither
                      the CSR nor the corpus is ever resident (or even
                      co-located: a cluster run's shards stay on their owner
                      hosts), so token batches flow from graphs that never
                      fit in RAM.  Batch b equals WalkLoader's batch b (same
                      CSR layout) while (b+1)*batch_size <= num_walkers;
                      past that the corpus wraps around.

Mesh/sharding hooks place each global batch over the dp axes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.csr import CSRShards, csr_to_host
from ..core.types import GraphConfig
from .walks import external_walks, host_walks, start_vertex, walks_to_tokens


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab: int = 512
    seed: int = 0


def _batch_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    """Placement of a global batch over the data axes (both loaders)."""
    if mesh is None:
        return None
    return NamedSharding(mesh, P(tuple(a for a in mesh.axis_names
                                       if a != "model")))


def _package_batch(tokens: np.ndarray, labels: np.ndarray,
                   sharding: Optional[NamedSharding]) -> Dict[str, jnp.ndarray]:
    out = {"tokens": tokens, "labels": labels}
    if sharding is not None:
        return {k: jax.device_put(v, sharding) for k, v in out.items()}
    return {k: jnp.asarray(v) for k, v in out.items()}


class WalkLoader:
    """Deterministic batches of random-walk token sequences."""

    def __init__(self, graph_cfg: GraphConfig, csr: Optional[CSRShards],
                 cfg: LoaderConfig, mesh: Optional[Mesh] = None,
                 host_csr: Optional[Tuple[np.ndarray, np.ndarray]] = None):
        # `host_csr` takes a pre-assembled (offv, adjv) pair — e.g. the disk
        # tier's bucket CSR via walks.concat_bucket_csr — in place of device
        # CSRShards (within-row adjacency order differs between the two
        # pipelines, and walks are order-sensitive, so parity comparisons
        # must pin the layout).
        self.gcfg = graph_cfg
        self.cfg = cfg
        self.offv, self.adjv = (host_csr if host_csr is not None
                                else csr_to_host(csr, graph_cfg))
        self.mesh = mesh
        self._sharding = _batch_sharding(mesh)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """{tokens [B,S], labels [B,S]} for train step `step` (pure fn)."""
        c = self.cfg
        wid = (np.int64(step) * c.batch_size
               + np.arange(c.batch_size)).astype(np.uint32)
        starts = start_vertex(c.seed, wid, self.gcfg.n)
        walks = host_walks(self.offv, self.adjv, starts, c.seq_len,
                           c.seed, n=self.gcfg.n, walker_ids=wid)
        tokens, labels = walks_to_tokens(walks, c.vocab)
        return _package_batch(tokens, labels, self._sharding)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class ExternalWalkLoader:
    """Deterministic walk-token batches from an out-of-core SHARDED corpus.

    Builds (or, with checkpoint=True, resumes) an external_walks corpus of
    `num_walkers` walks over the CSR bucket files in `workdir`, then serves
    batch(step) as rows [step*B : (step+1)*B) of the sharded corpus (mod W)
    — the same pure-function-of-step contract as WalkLoader, with the CSR
    on disk the whole time.  The corpus is per-bucket shard files + a
    manifest (core/corpus.py); batches gather rows across shard memmaps, so
    no host ever holds the whole corpus.  Walk length is seq_len (tokens
    drop the last vertex's label shift, exactly like WalkLoader).

    `corpus_manifest` streams batches straight from an existing manifest —
    e.g. one a cluster run (launch/cluster.py) produced on per-host
    workdirs — skipping generation entirely; `workdir` is then unused.
    """

    def __init__(self, graph_cfg: GraphConfig, workdir: str, cfg: LoaderConfig,
                 *, num_walkers: int = 0, mesh: Optional[Mesh] = None,
                 checkpoint: bool = True,
                 corpus_manifest: Optional[str] = None):
        from ..core.corpus import ShardedWalks

        self.gcfg = graph_cfg
        self.cfg = cfg
        if corpus_manifest is not None:
            self.result = None
            self.walks = ShardedWalks(corpus_manifest)
            if self.walks.length != cfg.seq_len:
                raise ValueError(
                    f"corpus manifest holds walks of length "
                    f"{self.walks.length}, loader needs seq_len={cfg.seq_len}")
        else:
            if num_walkers <= 0:
                raise ValueError("num_walkers required without corpus_manifest")
            self.result = external_walks(
                graph_cfg, workdir, num_walkers=num_walkers,
                length=cfg.seq_len, seed=cfg.seed, checkpoint=checkpoint)
            self.walks = self.result.walks
        self.mesh = mesh
        self._sharding = _batch_sharding(mesh)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """{tokens [B,S], labels [B,S]} for train step `step` (pure fn)."""
        c = self.cfg
        W = self.walks.shape[0]
        wid = (np.int64(step) * c.batch_size + np.arange(c.batch_size)) % W
        tokens, labels = walks_to_tokens(np.asarray(self.walks[wid]), c.vocab)
        return _package_batch(tokens, labels, self._sharding)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
