"""repro: External-memory distributed graph generation (Gupta 2012) as a JAX framework.

Layers:
  core/         the paper's contribution: shuffle, R-MAT, relabel, redistribute, CSR
  kernels/      Pallas TPU kernels for the compute hot spots
  models/       composable LM stack for the assigned architectures
  configs/      one config per assigned architecture (+ the paper's own)
  data/         graph -> random-walk token pipeline
  train/        train step, optimizer, checkpoints
  serve/        KV-cache engine, prefill/decode
  distributed/  sharding rules, collectives, fault tolerance, compression
  launch/       mesh, dryrun, train/serve drivers
"""

__version__ = "1.0.0"
