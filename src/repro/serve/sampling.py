"""Token sampling: greedy / temperature / top-k, deterministic per request.

Host-side numpy (engine samples a handful of scalars per step; keeping it off
the device lets the jitted decode step stay sampling-agnostic and reusable
across requests with different sampling params).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> no top-k filter
    seed: int = 0


def sample(logits: np.ndarray, params: SamplingParams, step: int) -> int:
    """One token from unnormalized logits [V]."""
    logits = np.asarray(logits, np.float64)
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    logits = logits / params.temperature
    if params.top_k > 0 and params.top_k < logits.shape[0]:
        kth = np.partition(logits, -params.top_k)[-params.top_k]
        logits = np.where(logits >= kth, logits, -np.inf)
    logits -= logits.max()
    p = np.exp(logits)
    p /= p.sum()
    rng = np.random.default_rng((params.seed * 1_000_003 + step) & 0x7FFFFFFF)
    return int(rng.choice(logits.shape[0], p=p))
