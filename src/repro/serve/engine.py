"""Continuous-batching serving engine.

vLLM-style iteration-level scheduling on a fixed slot grid:

  * the decode cache is batched [.., max_batch, ..] with PER-SLOT lengths
    (models accept `length` as a [B] vector — layers.py masks/writes per
    sequence), so sequences of different lengths decode in one step;
  * a finished slot is reused immediately: the next waiting request's prompt
    is prefilled into a fresh B=1 cache and spliced into the slot
    (batch-axis splice is structural — axes are detected by shape-diffing
    two abstract caches, no per-family code);
  * prefill processes the first P-1 prompt tokens; the final prompt token
    enters through the shared decode path, which yields the logits for the
    first sampled token — prefill and decode never duplicate logic.

Attention-cache families (dense/moe) optionally bucket prefill lengths to
powers of two to bound jit recompilation: right-padding is safe because the
per-slot length masks everything at positions >= length, and each decode
step overwrites position `length` before attending (see layers.attention).
SSM/hybrid state integrates every token it sees, so those prefill exactly.

Engine-vs-oracle equivalence (same tokens as one-request-at-a-time greedy
decoding) is asserted in tests/test_serve.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.registry import get_model
from .sampling import SamplingParams, sample

SUPPORTED_FAMILIES = ("dense", "moe", "ssm", "hybrid")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pending: int = 0          # next token to feed through decode
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.req is None


# ---------------------------------------------------------------------------
# structural cache helpers
# ---------------------------------------------------------------------------


def _expand_lengths(cache, batch: int):
    """Give every `length` leaf a trailing per-slot batch dim."""
    def per_leaf(path, leaf):
        name = None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = str(k.key)
                break
        if name == "length":
            shape = tuple(leaf.shape) + (batch,)
            if isinstance(leaf, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(shape, leaf.dtype)
            return jnp.broadcast_to(leaf[..., None], shape)
        return leaf

    return jax.tree_util.tree_map_with_path(per_leaf, cache)


def _make_cache(cfg: ModelConfig, batch: int, max_len: int, mode: str = "init"):
    api = get_model(cfg)
    return _expand_lengths(api.init_cache(cfg, batch, max_len, mode), batch)


def _batch_axes(cfg: ModelConfig, max_len: int):
    """Per-leaf batch axis, found by diffing abstract caches of batch 2 vs 3."""
    c2 = _make_cache(cfg, 2, max_len, "shape")
    c3 = _make_cache(cfg, 3, max_len, "shape")

    def per_leaf(l2, l3):
        diff = [i for i, (a, b) in enumerate(zip(l2.shape, l3.shape)) if a != b]
        assert len(diff) == 1, f"ambiguous batch axis: {l2.shape} vs {l3.shape}"
        return diff[0]

    return jax.tree.map(per_leaf, c2, c3)


def _splice_slot(cache, one, axes, slot: int):
    """Write the B=1 cache `one` into slot `slot` of the batched cache."""
    return jax.tree.map(
        lambda buf, new, ax: jax.lax.dynamic_update_slice_in_dim(buf, new, slot, axis=ax),
        cache, one, axes,
    )


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 512, dist=None, bucket_prefill: bool = True):
        assert cfg.family in SUPPORTED_FAMILIES, cfg.family
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.dist = dist
        # SSM state integrates pad tokens -> exact-length prefill there
        self.bucket_prefill = bucket_prefill and cfg.family in ("dense", "moe")
        self.cache = _make_cache(cfg, max_batch, max_len)
        self.axes = _batch_axes(cfg, max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.waiting: List[Request] = []
        self.finished: Dict[int, List[int]] = {}
        self._decode = jax.jit(
            lambda p, t, c: self.api.decode_step(cfg, p, t, c, dist))
        self._prefill = {}  # prompt-len -> jitted prefill
        self.steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0

    # -- request intake ----------------------------------------------------

    def add_request(self, req: Request):
        assert len(req.prompt) >= 1, "empty prompt"
        assert len(req.prompt) + req.max_new_tokens <= self.max_len
        self.waiting.append(req)

    # -- scheduling --------------------------------------------------------

    def _prefill_len(self, n: int) -> int:
        if not self.bucket_prefill:
            return n
        p = 1
        while p < n:
            p <<= 1
        return min(p, self.max_len)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill:
            self._prefill[plen] = jax.jit(
                lambda p, t, c: self.api.prefill(self.cfg, p, {"tokens": t}, c, self.dist))
        return self._prefill[plen]

    def _admit(self, slot_idx: int, req: Request):
        slot = self.slots[slot_idx]
        slot.req = req
        slot.generated = []
        prompt = list(req.prompt)
        n_pre = len(prompt) - 1            # last prompt token goes through decode
        one = _make_cache(self.cfg, 1, self.max_len)
        if n_pre > 0:
            plen = self._prefill_len(n_pre)
            toks = np.zeros((1, plen), np.int32)
            toks[0, :n_pre] = prompt[:n_pre]
            _, one = self._prefill_fn(plen)(self.params, jnp.asarray(toks), one)
            if plen != n_pre:
                # true length is n_pre; mask out the right-padding
                one = jax.tree_util.tree_map_with_path(
                    lambda path, leaf: (jnp.full_like(leaf, n_pre)
                                        if _leaf_is_length(path) else leaf), one)
            self.prefill_tokens += n_pre
        self.cache = _splice_slot(self.cache, one, self.axes, slot_idx)
        slot.pending = prompt[-1]

    def _retire(self, slot_idx: int):
        slot = self.slots[slot_idx]
        self.finished[slot.req.uid] = slot.generated
        slot.req = None

    # -- one engine iteration ----------------------------------------------

    def step(self) -> bool:
        """Admit what fits, run one decode wave.  False when fully idle."""
        for i, slot in enumerate(self.slots):
            if slot.free and self.waiting:
                self._admit(i, self.waiting.pop(0))
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return False

        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].pending
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens), self.cache)
        logits = np.asarray(jax.device_get(logits[:, -1]), np.float32)

        for i in active:
            slot = self.slots[i]
            req = slot.req
            tok = sample(logits[i], req.sampling, step=len(slot.generated))
            slot.generated.append(tok)
            slot.pending = tok
            done = (len(slot.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id))
            if done:
                self._retire(i)
        self.steps += 1
        self.decode_tokens += len(active)
        return True

    def run(self, requests: Optional[List[Request]] = None) -> Dict[int, List[int]]:
        for r in requests or []:
            self.add_request(r)
        while self.step():
            pass
        out, self.finished = self.finished, {}
        return out


def _leaf_is_length(path) -> bool:
    for k in reversed(path):
        if isinstance(k, jax.tree_util.DictKey):
            return str(k.key) == "length"
    return False


# ---------------------------------------------------------------------------
# single-request oracle (tests compare the engine against this)
# ---------------------------------------------------------------------------


def generate_reference(cfg: ModelConfig, params, req: Request, *,
                       max_len: int = 512, dist=None) -> List[int]:
    """One request, one slot, no batching — the engine must match this."""
    api = get_model(cfg)
    cache = _make_cache(cfg, 1, max_len)
    prompt = list(req.prompt)
    if len(prompt) > 1:
        _, cache = api.prefill(
            cfg, params, {"tokens": jnp.asarray([prompt[:-1]], jnp.int32)}, cache, dist)
    pending = prompt[-1]
    out: List[int] = []
    for _ in range(req.max_new_tokens):
        logits, cache = api.decode_step(
            cfg, params, jnp.asarray([[pending]], jnp.int32), cache, dist)
        tok = sample(np.asarray(logits[0, -1], np.float32), req.sampling, step=len(out))
        out.append(tok)
        pending = tok
        if req.eos_id is not None and tok == req.eos_id:
            break
    return out
