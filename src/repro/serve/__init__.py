from .engine import Engine, Request, generate_reference  # noqa: F401
from .sampling import SamplingParams, sample  # noqa: F401
