"""zamba2-2.7b — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf Zyphra/Zamba2-2.7B]  54L d_model=2560, 32H (GQA kv=32
=> MHA in the shared block), d_ff=10240, vocab=32000, ssm_state=64.  The
shared transformer block (attention + MLP, one weight copy) is applied every
6 mamba layers (9 call sites, each with its own KV cache).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=32,
    shared_attn_every=2, dtype="float32",
)
