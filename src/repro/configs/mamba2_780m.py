"""mamba2-780m — pure SSD (state-space duality), attention-free.

[arXiv:2405.21060; hf state-spaces/mamba2-780m; unverified tier]
48L d_model=1536, d_inner=2*d_model, ssm_state=128, head_dim=64, conv=4,
vocab=50280 (gpt-neox tokenizer padded).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=32,
    dtype="float32",
)
