"""codeqwen1.5-7b — qwen1.5 architecture, code model.

[hf Qwen/CodeQwen1.5-7B]  32L d_model=4096, 32H (GQA kv=32 => MHA),
d_ff=13440, vocab=92416, qkv bias (qwen1.5 family trait).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, qkv_bias=True, dtype="float32",
)
