"""deepseek-v2-lite-16b — MLA + fine-grained MoE.

[arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2-Lite]  27L d_model=2048, 16H,
MLA kv_lora_rank=512 (qk_nope=128, qk_rope=64, v_head=128), first layer
dense (d_ff=10944), then MoE: 64 routed experts top-6 + 2 shared experts,
per-expert d_ff=1408, vocab=102400.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, rope_theta=10_000.0,
    num_experts=64, experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1408, first_k_dense=1, norm_topk_prob=False,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=512,
    num_experts=8, experts_per_tok=2, num_shared_experts=1,
    moe_d_ff=96, first_k_dense=1,
    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    dtype="float32",
)
