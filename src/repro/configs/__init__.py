"""One config per assigned architecture (+ the paper's own graph configs).

`get_config(name)` resolves any assigned architecture id; `SMOKE[name]`
gives the reduced same-family config used by CPU smoke tests.
"""

from .base import ModelConfig, ShapeSpec, SHAPES, arch_ids, get_config, get_smoke_config  # noqa: F401
