"""Model/shape configuration schema + registry.

Every assigned architecture gets one file in this package defining
  CONFIG  — the exact published configuration (sources in each file)
  SMOKE   — a reduced same-family configuration for CPU smoke tests
and registers both here via `register()`.

Input shapes (assigned, LM-family): seq_len x global_batch
  train_4k     4096 x 256    -> train_step
  prefill_32k  32768 x 32    -> serve prefill
  decode_32k   32768 x 128   -> serve decode (1 new token, full KV cache)
  long_500k    524288 x 1    -> long-context decode (SSM/hybrid only)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"           # params/compute dtype (str: hashable+serializable)
    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width
    first_k_dense: int = 0            # leading dense layers (deepseek: 1)
    norm_topk_prob: bool = True
    # --- MLA (deepseek) ------------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # --- hybrid (zamba2) -------------------------------------------------------
    shared_attn_every: int = 0        # one shared attn+MLP block every k ssm layers
    # --- encdec (seamless) -----------------------------------------------------
    encoder_layers: int = 0
    # --- vlm (llava) -------------------------------------------------------------
    num_image_tokens: int = 0         # patch embeddings prepended (frontend stubbed)
    # --- implementation knobs ----------------------------------------------------
    scan_layers: bool = True
    remat: str = "block"              # none | block
    attn_q_chunk: int = 1024          # XLA blockwise attention chunk
    logits_fp32: bool = True
    moe_capacity_factor: float = 2.0  # EP dispatch buffer over uniform load
    moe_dispatch_int8: bool = False   # quantize the a2a payload (per-row scale)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding table rows, padded to a multiple of 128 so
        the vocab dim shards evenly over any mesh "model" axis (Megatron-style
        vocab padding; only seamless 256206->256256 and mamba2 50280->50304
        actually pad).  Logits columns >= vocab_size are masked in unembed."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # approximate parameter count (embedding included), used for roofline
    def param_count(self) -> int:
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.hd
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per = (d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads)
                   + d_in * d + 3 * nheads + d)
            return L * per + 2 * V * d + d
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (self.num_heads * hd) * d
        if self.kv_lora_rank:  # MLA replaces the KV projections
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = (d * self.num_heads * qk
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d)
        mlp_dense = 3 * d * self.d_ff
        per_moe = 0
        if self.num_experts:
            per_moe = (self.num_experts + self.num_shared_experts) * 3 * d * self.moe_d_ff + d * self.num_experts
        n_moe = max(0, L - self.first_k_dense) if self.num_experts else 0
        n_dense = L - n_moe
        total = L * attn + n_dense * mlp_dense + n_moe * per_moe + 2 * L * d
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            ssm_per = (d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads) + d_in * d + 3 * nheads + d)
            n_sites = L // max(1, self.shared_attn_every)
            shared = attn + mlp_dense + 2 * d
            total = L * ssm_per + shared + n_sites * 0 + 2 * d
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp_dense + 2 * d)
            dec = L * (2 * attn + mlp_dense + 3 * d)   # self + cross attention
            total = enc + dec
        total += 2 * V * d + d  # embed + unembed + final norm
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        all_experts = (self.num_experts + self.num_shared_experts)
        active_experts = (self.experts_per_tok + self.num_shared_experts)
        n_moe = max(0, self.num_layers - self.first_k_dense)
        expert_params = n_moe * all_experts * 3 * self.d_model * self.moe_d_ff
        active = n_moe * active_experts * 3 * self.d_model * self.moe_d_ff
        return int(full - expert_params + active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_ARCH_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "minitron-8b": "minitron_8b",
    "qwen2.5-32b": "qwen2p5_32b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "internlm2-1.8b": "internlm2_1p8b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "seamless-m4t-large-v2": "seamless_m4t_large",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-780m": "mamba2_780m",
}


def arch_ids():
    return list(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {arch_ids()}")
    return importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def long_context_supported(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic-context families (DESIGN.md §5)."""
    return cfg.family in ("ssm", "hybrid")
