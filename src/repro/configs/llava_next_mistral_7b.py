"""llava-next-mistral-7b — VLM, Mistral-7B text backbone.

[hf llava-hf/llava-v1.6-mistral-7b-hf; unverified tier]  Backbone: 32L
d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=32000 (+image tokens).
AnyRes tiling frontend (CLIP-L/336 + 2x2 grid + base) is STUBBED:
input_specs() supplies precomputed patch embeddings [B, num_image_tokens,
d_model]; num_image_tokens=1176 ~ one 336px tile + newline tokens x 2
(conservative anyres budget that keeps seq_len=4096 cells well-formed).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, rope_theta=1_000_000.0,
    num_image_tokens=1176,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, num_image_tokens=16, dtype="float32",
)
