"""qwen3-moe-235b-a22b — 128-expert top-8 MoE.

[hf Qwen/Qwen3-235B-A22B; family verified via Qwen/Qwen3-30B-A3B]
94L d_model=4096, 64H (GQA kv=4), per-expert d_ff=1536, vocab=151936,
128 routed experts top-8, norm_topk_prob, no shared experts.  head_dim=128
(explicit in the qwen3 family, != d_model/num_heads).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, rope_theta=1_000_000.0,
    num_experts=128, experts_per_tok=8, moe_d_ff=1536, norm_topk_prob=True,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512,
    num_experts=8, experts_per_tok=2, moe_d_ff=96, dtype="float32",
)
