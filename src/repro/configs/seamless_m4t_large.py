"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf facebook/seamless-m4t-v2-large]  Backbone only:
24L encoder + 24L decoder, d_model=1024, 16H (kv=16), d_ff=8192,
vocab=256206.  The audio frontend (w2v-BERT conformer feature extractor) is
STUBBED per the assignment: input_specs() supplies precomputed frame
embeddings [B, S_enc, d_model]; the decoder is a standard causal LM with
cross-attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=8192, vocab_size=256206,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, dtype="float32",
)
