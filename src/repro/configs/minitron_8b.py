"""minitron-8b — dense, pruned from Nemotron-4 15B.

[arXiv:2407.14679; hf nvidia/Minitron-8B-Base]  32L d_model=4096, 48H->32H
(GQA kv=8), d_ff=16384, vocab=256000 (the large sentencepiece vocab makes the
embedding the dominant parameter block: sharded over model AND data axes).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, dtype="float32",
)
