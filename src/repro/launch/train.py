"""Training driver: generate graph -> walk corpus -> train an LM.

This is the end-to-end path a real job takes (and what
examples/train_lm_on_graph_walks.py drives at laptop scale):

  1. distributed graph generation (the paper's pipeline) on a 1-D mesh
  2. deterministic random-walk batches (data/loader.py)
  3. sharded train steps with checkpoint/restart (train/)

`--data external` swaps 1+2 for the disk tier: the graph is generated
out-of-core (StreamingGenerator, CSR as bucket files in --workdir) and token
batches stream from an external_walks corpus memmap — the CSR never
materializes in RAM, so the data side scales past host memory.

On the CPU container this runs reduced configs end to end; on a pod the
same driver takes --arch/--mesh flags.  Restartable: re-running with the
same --ckpt-dir resumes from the newest valid checkpoint with identical
data order (batches are a pure function of the step index; the external
corpus additionally resumes its own walk phases from --workdir).
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax
import numpy as np

from ..configs.base import get_smoke_config
from ..core.pipeline import generate
from ..core.types import GraphConfig
from ..data import ExternalWalkLoader, LoaderConfig, WalkLoader
from ..distributed.collectives import flat_mesh
from ..models.registry import get_model
from ..train import OptimConfig, checkpoint, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--scale", type=int, default=12, help="graph scale (2^s vertices)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--data", choices=("host", "external"), default="host",
                    help="host: device pipeline + on-demand host sampler; "
                         "external: out-of-core generation + walk corpus")
    ap.add_argument("--workdir", default="",
                    help="disk-tier workdir for --data external "
                         "(temp dir if empty; reuse to resume)")
    ap.add_argument("--walkers", type=int, default=0,
                    help="external corpus size (0 = min(steps*batch, 8192))")
    ap.add_argument("--corpus-manifest", default="",
                    help="stream batches from an existing sharded corpus "
                         "manifest (e.g. a launch/cluster.py run's output) "
                         "instead of generating; implies --data external")
    args = ap.parse_args(argv)
    if args.corpus_manifest:
        args.data = "external"

    cfg = get_smoke_config(args.arch)
    lcfg = LoaderConfig(batch_size=args.batch, seq_len=args.seq,
                        vocab=cfg.vocab_size)
    t0 = time.time()
    scratch_workdir = None
    # everything below runs under the finally that reclaims a scratch
    # workdir — generation and corpus build can fail (or be interrupted)
    # with gigabytes already on disk
    try:
        if args.corpus_manifest:
            # 1+2 already happened elsewhere (e.g. a multi-host cluster run):
            # stream token batches straight from the sharded corpus manifest —
            # per-host shard files are gathered per batch, never assembled.
            gcfg = GraphConfig(scale=args.scale)
            loader = ExternalWalkLoader(gcfg, "", lcfg,
                                        corpus_manifest=args.corpus_manifest)
            print(f"[corpus] streaming {loader.walks.num_walkers} x "
                  f"{args.seq + 1} walks from {args.corpus_manifest}")
        elif args.data == "external":
            # 1+2. out-of-core generation + walk corpus: CSR and walks stay
            # on disk end to end (resumable via the workdir's phase
            # checkpoints; only an explicit --workdir persists for resume)
            from ..core.external import StreamingGenerator

            workdir = args.workdir
            if not workdir:
                workdir = scratch_workdir = tempfile.mkdtemp(
                    prefix="repro_external_")
            gcfg = GraphConfig(scale=args.scale, nb=4, chunk_edges=1 << 14,
                               shuffle_variant="external",
                               checkpoint_phases=True)
            gen = StreamingGenerator(gcfg, workdir)
            gen.run()
            print(f"[graphgen external] scale={args.scale} edges={gcfg.m} "
                  f"workdir={workdir} in {time.time() - t0:.1f}s")
            walkers = args.walkers or min(args.steps * args.batch, 8192)
            loader = ExternalWalkLoader(gcfg, workdir, lcfg,
                                        num_walkers=walkers)
            print(f"[corpus] {walkers} walks x {args.seq + 1} vertices, "
                  f"peak resident rows {loader.result.gauge.peak_rows}")
        else:
            # 1. graph generation (the paper's kernel is the data source)
            gcfg = GraphConfig(scale=args.scale, nb=len(jax.devices()),
                               capacity_factor=4.0)
            res = generate(gcfg)
            assert int(res.dropped_redistribute) == 0
            print(f"[graphgen] scale={args.scale} edges={gcfg.m} "
                  f"in {time.time() - t0:.1f}s")

            # 2. corpus
            loader = WalkLoader(gcfg, res.csr, lcfg)

        # 3. train with restart support
        ocfg = OptimConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
        state, factory = init_state(cfg, ocfg)
        start = 0
        if args.ckpt_dir:
            restored, step = checkpoint.restore_latest(args.ckpt_dir, state)
            if restored is not None:
                state, start = restored, step + 1
                print(f"[restore] resumed from step {step}")
        step_fn = jax.jit(make_train_step(cfg, ocfg, None, accum_steps=args.accum))

        losses = []
        for step in range(start, args.steps):
            batch = loader.batch(step)
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step, state, keep=3)
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps - 1, state, keep=3)
        print(f"final loss {np.mean(losses[-10:]):.4f} "
              f"(first-10 avg {np.mean(losses[:10]):.4f})")
        return losses
    finally:
        if scratch_workdir is not None:
            shutil.rmtree(scratch_workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
