"""Training driver: generate graph -> walk corpus -> train an LM.

This is the end-to-end path a real job takes (and what
examples/train_lm_on_graph_walks.py drives at laptop scale):

  1. distributed graph generation (the paper's pipeline) on a 1-D mesh
  2. deterministic random-walk batches (data/loader.py)
  3. sharded train steps with checkpoint/restart (train/)

On the CPU container this runs reduced configs end to end; on a pod the
same driver takes --arch/--mesh flags.  Restartable: re-running with the
same --ckpt-dir resumes from the newest valid checkpoint with identical
data order (batches are a pure function of the step index).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_smoke_config
from ..core.pipeline import generate
from ..core.types import GraphConfig
from ..data import LoaderConfig, WalkLoader
from ..distributed.collectives import flat_mesh
from ..models.registry import get_model
from ..train import OptimConfig, checkpoint, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--scale", type=int, default=12, help="graph scale (2^s vertices)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args(argv)

    # 1. graph generation (the paper's kernel is the data source)
    gcfg = GraphConfig(scale=args.scale, nb=len(jax.devices()),
                       capacity_factor=4.0)
    t0 = time.time()
    res = generate(gcfg)
    assert int(res.dropped_redistribute) == 0
    print(f"[graphgen] scale={args.scale} edges={gcfg.m} "
          f"in {time.time() - t0:.1f}s")

    # 2. corpus
    cfg = get_smoke_config(args.arch)
    loader = WalkLoader(gcfg, res.csr, LoaderConfig(
        batch_size=args.batch, seq_len=args.seq, vocab=cfg.vocab_size))

    # 3. train with restart support
    ocfg = OptimConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    state, factory = init_state(cfg, ocfg)
    start = 0
    if args.ckpt_dir:
        restored, step = checkpoint.restore_latest(args.ckpt_dir, state)
        if restored is not None:
            state, start = restored, step + 1
            print(f"[restore] resumed from step {step}")
    step_fn = jax.jit(make_train_step(cfg, ocfg, None, accum_steps=args.accum))

    losses = []
    for step in range(start, args.steps):
        batch = loader.batch(step)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step, state, keep=3)
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps - 1, state, keep=3)
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 avg {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
