"""Cell assembly: one (arch x shape x mesh) -> a jit-able step + abstract
inputs + shardings.  Shared by the dry-run, the roofline table, and the
§Perf hillclimb (which re-lowers cells under modified rules).

  train cells   -> train_step(state, batch)
  prefill cells -> prefill(params, batch, cache)
  decode cells  -> decode_step(params, tokens, cache)   (1 new token,
                   KV cache of seq_len — the assignment's decode semantics)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec, get_config, long_context_supported
from ..distributed.sharding import (
    batch_shardings, cache_shardings, make_dist, param_shardings)
from ..models.nn import ParamFactory
from ..models.registry import get_model, input_specs
from ..train import OptimConfig, init_state, make_train_step, state_shardings


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Any                 # the step callable
    args: Tuple             # abstract (ShapeDtypeStruct) example args
    in_shardings: Tuple
    out_shardings: Any      # None -> auto
    dist: Any
    kind: str
    donate: Tuple[int, ...] = ()   # train: state; serve: cache (in-place step)

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        return jitted.lower(*self.args)


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not long_context_supported(cfg):
        return False, ("full-attention family: 524288-token context is "
                       "quadratic; run for ssm/hybrid only (DESIGN.md §5)")
    return True, ""


def build_cell(arch: str, shape: ShapeSpec, mesh: Mesh, *,
               cfg: Optional[ModelConfig] = None,
               ocfg: Optional[OptimConfig] = None,
               fsdp: bool = True,
               accum_steps: int = 1,
               rule_overrides: Optional[Dict] = None,
               moe_dispatch: Optional[str] = None) -> Cell:
    cfg = cfg or get_config(arch)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape.name} skipped: {why}")
    dist = make_dist(cfg, mesh, shape, fsdp=fsdp, overrides=rule_overrides,
                     moe_dispatch=moe_dispatch)
    api = get_model(cfg)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        ocfg = ocfg or OptimConfig()
        state, factory = init_state(cfg, ocfg, mode="shape")
        batch = input_specs(cfg, shape, mode="shape")
        fn = make_train_step(cfg, ocfg, dist, accum_steps=accum_steps)
        st_sh = state_shardings(state, factory, dist)
        b_sh = batch_shardings(batch, dist)
        metrics_sh = {k: rep for k in
                      ("loss", "ntok", "lb_loss", "dropped", "lr", "grad_norm")}
        return Cell(arch, shape, cfg, fn, (state, batch), (st_sh, b_sh),
                    (st_sh, metrics_sh), dist, "train", donate=(0,))

    # ---- serving cells ----
    factory = ParamFactory(mode="shape", dtype=cfg.jdtype)
    params = api.init_params(cfg, factory)
    p_sh = param_shardings(factory.specs, params, dist)
    B, S = shape.global_batch, shape.seq_len
    cache = api.init_cache(cfg, B, S, mode="shape")
    c_sh = cache_shardings(cache, dist)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape, mode="shape")
        b_sh = batch_shardings(batch, dist)
        fn = lambda p, b, c: api.prefill(cfg, p, b, c, dist)  # noqa: E731
        logits_sh = dist.sharding(("batch", None, "vocab"))
        return Cell(arch, shape, cfg, fn, (params, batch, cache),
                    (p_sh, b_sh, c_sh), (logits_sh, c_sh), dist, "prefill",
                    donate=(2,))

    # decode: one new token against a seq_len-deep cache
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_sh = dist.sharding(("batch", None))
    fn = lambda p, t, c: api.decode_step(cfg, p, t, c, dist)  # noqa: E731
    logits_sh = dist.sharding(("batch", None, "vocab"))
    return Cell(arch, shape, cfg, fn, (params, tokens, cache),
                (p_sh, t_sh, c_sh), (logits_sh, c_sh), dist, "decode",
                donate=(2,))
