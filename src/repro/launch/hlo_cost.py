"""Trip-count-aware cost accounting over optimized HLO.

XLA's `compiled.cost_analysis()` counts each while-loop BODY ONCE, but every
layer scan (lax.scan over blocks), attention query-chunk lax.map, SSD chunk
scan and grad-accumulation loop lowers to a while loop — so its flops/bytes
under-count real work by the trip count (24x-94x for the layer stacks).
This module re-derives the roofline numerators from the HLO text with while
bodies multiplied by their static trip counts:

  flops       2 * numel(result) * prod(contracted dims) per `dot`,
              recursively through fusions, x trip multipliers
  bytes       fusion-boundary traffic: sum(operand bytes)+result bytes per
              top-level instruction of every *executed* computation
              (parameters/constants/tuple plumbing skipped), x multipliers
  collectives operand bytes per all-gather / all-reduce / reduce-scatter /
              all-to-all / collective-permute, x multipliers, per kind

Trip counts: a jax scan lowers to `while(cond=%c, body=%b)` whose cond
compares the induction variable against an s32 constant — the largest s32
constant in the cond computation is the trip count (validated against
known-layer-count models in tests/test_roofline.py).

The numbers feed launch/roofline.py; `compiled.cost_analysis()` is still
recorded in the dry-run JSON for reference.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_ATTR_CALL_RE = re.compile(r"(calls|body|condition|to_apply)=%([\w.-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "after-all", "add-dependency", "opt-barrier", "conditional",
    "call", "iota", "partition-id", "replica-id",
}


def _type_and_rest(rest: str) -> Tuple[str, str]:
    """Split '<type> <opcode>(...)' -> (type_str, remainder)."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:]
    m = re.match(r"\w+\[[\d,]*\](?:\{[^}]*\})?", rest)
    if m:
        return m.group(0), rest[m.end():]
    return "", rest


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _numel(type_str: str) -> int:
    dims = _first_shape_dims(type_str)
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]
    root: str = ""


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    current: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):  # possible computation header
            m = _COMP_HDR_RE.match(line)
            if m:
                current = Computation(m.group(1), {}, [])
                comps[current.name] = current
                if line.startswith("ENTRY"):
                    entry = current.name
            elif line.startswith("}"):
                current = None
            continue
        if current is None:
            continue
        if line.strip().startswith("}"):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        if line.lstrip().startswith("ROOT"):
            current.root = name
        type_str, tail = _type_and_rest(rest)
        tail = tail.lstrip()
        om = re.match(r"([\w-]+)\(", tail)
        if not om:
            continue
        op = om.group(1)
        args = tail[om.end():]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    attrs = args[i + 1:]
                    args = args[:i]
                    break
        else:
            attrs = ""
        operands = _OPERAND_RE.findall(args)
        current.instrs[name] = Instr(name, type_str, op, operands, args + "|" + attrs)
        current.order.append(name)
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the cond computation = the scan trip count
    (jax scans lower to `while i < L`; L is the only s32 constant there)."""
    best = 1
    for iname in cond.order:
        ins = cond.instrs[iname]
        if ins.op != "constant" or not ins.type_str.startswith("s32"):
            continue
        m = re.match(r"(\d+)\|", ins.attrs)
        if m:
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    transfers: List[Tuple[str, str, float, float]] = dataclasses.field(
        default_factory=list)  # (kind, instr, bytes_each, multiplier)

    def add_coll(self, kind: str, nbytes: float, mult: float, name: str):
        self.coll[kind] = self.coll.get(kind, 0.0) + nbytes * mult
        self.transfers.append((kind, name, nbytes, mult))


def _dot_flops(ins: Instr, comp: Computation,
               comps: Dict[str, Computation]) -> float:
    cm = _CONTRACT_RE.search(ins.attrs)
    contract = [int(x) for x in cm.group(1).split(",")] if (cm and cm.group(1)) else []
    lhs_dims: List[int] = []
    if ins.operands:
        lhs_name = ins.operands[0]
        src = comp.instrs.get(lhs_name)
        if src is not None:
            lhs_dims = _first_shape_dims(src.type_str)
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * _numel(ins.type_str) * k


def _fusion_boundary_bytes(ins: Instr, comp: Computation,
                           comps: Dict[str, Computation]) -> float:
    """Slice-aware fusion traffic.

    A fusion's nominal boundary is sum(operands)+result, but two patterns
    make that wildly pessimistic for cache-style code:
      * a big operand consumed ONLY by dynamic-slice/gather inside the
        fusion physically reads just the slices;
      * a fusion whose root is dynamic-update-slice writes just the update
        (XLA performs it in place), and the target operand isn't read.
    """
    callee = comps.get(dict(_ATTR_CALL_RE.findall(ins.attrs)).get("calls", ""))
    if callee is None:
        opb = sum(_shape_bytes(comp.instrs[o].type_str)
                  for o in ins.operands if o in comp.instrs)
        return opb + _shape_bytes(ins.type_str)

    # reads: per parameter, by how it is used inside.  Trace through
    # "transparent" ops (convert/copy/bitcast — CPU bf16 legalization wraps
    # cache updates in converts that would not exist on the TPU target).
    uses: Dict[str, List[Instr]] = {}
    for iname in callee.order:
        cins = callee.instrs[iname]
        for o in cins.operands:
            uses.setdefault(o, []).append(cins)

    _TRANSPARENT = ("convert", "copy", "bitcast")

    def effective_uses(name: str, depth: int = 0) -> List[Tuple[Instr, str]]:
        """[(consumer, name-it-consumes)] skipping transparent chains."""
        out: List[Tuple[Instr, str]] = []
        for u in uses.get(name, []):
            if u.op in _TRANSPARENT and depth < 8:
                out.extend(effective_uses(u.name, depth + 1))
            else:
                out.append((u, name))
        return out

    reads = 0.0
    for iname in callee.order:
        p = callee.instrs[iname]
        if p.op != "parameter":
            continue
        pu = effective_uses(p.name)
        if pu and all(u.op in ("dynamic-slice", "gather") for u, _ in pu):
            reads += sum(_shape_bytes(u.type_str) for u, _ in pu)
        elif pu and all(u.op == "dynamic-update-slice" and u.operands
                        and u.operands[0] == nm for u, nm in pu):
            reads += 0.0          # pure in-place update target
        else:
            reads += _shape_bytes(p.type_str)

    # writes: root-aware
    def piece_bytes(pname: str, depth: int = 0) -> float:
        pi = callee.instrs.get(pname)
        if pi is None:
            return 0.0
        if pi.op in _TRANSPARENT and pi.operands and depth < 8:
            return piece_bytes(pi.operands[0], depth + 1)
        if pi.op == "dynamic-update-slice" and len(pi.operands) > 1:
            upd = callee.instrs.get(pi.operands[1])
            return _shape_bytes(upd.type_str if upd else pi.type_str)
        return _shape_bytes(pi.type_str)

    root = callee.instrs.get(callee.root or (callee.order[-1] if callee.order else ""))
    if root is None:
        writes = _shape_bytes(ins.type_str)
    elif root.op == "tuple":
        writes = sum(piece_bytes(o) for o in root.operands)
    else:
        writes = piece_bytes(root.name)
    return reads + writes


def _cost_comp(name: str, mult: float, comps: Dict[str, Computation],
               totals: CostTotals, fusion_ctx: bool = False):
    comp = comps.get(name)
    if comp is None:
        return
    for iname in comp.order:
        ins = comp.instrs[iname]
        op = ins.op
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in COLLECTIVES:
            opb = sum(_shape_bytes(comp.instrs[o].type_str)
                      for o in ins.operands if o in comp.instrs)
            totals.add_coll(base, opb, mult, iname)
            totals.bytes += (opb + _shape_bytes(ins.type_str)) * mult
            continue
        if op == "dot":
            totals.flops += _dot_flops(ins, comp, comps) * mult
            if not fusion_ctx:
                opb = sum(_shape_bytes(comp.instrs[o].type_str)
                          for o in ins.operands if o in comp.instrs)
                totals.bytes += (opb + _shape_bytes(ins.type_str)) * mult
            continue
        if op == "while":
            am = dict(_ATTR_CALL_RE.findall(ins.attrs))
            cond = am.get("condition")
            body = am.get("body")
            trip = _trip_count(comps[cond]) if cond in comps else 1
            if body:
                _cost_comp(body, mult * trip, comps, totals)
            if cond in comps:
                _cost_comp(cond, mult * trip, comps, totals)
            continue
        if op == "fusion":
            am = dict(_ATTR_CALL_RE.findall(ins.attrs))
            callee = am.get("calls")
            if callee:
                # flops & collectives inside the fusion count; bytes are the
                # (slice-aware) fusion boundary only
                _cost_comp(callee, mult, comps, totals, fusion_ctx=True)
            if not fusion_ctx:
                totals.bytes += _fusion_boundary_bytes(ins, comp, comps) * mult
            continue
        if op in ("call", "conditional"):
            am = dict(_ATTR_CALL_RE.findall(ins.attrs))
            for key in ("calls", "to_apply", "body"):
                if key in am:
                    _cost_comp(am[key], mult, comps, totals, fusion_ctx)
            bm = _BRANCHES_RE.search(ins.attrs)
            if bm:
                for b in _OPERAND_RE.findall(bm.group(1)):
                    _cost_comp(b, mult, comps, totals, fusion_ctx)
            continue
        if op in _SKIP_BYTES_OPS or fusion_ctx:
            continue
        if op in ("dynamic-slice", "gather"):
            # physically reads only the slice/gathered rows, not operand 0
            totals.bytes += 2.0 * _shape_bytes(ins.type_str) * mult
            continue
        if op in ("dynamic-update-slice", "scatter"):
            # in-place update: read+write the update region only (operand 1)
            upd = (comp.instrs[ins.operands[1]].type_str
                   if len(ins.operands) > 1 and ins.operands[1] in comp.instrs
                   else ins.type_str)
            totals.bytes += 2.0 * _shape_bytes(upd) * mult
            continue
        opb = sum(_shape_bytes(comp.instrs[o].type_str)
                  for o in ins.operands if o in comp.instrs)
        totals.bytes += (opb + _shape_bytes(ins.type_str)) * mult


def analyze(hlo: str) -> CostTotals:
    comps, entry = parse_module(hlo)
    totals = CostTotals()
    if entry:
        _cost_comp(entry, 1.0, comps, totals)
    return totals
