"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) cell:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_wire_bytes_per_chip / link_bw

Sources: `compiled.cost_analysis()` (the post-SPMD per-device module) gives
flops and bytes-accessed; collective bytes are NOT in cost_analysis, so we
parse the optimized HLO (`compiled.as_text()`) and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Shapes in that module are per-device shard shapes, so
the sums are per-chip wire bytes; multiplying by chip count gives the global
"collective_bytes" of the assignment formula — the two cancel, the reported
term is per-chip bytes / link bandwidth either way.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# collective ops; `-start` variants counted, `-done` skipped (same transfer)
_COLL_RE = re.compile(
    r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind over the optimized module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operands live inside the parens that _COLL_RE matched up to
        args = line[m.end():]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args))
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: Dict[str, int]
    chips: int
    model_flops: float              # 6*N*D (train) / 2*N_active*tokens (serve)
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step-time model: overlapped terms -> max() is the bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs — remat/redundancy waste meter."""
        tot = self.flops_per_chip * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the §Perf score)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * self.peak_flops * t)

    def as_dict(self) -> Dict:
        d = getattr(self, "xla_cost", None)
        extra = {"xla_cost": d} if d else {}
        return {
            **extra,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_by_kind": self.coll_by_kind,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_bound_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def from_compiled(compiled, chips: int, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled artifact.

    Numerators come from the trip-count-aware HLO walk (launch/hlo_cost.py)
    because XLA's cost_analysis counts while bodies once (layer scans /
    attention chunk maps / SSD chunk scans would under-count 24x-94x).  The
    raw cost_analysis numbers are kept in `xla_cost` for reference.
    """
    from . import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    totals = hlo_cost.analyze(text)
    r = Roofline(
        flops_per_chip=totals.flops,
        bytes_per_chip=totals.bytes,
        coll_bytes_per_chip=float(sum(totals.coll.values())),
        coll_by_kind={k: int(v) for k, v in totals.coll.items()},
        chips=chips,
        model_flops=model_flops,
    )
    r.xla_cost = {"flops": float(cost.get("flops", 0.0)),
                  "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    return r


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D prefill, 2*N*B decode (active
    params for MoE)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq
