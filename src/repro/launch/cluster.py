"""Cluster launcher CLI: start worker hosts and drive multi-host runs.

Quickstart (single box -> 2-host local-exec -> ssh template)
-----------------------------------------------------------

1. Single box (no cluster — the in-process partitioned driver):

       PYTHONPATH=src python - <<'EOF'
       from repro.core.phases import PartitionedGenerator
       from repro.core.types import GraphConfig
       cfg = GraphConfig(scale=12, nb=4, shuffle_variant="external")
       with PartitionedGenerator(cfg, "/tmp/g1") as gen:
           gen.run(); gen.walk_corpus(1024, 16)
       EOF

2. Two "hosts" on one box, real process + workdir isolation, socket
   exchange (the loopback deployment shape CI exercises):

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /tmp/cluster --scale 12 --nb 4 \
           --walkers 1024 --length 16

   Each host h gets /tmp/cluster/host{h} (its buckets' stores, CSR files,
   and corpus shards live THERE and only there); the controller keeps
   /tmp/cluster/ctrl with checkpoint state, graph_manifest.json, and
   walks_manifest.json.  Re-running the same command after a crash or a
   host kill resumes: surviving hosts skip all completed work.

3. Real hosts over ssh (or srun — it's just a template).  Host workdirs are
   per-host LOCAL paths; only the controller and exchange ports cross the
   network:

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /data/cluster --scale 30 --nb 64 \
           --host-names node1,node2 \
           --template 'ssh {host} env PYTHONPATH=/repo/src {python} -m \
repro.launch.cluster host --controller {controller} --host-id {host_id} \
--workdir {workdir}'

   (For the template to work, the controller address in `{controller}`
   must be reachable from the worker hosts: `--bind 0.0.0.0` to listen on
   every interface, plus `--advertise 10.0.0.5` — the routable address
   workers should dial; the bound port is appended automatically.)

Training then streams straight from the sharded corpus manifest:

       PYTHONPATH=src python -m repro.launch.train --data external \
           --corpus-manifest /tmp/cluster/ctrl/walks_manifest.json --seq 16

4. Many graphs through one fleet — the multi-tenant job queue.  `submit`
   appends jobs to <workdir>/ctrl/jobqueue.json (no cluster needed);
   `drain` launches the hosts once and runs every queued job
   concurrently, work-stealing style:

       PYTHONPATH=src python -m repro.launch.cluster submit \
           --workdir /tmp/cluster --scale 12 --nb 4 --recompute \
           --walks 1024:16:0:walks.npy
       PYTHONPATH=src python -m repro.launch.cluster submit \
           --workdir /tmp/cluster --scale 13 --nb 4 --recompute --seed 7
       PYTHONPATH=src python -m repro.launch.cluster queue \
           --workdir /tmp/cluster
       PYTHONPATH=src python -m repro.launch.cluster drain \
           --workdir /tmp/cluster --hosts 2 --max-concurrent 2

   Scheduling vocabulary (measured per drain in the summary JSON and in
   benchmarks/bench_jobqueue.py):

   - LEASE: hosts PULL tasks — a poll hands out at most `--lease-size`
     tasks from the host's own queue (0 = the whole queue).  Control
     cost is one ~hundreds-of-bytes header frame per poll/report, never
     per-byte-of-data; leases only bound BATCHING, placement of
     data-bearing tasks stays with the bucket owner.
   - STEAL: an idle host with an empty queue takes stealable tasks
     (communication-free recompute kernels — no local inputs) from the
     tail of the longest peer queue, so one job's straggler never idles
     the fleet.  `steals` in the drain summary counts migrations.
   - OVERLAP FACTOR: serial_makespan / queued_makespan for the same job
     set — >1 means independent jobs' I/O and exchange phases really
     did interleave; `utilization` (busy-seconds / fleet-seconds) is
     the same effect as a ratio.
   - DEAD-LETTER: a task failing deterministically past `--lease-budget`
     dispatches parks its JOB (queue keeps draining, partial stores
     GC'd) — bulkhead semantics, one poisoned job can't wedge the rest.
   - Walk specs W:L:seed:out submitted together with `--fuse-walks`
     advance through ONE CSR scan per hop (walk_hop_fused), k corpora
     for one read pass.

   Every job's artifacts stay bit-identical to a serial single-job run;
   each job's stores live under the job's namespace subdir of every job's host
   workdir plus <ctrl>/<job tag>/ for manifests and checkpoints.

5. Skew rebalancing + elastic hosts.  RMAT degree skew concentrates hot
   buckets on a few hosts; the controller's versioned shard map can move
   those bucket shards to colder (or freshly admitted) hosts between
   phases.  Start a run with rebalancing armed — the controller snapshots
   per-bucket I/O from the ledgers at every phase barrier, plans a greedy
   migration off the hottest host, and ships the shards over the exchange
   transport (MIGRATE frames, ack-after-durable, resumable):

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /tmp/cluster --scale 14 --nb 8 --rebalance

   Or drive it by hand from a second terminal while a run is live (the
   run drops its control address in <workdir>/ctrl/controller_addr):

       # one-shot: arm a rebalance at the next phase barrier
       PYTHONPATH=src python -m repro.launch.cluster rebalance \
           --workdir /tmp/cluster
       # elastic admission: a new empty host joins mid-run; the next
       # rebalance assigns it shards, later phases run on it
       PYTHONPATH=src python -m repro.launch.cluster admit \
           --workdir /tmp/cluster --host-workdir /tmp/cluster/host2
       # inspect the live map, per-bucket byte loads, and host roster
       PYTHONPATH=src python -m repro.launch.cluster status \
           --workdir /tmp/cluster

   Invariants the rebalancer keeps (tests/test_shardmap.py asserts all
   of them): artifacts stay BIT-IDENTICAL to the never-rebalanced run —
   the map changes where bytes live, never what they are; migrations are
   checkpointed per file, so a killed host resumes without re-sending
   completed shards; frames routed under a stale map version are refused
   by the receiving host.  benchmarks/bench_skew.py measures the payoff
   (makespan + per-host byte spread, static vs rebalanced).

6. Overlapped I/O.  Every host's external kernels overlap disk reads and
   writes with compute by default (GraphConfig.io_overlap — merge-cursor
   prefetch + write-behind emission, core/blockstore.py); outputs are
   bit-identical with the flag off, so flipping it never invalidates a
   checkpoint.  Force the strictly serial path for a run or a single
   host with the environment override:

       REPRO_IO_OVERLAP=0 PYTHONPATH=src python -m repro.launch.cluster \
           run --hosts 2 --workdir /tmp/cluster --scale 14 --nb 8

   The time the pipeline could NOT hide shows up in every ledger
   surfaced by `status` and the per-phase orchestrator deltas:
   `read_wait_s` (consumer stalled on an unfinished prefetch),
   `write_wait_s` (producer stalled on the in-flight chunk), and
   `overlap_s` (I/O seconds that ran hidden behind compute).
   benchmarks/bench_overlap.py gates the wall-time win.

7. Tracing + live telemetry (core/trace.py).  Every layer — orchestrator
   phases, the ~23 bucket kernels, external sort/merge/partition passes,
   prefetch/write-behind stalls, exchange frames, migrations, controller
   barriers — emits structured spans when a run is traced.  Tracing is
   timing-only: trace=False runs are bit-identical AND checkpoint-
   compatible with traced ones (result_config_key normalizes the flag
   out), and the tracer is a no-op stub unless armed:

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /tmp/cluster --scale 12 --nb 4 --trace

   Each process appends to its own <workdir>/trace/trace_<pid>.jsonl;
   hosts ship completed lines to the controller piggybacked on the task
   loop, landing in <ctrl>/trace/host<h>.jsonl.  Merge every lane into
   one Chrome/Perfetto trace-event file (open it at https://ui.perfetto.dev
   or chrome://tracing) and print the per-phase wall-time table:

       PYTHONPATH=src python -m repro.launch.cluster trace \
           --workdir /tmp/cluster

   (`--out` overrides the default <ctrl>/trace_merged.json; the merge
   also runs the timeline validator — negative durations or span-nesting
   violations print as warnings, not errors.)  `REPRO_TRACE=1` force-arms
   tracing for any run without touching configs, exactly like
   REPRO_IO_OVERLAP.

   While a run is live, watch the fleet instead of polling JSON: the
   `status` admin RPC now carries a per-host live view — current phase
   key, queue depth, in-flight tasks, busy seconds, heartbeat age, and
   the unified metrics snapshot (io / stalls / wire / memory, the same
   schema BENCH_*.json embeds):

       PYTHONPATH=src python -m repro.launch.cluster status \
           --workdir /tmp/cluster --watch            # redraws every 2 s

Subcommands: `host` (the worker daemon an exec backend or an operator
starts), `run` (controller + hosts end to end), `spec` (emit a ClusterSpec
JSON for external orchestration), `submit`/`queue`/`drain` (the job
queue), `status`/`rebalance`/`admit` (admin RPCs against a live
controller; `status --watch` is the live fleet view), `trace` (merge a
run's span files into one Perfetto-loadable timeline).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import socket
import sys
import time

from ..core.cluster import (
    ClusterError,
    ClusterGenerator,
    ClusterSpec,
    CommandTemplateBackend,
    HostRunner,
    HostSpec,
    LocalExecBackend,
    _ctrl_request,
)
from ..core.jobqueue import JobScheduler, load_state, submit_job
from ..core.trace import (
    merge_traces,
    phase_durations,
    validate_timeline,
    write_perfetto,
)
from ..core.types import GraphConfig


def _build_spec(args) -> ClusterSpec:
    names = (args.host_names.split(",") if args.host_names else
             ["127.0.0.1"] * args.hosts)
    if len(names) != args.hosts:
        raise SystemExit(f"--host-names lists {len(names)} names for "
                         f"--hosts {args.hosts}")
    root = os.path.abspath(args.workdir)
    return ClusterSpec(
        nb=args.nb,
        controller_host=args.bind,
        hosts=tuple(HostSpec(h, os.path.join(root, f"host{h}"), names[h])
                    for h in range(args.hosts)))


def cmd_host(args) -> int:
    HostRunner(args.workdir, args.host_id, args.controller,
               workers=args.workers, checkpoint=not args.no_checkpoint,
               max_tasks=args.max_tasks,
               exchange_host=args.exchange_host).run()
    return 0


def cmd_spec(args) -> int:
    spec = _build_spec(args)
    path = os.path.abspath(args.out) if args.out else os.path.join(
        os.path.abspath(args.workdir), "cluster_spec.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    spec.save(path)
    print(path)
    return 0


def cmd_run(args) -> int:
    spec = _build_spec(args)
    cfg = GraphConfig(scale=args.scale, nb=args.nb, edge_factor=args.edge_factor,
                      chunk_edges=args.chunk_edges, seed=args.seed,
                      shuffle_variant="external", transport="socket",
                      merge_fanin=args.merge_fanin,
                      pooled_cascade=args.pooled_cascade,
                      trace=args.trace)
    backend = (CommandTemplateBackend(args.template) if args.template
               else LocalExecBackend(workers=args.workers))
    ctrl_dir = os.path.join(os.path.abspath(args.workdir), "ctrl")
    gen = ClusterGenerator(cfg, spec, ctrl_dir, backend=backend,
                           checkpoint=not args.no_checkpoint,
                           max_restarts=args.max_restarts,
                           barrier_timeout=args.barrier_timeout,
                           advertise=args.advertise or None,
                           rebalance=args.rebalance)
    _write_ctrl_addr(ctrl_dir, gen.controller.public_addr)
    try:
        manifest, ledger = gen.run(csr_variant=args.csr_variant)
        print(f"[graph] manifest {manifest}")
        summary = {"graph_manifest": manifest, "ledger": ledger.as_dict(),
                   "restarts": gen.controller.restarts}
        if args.walkers > 0:
            walks = gen.walk_corpus(args.walkers, args.length,
                                    seed=args.walk_seed)
            print(f"[corpus] manifest {walks.manifest_path} "
                  f"({walks.num_walkers} x {walks.length + 1})")
            summary["corpus_manifest"] = walks.manifest_path
        print(json.dumps(summary, indent=1))
    finally:
        gen.close()
    if args.trace:
        # Merge AFTER close: closing stops the hosts, whose shutdown path
        # ships any trace lines still sitting in their local files.
        _merge_run_trace(os.path.abspath(args.workdir), "")
    return 0


def _write_ctrl_addr(ctrl_dir: str, addr: str) -> None:
    """Drop the live controller's admin address where the `status` /
    `rebalance` / `admit` subcommands expect it (best effort — an
    operator can always pass --controller explicitly)."""
    os.makedirs(ctrl_dir, exist_ok=True)
    with open(os.path.join(ctrl_dir, "controller_addr"), "w") as f:
        f.write(addr)


def _ctrl_addr(args) -> str:
    if getattr(args, "controller", ""):
        return args.controller
    path = os.path.join(os.path.abspath(args.workdir), "ctrl",
                        "controller_addr")
    try:
        with open(path) as f:
            return f.read().strip()
    except OSError:
        raise SystemExit(f"no --controller given and {path} not found "
                         "(is a run live in this workdir?)")


def _admin_request(addr: str, req: dict) -> dict:
    host, _, port = addr.rpartition(":")
    with socket.create_connection((host, int(port)), timeout=30.0) as sock:
        return _ctrl_request(sock, {"op": "admin", **req})


def _trace_dirs(root: str):
    """Every place a run's span files can live under one launcher root:
    the controller's own lane + shipped host lanes (ctrl/trace), per-job
    controller workdirs (ctrl/<jobNNNN>/trace), and the hosts' LOCAL trace
    dirs — including namespace subdirs — which cover lines a host never
    got to ship (same-box and shared-fs deployments see them directly)."""
    pats = ("ctrl/trace", "ctrl/*/trace", "host*/trace", "host*/*/trace")
    dirs = []
    for pat in pats:
        dirs.extend(sorted(glob.glob(os.path.join(root, pat))))
    return [d for d in dirs if os.path.isdir(d)]


def _merge_run_trace(root: str, out: str) -> int:
    dirs = _trace_dirs(root)
    events = merge_traces(dirs)
    if not events:
        print(f"no trace events under {root} — was the run started with "
              "--trace (or REPRO_TRACE=1)?", file=sys.stderr)
        return 1
    warns = validate_timeline(events)
    for w in warns[:20]:
        print(f"[trace-warn] {w}", file=sys.stderr)
    if len(warns) > 20:
        print(f"[trace-warn] ... {len(warns) - 20} more", file=sys.stderr)
    path = os.path.abspath(out) if out else os.path.join(
        root, "ctrl", "trace_merged.json")
    write_perfetto(events, path)
    lanes = {(e.get("host"), e.get("pid")) for e in events}
    print(f"[trace] {len(events)} events across {len(lanes)} process "
          f"lane(s) -> {path}")
    durs = phase_durations(events)
    if durs:
        width = max(len(n) for n in durs)
        for name in sorted(durs, key=durs.get, reverse=True):
            print(f"  {name:<{width}}  {durs[name]:9.3f}s")
        print(f"  {'[sum of phases]':<{width}}  {sum(durs.values()):9.3f}s")
    return 0


def cmd_trace(args) -> int:
    return _merge_run_trace(os.path.abspath(args.workdir), args.out)


def _fmt_status_table(st: dict) -> str:
    """Compact per-host fleet table from the status RPC's hosts_live view."""
    rows = [f"{'host':>4}  {'phase':<34} {'queue':>5} {'infl':>4} "
            f"{'done':>5} {'busy_s':>8} {'hb_age':>6} {'MB_rd':>8} "
            f"{'MB_wr':>8} {'MB_wire':>8} {'stall_s':>7}"]
    for hid in sorted(st.get("hosts_live", {}), key=int):
        h = st["hosts_live"][hid]
        m = h.get("metrics", {})
        io, stalls, wire = (m.get("io", {}), m.get("stalls", {}),
                            m.get("wire", {}))
        age = h.get("heartbeat_age_s")
        wire_mb = (wire.get("bytes_sent", 0) + wire.get("bytes_recv", 0)) / 1e6
        stall = stalls.get("read_wait_s", 0.0) + stalls.get("write_wait_s", 0.0)
        rows.append(
            f"{hid:>4}  {(h.get('phase') or '-')[:34]:<34} "
            f"{h.get('queue', 0):>5} {h.get('inflight', 0):>4} "
            f"{h.get('tasks_done', 0):>5} {h.get('busy_seconds', 0.0):>8.1f} "
            f"{('-' if age is None else f'{age:.0f}'):>6} "
            f"{io.get('bytes_read', 0) / 1e6:>8.1f} "
            f"{io.get('bytes_written', 0) / 1e6:>8.1f} "
            f"{wire_mb:>8.1f} {stall:>7.2f}")
    rows.append(f"steals={st.get('steals', 0)} "
                f"rebalance_armed={st.get('rebalance_requested', False)} "
                f"map_v{st.get('map', {}).get('version', 0)}")
    return "\n".join(rows)


def cmd_status(args) -> int:
    addr = _ctrl_addr(args)
    if not args.watch:
        print(json.dumps(_admin_request(addr, {"cmd": "status"}),
                         indent=1, sort_keys=True))
        return 0
    try:
        while True:
            st = _admin_request(addr, {"cmd": "status"})
            # ANSI clear + home keeps the table in place like `watch(1)`.
            sys.stdout.write("\x1b[2J\x1b[H" + _fmt_status_table(st) + "\n")
            sys.stdout.flush()
            time.sleep(max(0.2, args.interval))
    except KeyboardInterrupt:
        return 0
    except (OSError, ClusterError):
        print("controller gone; exiting watch", file=sys.stderr)
        return 0


def cmd_rebalance(args) -> int:
    _admin_request(_ctrl_addr(args), {"cmd": "rebalance"})
    print("rebalance armed: plan/migrate/commit runs at the next "
          "phase barrier")
    return 0


def cmd_admit(args) -> int:
    out = _admin_request(_ctrl_addr(args), {
        "cmd": "admit",
        "workdir": os.path.abspath(args.host_workdir),
        "host": args.host_name,
        "launch": not args.no_launch,
    })
    print(json.dumps(out))
    return 0


def _parse_walk_spec(s: str):
    parts = s.split(":")
    if len(parts) != 4:
        raise SystemExit(f"walk spec {s!r} is not W:L:seed:out_name")
    return (int(parts[0]), int(parts[1]), int(parts[2]), parts[3])


def _queue_root(args) -> str:
    return os.path.join(os.path.abspath(args.workdir), "ctrl")


def cmd_submit(args) -> int:
    cfg = GraphConfig(scale=args.scale, nb=args.nb,
                      edge_factor=args.edge_factor,
                      chunk_edges=args.chunk_edges, seed=args.seed,
                      shuffle_variant=("recompute" if args.recompute
                                       else "external"),
                      transport="socket", merge_fanin=args.merge_fanin)
    job = submit_job(_queue_root(args), cfg, csr_variant=args.csr_variant,
                     walks=[_parse_walk_spec(w) for w in args.walks],
                     fuse_walks=args.fuse_walks,
                     fuse_gen_relabel=args.fuse_gen_relabel,
                     name=args.name)
    print(json.dumps({"job": job.tag, "name": job.name,
                      "tasks": job.num_tasks, "phases": len(job.plan)}))
    return 0


def cmd_queue(args) -> int:
    state = load_state(_queue_root(args))
    for d in state["jobs"]:
        print(f"{d['job_id']:>6} {d.get('name', ''):<16} "
              f"{d['status']:<8} "
              f"{sum(len(p['keys']) for p in d.get('plan', [])):>5} tasks  "
              f"{d.get('error', '')}")
    for dl in state["dead_letters"]:
        print(f"[dead-letter] {dl['job']}: {dl['task_key']} "
              f"after {dl['attempts']} attempt(s)")
    return 0


def cmd_drain(args) -> int:
    spec = _build_spec(args)
    backend = (CommandTemplateBackend(args.template) if args.template
               else LocalExecBackend(workers=args.workers))
    sched = JobScheduler(spec, _queue_root(args), backend=backend,
                         max_concurrent=args.max_concurrent,
                         lease_size=args.lease_size,
                         lease_budget=args.lease_budget,
                         max_restarts=args.max_restarts,
                         barrier_timeout=args.barrier_timeout,
                         checkpoint=not args.no_checkpoint,
                         advertise=args.advertise or None)
    _write_ctrl_addr(_queue_root(args), sched.controller.public_addr)
    try:
        summary = sched.drain()
        print(json.dumps(summary, indent=1))
        return 0 if not summary["dead_letters"] else 2
    finally:
        sched.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.cluster")
    sub = ap.add_subparsers(dest="cmd", required=True)

    h = sub.add_parser("host", help="worker-host daemon (one per machine)")
    h.add_argument("--controller", required=True, help="controller host:port")
    h.add_argument("--host-id", type=int, required=True)
    h.add_argument("--workdir", required=True)
    h.add_argument("--workers", type=int, default=0,
                   help="local spawn-pool size (0 = in-process)")
    h.add_argument("--no-checkpoint", action="store_true")
    h.add_argument("--exchange-host", default="127.0.0.1",
                   help="bind address of this host's ExchangeServer")
    h.add_argument("--max-tasks", type=int, default=0,
                   help="crash-test hook: hard-exit after N executed tasks")
    h.set_defaults(fn=cmd_host)

    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--hosts", type=int, default=2)
    common.add_argument("--workdir", required=True,
                        help="root dir: host{h}/ per host + ctrl/")
    common.add_argument("--nb", type=int, default=4)
    common.add_argument("--bind", default="127.0.0.1",
                        help="controller bind address")
    common.add_argument("--advertise", default="",
                        help="controller address workers dial, when it "
                             "differs from --bind (e.g. bind 0.0.0.0, "
                             "advertise the routable interface); bare "
                             "hostnames get the bound port appended")
    common.add_argument("--host-names", default="",
                        help="comma list of launch targets for {host}")

    s = sub.add_parser("spec", parents=[common],
                       help="emit a ClusterSpec JSON")
    s.add_argument("--out", default="")
    s.set_defaults(fn=cmd_spec)

    r = sub.add_parser("run", parents=[common],
                       help="controller + hosts, generation (+ walks)")
    r.add_argument("--scale", type=int, default=12)
    r.add_argument("--edge-factor", type=int, default=4)
    r.add_argument("--chunk-edges", type=int, default=1 << 14)
    r.add_argument("--seed", type=int, default=0x5EED_1234)
    r.add_argument("--merge-fanin", type=int, default=64)
    r.add_argument("--pooled-cascade", action="store_true",
                   help="dispatch cascade merge levels through the cluster")
    r.add_argument("--csr-variant", choices=("sorted", "scatter"),
                   default="sorted")
    r.add_argument("--walkers", type=int, default=0,
                   help="walk-corpus size (0 = generation only)")
    r.add_argument("--length", type=int, default=16)
    r.add_argument("--walk-seed", type=int, default=0)
    r.add_argument("--workers", type=int, default=0,
                   help="per-host local pool size (local backend)")
    r.add_argument("--template", default="",
                   help="command template backend (ssh/srun); see module doc")
    r.add_argument("--max-restarts", type=int, default=1)
    r.add_argument("--barrier-timeout", type=float, default=600.0)
    r.add_argument("--no-checkpoint", action="store_true")
    r.add_argument("--rebalance", action="store_true",
                   help="rebalance hot bucket shards off straggler hosts "
                        "at every phase barrier (skew-aware shard map)")
    r.add_argument("--trace", action="store_true",
                   help="emit spans on every host + the controller and "
                        "merge them into <ctrl>/trace_merged.json "
                        "(Perfetto trace-event format) when the run ends; "
                        "timing-only, outputs stay bit-identical")
    r.set_defaults(fn=cmd_run)

    admin = argparse.ArgumentParser(add_help=False)
    admin.add_argument("--workdir", default="",
                       help="run root; reads <workdir>/ctrl/controller_addr")
    admin.add_argument("--controller", default="",
                       help="controller host:port (overrides --workdir)")

    st = sub.add_parser("status", parents=[admin],
                        help="live shard map, bucket loads, host roster, "
                             "per-host telemetry (--watch for a live view)")
    st.add_argument("--watch", action="store_true",
                    help="redraw a compact per-host fleet table until ^C")
    st.add_argument("--interval", type=float, default=2.0,
                    help="seconds between --watch polls")
    st.set_defaults(fn=cmd_status)

    tr = sub.add_parser("trace",
                        help="merge a traced run's span files into one "
                             "Perfetto-loadable timeline + phase table")
    tr.add_argument("--workdir", required=True,
                    help="the run root passed to `run`/`drain`")
    tr.add_argument("--out", default="",
                    help="output path (default <workdir>/ctrl/"
                         "trace_merged.json)")
    tr.set_defaults(fn=cmd_trace)

    rb = sub.add_parser("rebalance", parents=[admin],
                        help="arm a shard rebalance at the next phase "
                             "barrier of the live run")
    rb.set_defaults(fn=cmd_rebalance)

    ad = sub.add_parser("admit", parents=[admin],
                        help="admit a new host into the live cluster "
                             "(owns nothing until the next rebalance)")
    ad.add_argument("--host-workdir", required=True,
                    help="the new host's LOCAL workdir")
    ad.add_argument("--host-name", default="127.0.0.1",
                    help="launch target for the backend template")
    ad.add_argument("--no-launch", action="store_true",
                    help="register only; the operator starts the `host` "
                         "daemon out of band")
    ad.set_defaults(fn=cmd_admit)

    sb = sub.add_parser("submit", help="append one job to the queue "
                                       "(no cluster needed)")
    sb.add_argument("--workdir", required=True)
    sb.add_argument("--nb", type=int, default=4)
    sb.add_argument("--scale", type=int, default=12)
    sb.add_argument("--edge-factor", type=int, default=4)
    sb.add_argument("--chunk-edges", type=int, default=1 << 14)
    sb.add_argument("--seed", type=int, default=0x5EED_1234)
    sb.add_argument("--merge-fanin", type=int, default=64)
    sb.add_argument("--recompute", action="store_true",
                    help="shuffle_variant='recompute' (makes generation "
                         "tasks stealable)")
    sb.add_argument("--fuse-gen-relabel", action="store_true",
                    help="one fused regenerate+relabel barrier "
                         "(recompute only)")
    sb.add_argument("--csr-variant", choices=("sorted", "scatter"),
                    default="sorted")
    sb.add_argument("--walks", action="append", default=[],
                    metavar="W:L:seed:out",
                    help="walk corpus spec; repeatable")
    sb.add_argument("--fuse-walks", action="store_true",
                    help="advance all this job's corpora through one CSR "
                         "scan per hop")
    sb.add_argument("--name", default="")
    sb.set_defaults(fn=cmd_submit)

    q = sub.add_parser("queue", help="print queue + dead-letter state")
    q.add_argument("--workdir", required=True)
    q.set_defaults(fn=cmd_queue)

    d = sub.add_parser("drain", parents=[common],
                       help="launch hosts once, run every queued job "
                            "(work-stealing, overlapped)")
    d.add_argument("--max-concurrent", type=int, default=2)
    d.add_argument("--lease-size", type=int, default=2,
                   help="tasks handed out per host poll (0 = whole queue)")
    d.add_argument("--lease-budget", type=int, default=2,
                   help="dispatches a deterministically failing task gets "
                        "before its job dead-letters")
    d.add_argument("--workers", type=int, default=0)
    d.add_argument("--template", default="")
    d.add_argument("--max-restarts", type=int, default=1)
    d.add_argument("--barrier-timeout", type=float, default=600.0)
    d.add_argument("--no-checkpoint", action="store_true")
    d.set_defaults(fn=cmd_drain)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
