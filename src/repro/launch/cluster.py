"""Cluster launcher CLI: start worker hosts and drive multi-host runs.

Quickstart (single box -> 2-host local-exec -> ssh template)
-----------------------------------------------------------

1. Single box (no cluster — the in-process partitioned driver):

       PYTHONPATH=src python - <<'EOF'
       from repro.core.phases import PartitionedGenerator
       from repro.core.types import GraphConfig
       cfg = GraphConfig(scale=12, nb=4, shuffle_variant="external")
       with PartitionedGenerator(cfg, "/tmp/g1") as gen:
           gen.run(); gen.walk_corpus(1024, 16)
       EOF

2. Two "hosts" on one box, real process + workdir isolation, socket
   exchange (the loopback deployment shape CI exercises):

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /tmp/cluster --scale 12 --nb 4 \
           --walkers 1024 --length 16

   Each host h gets /tmp/cluster/host{h} (its buckets' stores, CSR files,
   and corpus shards live THERE and only there); the controller keeps
   /tmp/cluster/ctrl with checkpoint state, graph_manifest.json, and
   walks_manifest.json.  Re-running the same command after a crash or a
   host kill resumes: surviving hosts skip all completed work.

3. Real hosts over ssh (or srun — it's just a template).  Host workdirs are
   per-host LOCAL paths; only the controller and exchange ports cross the
   network:

       PYTHONPATH=src python -m repro.launch.cluster run \
           --hosts 2 --workdir /data/cluster --scale 30 --nb 64 \
           --host-names node1,node2 \
           --template 'ssh {host} env PYTHONPATH=/repo/src {python} -m \
repro.launch.cluster host --controller {controller} --host-id {host_id} \
--workdir {workdir}'

   (For the template to work, the controller address in `{controller}`
   must be reachable from the worker hosts: `--bind 0.0.0.0` to listen on
   every interface, plus `--advertise 10.0.0.5` — the routable address
   workers should dial; the bound port is appended automatically.)

Training then streams straight from the sharded corpus manifest:

       PYTHONPATH=src python -m repro.launch.train --data external \
           --corpus-manifest /tmp/cluster/ctrl/walks_manifest.json --seq 16

Subcommands: `host` (the worker daemon an exec backend or an operator
starts), `run` (controller + hosts end to end), `spec` (emit a ClusterSpec
JSON for external orchestration).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from ..core.cluster import (
    ClusterGenerator,
    ClusterSpec,
    CommandTemplateBackend,
    HostRunner,
    HostSpec,
    LocalExecBackend,
)
from ..core.types import GraphConfig


def _build_spec(args) -> ClusterSpec:
    names = (args.host_names.split(",") if args.host_names else
             ["127.0.0.1"] * args.hosts)
    if len(names) != args.hosts:
        raise SystemExit(f"--host-names lists {len(names)} names for "
                         f"--hosts {args.hosts}")
    root = os.path.abspath(args.workdir)
    return ClusterSpec(
        nb=args.nb,
        controller_host=args.bind,
        hosts=tuple(HostSpec(h, os.path.join(root, f"host{h}"), names[h])
                    for h in range(args.hosts)))


def cmd_host(args) -> int:
    HostRunner(args.workdir, args.host_id, args.controller,
               workers=args.workers, checkpoint=not args.no_checkpoint,
               max_tasks=args.max_tasks,
               exchange_host=args.exchange_host).run()
    return 0


def cmd_spec(args) -> int:
    spec = _build_spec(args)
    path = os.path.abspath(args.out) if args.out else os.path.join(
        os.path.abspath(args.workdir), "cluster_spec.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    spec.save(path)
    print(path)
    return 0


def cmd_run(args) -> int:
    spec = _build_spec(args)
    cfg = GraphConfig(scale=args.scale, nb=args.nb, edge_factor=args.edge_factor,
                      chunk_edges=args.chunk_edges, seed=args.seed,
                      shuffle_variant="external", transport="socket",
                      merge_fanin=args.merge_fanin,
                      pooled_cascade=args.pooled_cascade)
    backend = (CommandTemplateBackend(args.template) if args.template
               else LocalExecBackend(workers=args.workers))
    ctrl_dir = os.path.join(os.path.abspath(args.workdir), "ctrl")
    gen = ClusterGenerator(cfg, spec, ctrl_dir, backend=backend,
                           checkpoint=not args.no_checkpoint,
                           max_restarts=args.max_restarts,
                           barrier_timeout=args.barrier_timeout,
                           advertise=args.advertise or None)
    try:
        manifest, ledger = gen.run(csr_variant=args.csr_variant)
        print(f"[graph] manifest {manifest}")
        summary = {"graph_manifest": manifest, "ledger": ledger.as_dict(),
                   "restarts": gen.controller.restarts}
        if args.walkers > 0:
            walks = gen.walk_corpus(args.walkers, args.length,
                                    seed=args.walk_seed)
            print(f"[corpus] manifest {walks.manifest_path} "
                  f"({walks.num_walkers} x {walks.length + 1})")
            summary["corpus_manifest"] = walks.manifest_path
        print(json.dumps(summary, indent=1))
        return 0
    finally:
        gen.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.cluster")
    sub = ap.add_subparsers(dest="cmd", required=True)

    h = sub.add_parser("host", help="worker-host daemon (one per machine)")
    h.add_argument("--controller", required=True, help="controller host:port")
    h.add_argument("--host-id", type=int, required=True)
    h.add_argument("--workdir", required=True)
    h.add_argument("--workers", type=int, default=0,
                   help="local spawn-pool size (0 = in-process)")
    h.add_argument("--no-checkpoint", action="store_true")
    h.add_argument("--exchange-host", default="127.0.0.1",
                   help="bind address of this host's ExchangeServer")
    h.add_argument("--max-tasks", type=int, default=0,
                   help="crash-test hook: hard-exit after N executed tasks")
    h.set_defaults(fn=cmd_host)

    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--hosts", type=int, default=2)
    common.add_argument("--workdir", required=True,
                        help="root dir: host{h}/ per host + ctrl/")
    common.add_argument("--nb", type=int, default=4)
    common.add_argument("--bind", default="127.0.0.1",
                        help="controller bind address")
    common.add_argument("--advertise", default="",
                        help="controller address workers dial, when it "
                             "differs from --bind (e.g. bind 0.0.0.0, "
                             "advertise the routable interface); bare "
                             "hostnames get the bound port appended")
    common.add_argument("--host-names", default="",
                        help="comma list of launch targets for {host}")

    s = sub.add_parser("spec", parents=[common],
                       help="emit a ClusterSpec JSON")
    s.add_argument("--out", default="")
    s.set_defaults(fn=cmd_spec)

    r = sub.add_parser("run", parents=[common],
                       help="controller + hosts, generation (+ walks)")
    r.add_argument("--scale", type=int, default=12)
    r.add_argument("--edge-factor", type=int, default=4)
    r.add_argument("--chunk-edges", type=int, default=1 << 14)
    r.add_argument("--seed", type=int, default=0x5EED_1234)
    r.add_argument("--merge-fanin", type=int, default=64)
    r.add_argument("--pooled-cascade", action="store_true",
                   help="dispatch cascade merge levels through the cluster")
    r.add_argument("--csr-variant", choices=("sorted", "scatter"),
                   default="sorted")
    r.add_argument("--walkers", type=int, default=0,
                   help="walk-corpus size (0 = generation only)")
    r.add_argument("--length", type=int, default=16)
    r.add_argument("--walk-seed", type=int, default=0)
    r.add_argument("--workers", type=int, default=0,
                   help="per-host local pool size (local backend)")
    r.add_argument("--template", default="",
                   help="command template backend (ssh/srun); see module doc")
    r.add_argument("--max-restarts", type=int, default=1)
    r.add_argument("--barrier-timeout", type=float, default=600.0)
    r.add_argument("--no-checkpoint", action="store_true")
    r.set_defaults(fn=cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
