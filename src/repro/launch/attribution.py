"""Byte/flop attribution over HLO — the §Perf profiling lens.

`top_bytes(hlo)` returns the heaviest memory-traffic instructions with their
while-trip multipliers applied; `by_op(hlo)` aggregates per op kind.  This is
the dry-run's substitute for a wall-clock profile: optimization iterations
read this table, pick the dominant contributor, and attack it.
"""

from __future__ import annotations

from collections import Counter
from typing import List, Tuple

from . import hlo_cost


def _walk(comps, entry):
    """Yield (bytes, 'comp/instr:op', type_str) with multipliers applied."""
    items: List[Tuple[float, str, str]] = []

    def walk(name, mult, fusion_ctx=False):
        comp = comps.get(name)
        if comp is None:
            return
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.op
            if op.endswith("-done"):
                continue
            if op == "while":
                am = dict(hlo_cost._ATTR_CALL_RE.findall(ins.attrs))
                cond = am.get("condition")
                trip = (hlo_cost._trip_count(comps[cond])
                        if cond in comps else 1)
                walk(am.get("body"), mult * trip)
                continue
            if op == "fusion":
                if not fusion_ctx:
                    b = hlo_cost._fusion_boundary_bytes(ins, comp, comps) * mult
                    items.append((b, f"{name}/{iname}:fusion", ins.type_str))
                continue
            if op in ("call", "conditional"):
                am = dict(hlo_cost._ATTR_CALL_RE.findall(ins.attrs))
                for key in ("calls", "to_apply", "body"):
                    if key in am:
                        walk(am[key], mult, fusion_ctx)
                continue
            if op in hlo_cost._SKIP_BYTES_OPS or fusion_ctx:
                continue
            if op in ("dynamic-slice", "gather"):
                b = 2 * hlo_cost._shape_bytes(ins.type_str) * mult
            elif op in ("dynamic-update-slice", "scatter"):
                upd = (comp.instrs[ins.operands[1]].type_str
                       if len(ins.operands) > 1 and ins.operands[1] in comp.instrs
                       else ins.type_str)
                b = 2 * hlo_cost._shape_bytes(upd) * mult
            else:
                opb = sum(hlo_cost._shape_bytes(comp.instrs[o].type_str)
                          for o in ins.operands if o in comp.instrs)
                b = (opb + hlo_cost._shape_bytes(ins.type_str)) * mult
            items.append((b, f"{name}/{iname}:{op}", ins.type_str))

    walk(entry, 1.0)
    return items


def top_bytes(hlo: str, n: int = 15):
    comps, entry = hlo_cost.parse_module(hlo)
    items = _walk(comps, entry)
    items.sort(reverse=True)
    return items[:n]


def by_op(hlo: str):
    comps, entry = hlo_cost.parse_module(hlo)
    agg = Counter()
    for b, name, _ in _walk(comps, entry):
        agg[name.rsplit(":", 1)[-1]] += b
    return agg.most_common()
