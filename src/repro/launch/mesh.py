"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins XLA_FLAGS before first jax init;
smoke tests must keep seeing 1 device).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — the §Roofline denominators.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    # single-pod mesh on the 512-device dry-run runtime: take the first pod
    assert len(devs) >= n, (len(devs), n)
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_graph_mesh(n_shards: int | None = None, axis: str = "shards"):
    """1-D mesh for the graph-generation pipeline (paper's nb compute nodes)."""
    import numpy as np

    devs = jax.devices()
    n = n_shards or len(devs)
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))
