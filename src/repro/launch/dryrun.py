import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import: jax locks the device
# count at first init, and the multi-pod dry-run needs 512 placeholder CPU
# devices to build the production meshes.  Everything below is ordinary.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from ..configs.base import SHAPES, arch_ids, get_config   # noqa: E402
from . import roofline as rl                              # noqa: E402
from .cells import build_cell, cell_supported             # noqa: E402
from .mesh import MULTI_POD_CHIPS, SINGLE_POD_CHIPS       # noqa: E402


def production_mesh(multi_pod: bool):
    from .mesh import make_production_mesh

    return make_production_mesh(multi_pod=multi_pod)


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not implement it
        return {"error": repr(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out or {"repr": repr(ma)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             verbose: bool = True, cell_kwargs=None):
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": MULTI_POD_CHIPS if multi_pod else SINGLE_POD_CHIPS}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    mesh = production_mesh(multi_pod)
    cell = build_cell(arch, shape, mesh, **(cell_kwargs or {}))
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hlo = compiled.as_text()
    roof = rl.from_compiled(
        compiled, rec["chips"], rl.model_flops_for_cell(cfg, shape),
        hlo_text=hlo)
    mem = _memory_analysis_dict(compiled)
    rec.update({
        "status": "ok",
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "roofline": roof.as_dict(),
        "hlo_bytes": len(hlo),
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops/chip={:.3e} bytes/chip={:.3e}".format(
            roof.flops_per_chip, roof.bytes_per_chip))
        print("  collectives/chip:", roof.coll_by_kind)
        print("  roofline: compute {:.3e}s memory {:.3e}s collective {:.3e}s"
              " -> {} bound, useful-flops ratio {:.3f}, MFU bound {:.3f}".format(
                  roof.t_compute, roof.t_memory, roof.t_collective,
                  roof.bottleneck, roof.useful_flops_ratio, roof.mfu_bound))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see configs.base.arch_ids)")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="", help="append JSONL records here")
    args = ap.parse_args()

    archs = arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    failures.append(rec)
                    print(f"[{arch} x {shape} x {rec['mesh']}] FAILED: {e!r}")
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{len(failures)} failed, {len(records)} total ===")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
