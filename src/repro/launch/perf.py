"""§Perf hillclimbing driver: re-lower a cell under named variants and diff
the roofline terms.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen3-moe-235b-a22b \
        --shape train_4k --variants baseline,accum8,sp,remat_none

Each variant is hypothesis -> change -> re-lower -> re-analyse; the JSONL
output is the §Perf iteration log's data.  Variants compose with '+'
(e.g. accum8+sp).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict  # noqa: E402

from ..configs.base import SHAPES, get_config           # noqa: E402
from . import roofline as rl                            # noqa: E402
from .attribution import by_op, top_bytes               # noqa: E402
from .cells import build_cell                           # noqa: E402
from .dryrun import _memory_analysis_dict, production_mesh  # noqa: E402

# Each variant: dict of build_cell overrides (cfg_update applies to the
# ModelConfig; the rest are build_cell kwargs).
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    # gradient accumulation: 8 microbatches -> 1/8 of the live activations
    # (HBM fit), slightly more flops (per-microbatch remat/loss overhead)
    "accum8": {"accum_steps": 8},
    "accum4": {"accum_steps": 4},
    "accum16": {"accum_steps": 16},
    # sequence parallelism: residual stream sharded over "model" between
    # blocks; the TP activation all-reduce becomes reduce-scatter/all-gather
    "sp": {"rule_overrides": {"seq": "model"}},
    # no remat: recompute disappears (flops down), activation residency up
    "remat_none": {"cfg_update": {"remat": "none"}},
    # bf16 logits: halves unembed/logit traffic; xent still f32 internally
    "logits_bf16": {"cfg_update": {"logits_fp32": False}},
    # MoE dispatch buffer factor 2.0 -> 1.25 (drops absorbed by EF of the
    # router's aux loss pressure; report the drop counter!)
    "moecap125": {"cfg_update": {"moe_capacity_factor": 1.25}},
    # attention query chunk sweep (score-staging working set)
    "qchunk512": {"cfg_update": {"attn_q_chunk": 512}},
    "qchunk2048": {"cfg_update": {"attn_q_chunk": 2048}},
    # MoE EP dispatch off (dense ref; expect compute blow-up — negative ctl)
    "ep_off": {"moe_dispatch": "dense"},
    # no FSDP: params replicated over data (kills param all-gathers, HBM up)
    "no_fsdp": {"fsdp": False},
    # int8 a2a dispatch payloads (DeepSeek-V3-style): ~2x less MoE traffic
    "dispatch_int8": {"cfg_update": {"moe_dispatch_int8": True}},
    # pure data parallelism: batch over BOTH mesh axes, no tensor parallel
    # (small models: per-layer TP collectives vanish; params replicated over
    # the model axis, still FSDP over data)
    "dp_pure": {"rule_overrides": {"batch": ("data", "model"), "heads": None,
                                   "ff": None, "vocab": None,
                                   "kv_heads": None, "kv_seq": None}},
    # bf16 Adam moments: optimizer state 12 -> 8 bytes/param (HBM fit lever)
    "opt_bf16": {"ocfg_update": {"moments_dtype": "bfloat16"}},
    # larger SSD chunk: fewer chunk-state materializations per scan
    "ssdchunk512": {"cfg_update": {"ssm_chunk": 512}},
    "ssdchunk1024": {"cfg_update": {"ssm_chunk": 1024}},
}


def run_variant(arch: str, shape_name: str, names: str, *,
                multi_pod: bool = False, attribution: bool = False):
    from ..train import OptimConfig
    import dataclasses as _dc

    shape = SHAPES[shape_name]
    kwargs: Dict = {}
    cfg = get_config(arch)
    ocfg = OptimConfig()
    for name in names.split("+"):
        v = dict(VARIANTS[name])
        cfg = cfg.with_(**v.pop("cfg_update", {}))
        ocfg = _dc.replace(ocfg, **v.pop("ocfg_update", {}))
        overrides = dict(kwargs.get("rule_overrides") or {})
        overrides.update(v.pop("rule_overrides", {}) or {})
        kwargs.update(v)
        if overrides:
            kwargs["rule_overrides"] = overrides
    kwargs["ocfg"] = ocfg
    mesh = production_mesh(multi_pod)
    chips = 512 if multi_pod else 256
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, cfg=cfg, **kwargs)
    lowered = cell.lower()
    compiled = lowered.compile()
    hlo = compiled.as_text()
    roof = rl.from_compiled(compiled, chips,
                            rl.model_flops_for_cell(cfg, shape), hlo_text=hlo)
    rec = {
        "arch": arch, "shape": shape_name, "variant": names,
        "mesh": "multi" if multi_pod else "single",
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": _memory_analysis_dict(compiled),
        "roofline": roof.as_dict(),
    }
    if attribution:
        rec["top_bytes"] = [
            {"bytes": b, "instr": n[:120], "type": t[:60]}
            for b, n, t in top_bytes(hlo, 10)]
        rec["bytes_by_op"] = [[k, v] for k, v in by_op(hlo)[:12]]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attribution", action="store_true")
    ap.add_argument("--out", default="experiments/perf.jsonl")
    args = ap.parse_args()

    for names in args.variants.split(","):
        try:
            rec = run_variant(args.arch, args.shape, names,
                              multi_pod=args.multi_pod,
                              attribution=args.attribution)
        except Exception as e:
            rec = {"arch": args.arch, "shape": args.shape, "variant": names,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-1500:]}
            print(f"[{names}] FAILED {e!r}")
        else:
            ro = rec["roofline"]
            print(f"[{names}] tC={ro['t_compute_s']:.3e} "
                  f"tM={ro['t_memory_s']:.3e} tX={ro['t_collective_s']:.3e} "
                  f"bound={ro['bottleneck']} mfu_bound={ro['mfu_bound']:.4f} "
                  f"step_bound={ro['step_time_bound_s']:.3e}")
        if args.out:
            os.makedirs(os.path.dirname(args.out), exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
