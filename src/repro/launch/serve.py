"""Serving driver: continuous-batching engine over a trained/initialized LM.

Loads params (fresh or from a train checkpoint), starts the Engine, and
feeds it a stream of randomized requests — the example end-to-end path for
the inference side (examples/serve_lm.py drives this at laptop scale).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_smoke_config
from ..models.registry import init_all
from ..serve import Engine, Request, SamplingParams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params, _ = init_all(cfg, seed=args.seed)
    engine = Engine(cfg, params, max_batch=args.max_batch,
                    max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(1, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=args.max_new,
            sampling=SamplingParams(temperature=args.temperature, seed=i)))

    t0 = time.time()
    out = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {total_new} tokens, "
          f"{engine.steps} engine steps, {dt:.1f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s)")
    print(f"prefill tokens {engine.prefill_tokens}, "
          f"decode tokens {engine.decode_tokens}, "
          f"slot utilization {engine.decode_tokens / max(1, engine.steps * args.max_batch):.2f}")
    for uid in sorted(out)[:4]:
        print(f"  req {uid}: {out[uid][:12]}")
    return out


if __name__ == "__main__":
    main()
