"""Relabel edges through the permutation vector (paper Alg. 6-7).

This is the step that captures the paper's central idea: a *hash-style*
relabel touches pv at random positions (random I/O); the paper instead
chunk-sorts the edges by endpoint and streams the permutation ranges past
them one at a time, doing a sort-merge-join — every access sequential.

TPU adaptation (ring variant, paper-faithful):
  * edges sorted locally by the field being relabeled (the chunk sort);
  * the pv ranges do not sit on disk on a remote node — they sit in the HBM
    of remote shards.  The paper's `permute_server` pull becomes a static
    ring schedule: in round r, shard `bid` holds the pv chunk of shard
    `(bid + r) mod nb` (one `ppermute` per round).  nb rounds stream the
    whole vector past every shard with O(B) resident memory — the exact
    analogue of the paper's bounded-buffer streaming;
  * the merge-join inside a round is a masked monotone gather: edges are
    sorted, the pv chunk is contiguous, so `pv_chunk[field - base]` is a
    sequential-access gather (kernels/relabel.py tiles it through VMEM).

Communication-free variant (`relabel_recompute`, Funke et al.): when the
permutation is the keyed Feistel family (cfg.perm_family="feistel"), pv[u]
is a pure hash of u — so the relabel is an ELEMENTWISE map u -> perm(u)
with no pv operand, no sorting, and no collectives at all.  The exchange
bytes of both ring and all_to_all variants become per-element hash
evaluations; this is the device twin of the disk tier's
shuffle_variant="recompute" fast path.

Disk-tier twin's I/O overlap (cfg.io_overlap): the external relabel kernels
(phases.relabel_*_bucket, external.StreamingGenerator.relabel) prefetch
their merge-cursor refills and complete their emitted runs write-behind
(blockstore.PrefetchReader / WriteBehindWriter), hiding the sort-merge-join
pass's disk time behind the lookup compute — this module is pure device
compute with no disk I/O, so the flag has nothing to overlap here.

Optimized variant (`relabel_alltoall`): ship each endpoint to its owner
(capacity_all_to_all), gather, ship back.  One round trip instead of nb
rounds — but the destinations are *raw R-MAT ids*, whose ownership is
heavily skewed toward shard 0 (P(top bits all zero) ~ (a+b)^log2(nb)), so the
fixed capacity must be ~nb^0.4x uniform.  DESIGN.md quantifies why the
paper's ring is the robust choice under skew; the all_to_all variant is the
fast path at small nb / high capacity.  (Post-relabel, ids are uniform and
the same primitive is cheap — that's redistribute.py.)
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.collectives import capacity_all_to_all, return_all_to_all, ring_shift, shard_map
from .types import GraphConfig


def _relabel_field_ring(field: jnp.ndarray, pv_local: jnp.ndarray, *, bid, nb: int, B: int, axis: str):
    """Relabel one endpoint field via the ring-streamed merge-join.

    field: [N] local endpoint values (any order; sorting is an optimization
           handled by the caller/kernel, correctness does not require it).
    pv_local: [B] this shard's pv chunk.
    """
    sort_idx = jnp.argsort(field)            # paper: chunk-sort by endpoint
    sorted_field = field[sort_idx]
    out_sorted = jnp.zeros_like(sorted_field)

    def round_body(r, carry):
        pv_chunk, out = carry
        chunk_owner = (bid + r) % nb
        base = chunk_owner * B
        local = sorted_field - base
        in_range = (local >= 0) & (local < B)
        idx = jnp.clip(local, 0, B - 1)
        gathered = pv_chunk[idx]              # monotone gather (edges sorted)
        out = jnp.where(in_range, gathered, out)
        pv_chunk = ring_shift(pv_chunk, axis) if nb > 1 else pv_chunk
        return pv_chunk, out

    _, out_sorted = lax.fori_loop(0, nb, round_body, (pv_local, out_sorted))
    # scatter back to generation order
    return jnp.zeros_like(field).at[sort_idx].set(out_sorted)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def relabel_ring(
    cfg: GraphConfig,
    mesh: Mesh,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    pv: jnp.ndarray,
    axis: str = "shards",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper-faithful relabel: dst pass then src pass (paper relabels the
    destination field first, then the source field — Alg. 7 runs twice)."""
    nb = mesh.shape[axis]
    B = cfg.bucket_size

    def per_shard(src_l, dst_l, pv_l):
        bid = lax.axis_index(axis)
        new_dst = _relabel_field_ring(dst_l, pv_l, bid=bid, nb=nb, B=B, axis=axis)
        new_src = _relabel_field_ring(src_l, pv_l, bid=bid, nb=nb, B=B, axis=axis)
        return new_src, new_dst

    fn = shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    return fn(src, dst, pv)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def relabel_recompute(
    cfg: GraphConfig,
    mesh: Mesh,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    axis: str = "shards",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Communication-free relabel: (perm(src), perm(dst)) elementwise.

    Takes NO pv operand — the permutation is recomputed from cfg.seed via
    the keyed Feistel family (shuffle.graph_perm), so there is nothing to
    stream, ring-shift, or exchange.  `mesh`/`axis` are accepted for
    signature symmetry with the other variants and unused: the map is
    embarrassingly shard-local.  Bit-identical to relabel_ring against
    pv = shuffle_recompute(cfg, ...) (tested)."""
    from .shuffle import graph_perm

    del mesh, axis  # no collectives: the whole point
    return (graph_perm(cfg.seed, src, cfg.n, rounds=cfg.feistel_rounds),
            graph_perm(cfg.seed, dst, cfg.n, rounds=cfg.feistel_rounds))


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis", "capacity"))
def relabel_alltoall(
    cfg: GraphConfig,
    mesh: Mesh,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    pv: jnp.ndarray,
    axis: str = "shards",
    capacity: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Optimized relabel: one bucketed round trip per *both* fields at once.

    Returns (new_src, new_dst, dropped).  dropped > 0 means the capacity
    factor was too small for the R-MAT ownership skew — callers must treat
    that as a hard error (a mislabeled edge is corruption, not load shedding).
    """
    nb = mesh.shape[axis]
    B = cfg.bucket_size
    if capacity == 0:
        per_shard_q = 2 * (cfg.edges_per_shard)
        capacity = int(cfg.capacity_factor * per_shard_q / max(nb, 1)) + 8

    def per_shard(src_l, dst_l, pv_l):
        q = jnp.concatenate([src_l, dst_l])            # both fields, one trip
        ex = capacity_all_to_all(q, q // B, axis=axis, capacity=capacity)
        base = lax.axis_index(axis) * B
        local = jnp.clip(ex.data - base, 0, B - 1)
        answered = jnp.where(ex.valid, pv_l[local], 0)
        back = return_all_to_all(answered, ex.position, axis=axis)
        new_src, new_dst = jnp.split(back, 2)
        return new_src, new_dst, ex.dropped

    fn = shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P()),
    )
    return fn(src, dst, pv)
