"""Pure-numpy mirrors of the counter-based generation math (no jax import).

The disk tier and the multi-process partitioned mode (core/phases.py) run on
the host, often inside worker processes where pulling in a jit stack per
phase call is pure overhead.  These mirrors perform the *identical* uint32
arithmetic as core/rmat.py's jnp reference — tests assert bit-exact equality
— so every consumer (device pipeline, streaming generator, partitioned
workers) observes the same edge stream and the same shuffle schedule.

All arithmetic is wrapping uint32, matching XLA's integer semantics.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

# Same avalanche constants as core/rmat.py.
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN = 0x9E3779B9


def mix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of rmat.mix32 (murmur3-finalizer variant, bijective)."""
    x = np.asarray(x, np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(15))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def counter_uniform_u32_np(seed: int, index: np.ndarray, stream: int) -> np.ndarray:
    s = np.uint32((seed ^ (stream * _GOLDEN)) & 0xFFFFFFFF)
    return mix32_np(mix32_np(np.asarray(index, np.uint32) + s) ^ s)


def round_salt(seed: int, r: int) -> np.uint32:
    """Per-round shuffle salt — twin of shuffle._shuffle_rounds_body's
    mix32(seed + r * GOLDEN)."""
    s = (seed + r * _GOLDEN) & 0xFFFFFFFF
    return mix32_np(np.asarray([s], np.uint32))[0]


def shuffle_keys(values: np.ndarray, salt: np.uint32) -> np.ndarray:
    """Twin of shuffle._local_shuffle's sort keys: mix32(value ^ salt).

    Bijective in `value`, so keys are unique within any set of distinct
    vertex ids — external sort by these keys reproduces the device local
    shuffle exactly."""
    return mix32_np(np.asarray(values).astype(np.uint32) ^ salt)


def rmat_thresholds(a: float, b: float, c: float, d: float) -> Tuple[int, int, int]:
    """Integer cut points on the uint32 lattice (twin of types.quadrant_thresholds,
    duplicated here so worker processes need no jax-importing module)."""
    two32 = float(1 << 32)
    t_src = int((c + d) * two32)
    t_dst0 = int((b / (a + b)) * two32)
    t_dst1 = int((d / (c + d)) * two32)
    return t_src, t_dst0, t_dst1


def rmat_edges_np(
    scale: int,
    seed: int,
    start: int,
    count: int,
    a: float,
    b: float,
    c: float,
    d: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of rmat.rmat_edge_block: `count` edges with global ids
    [start, start+count), bit-identical to the jnp reference."""
    t_src, t_dst0, t_dst1 = rmat_thresholds(a, b, c, d)
    idx = np.uint32(start) + np.arange(count, dtype=np.uint32)
    src = np.zeros(count, np.uint32)
    dst = np.zeros(count, np.uint32)
    for level in range(scale):
        r1 = counter_uniform_u32_np(seed, idx, 2 * level)
        r2 = counter_uniform_u32_np(seed, idx, 2 * level + 1)
        src_bit = r1 < np.uint32(t_src)
        t_d = np.where(src_bit, np.uint32(t_dst1), np.uint32(t_dst0))
        dst_bit = r2 < t_d
        src = (src << np.uint32(1)) | src_bit.astype(np.uint32)
        dst = (dst << np.uint32(1)) | dst_bit.astype(np.uint32)
    return src.astype(np.int64), dst.astype(np.int64)


def rmat_edges_np_cfg(cfg, start: int, count: int) -> Tuple[np.ndarray, np.ndarray]:
    """Config-object convenience (any object with scale/seed/a/b/c/d)."""
    return rmat_edges_np(cfg.scale, cfg.seed, start, count, cfg.a, cfg.b, cfg.c, cfg.d)


def walk_rand_np(seed: int, walker: np.ndarray, step: int) -> np.ndarray:
    """Counter RNG of the random-walk samplers (data/walks.py), keyed by
    (seed, walker_id, step).  Lives here, jax-free, because the external walk
    kernels (phases.py) run inside worker processes; data/walks.py aliases
    this same function so all three samplers share one bit-exact stream."""
    s = np.uint32(seed & 0xFFFFFFFF)
    return mix32_np(mix32_np(np.asarray(walker, np.uint32) ^ s)
                    + np.uint32((step * _GOLDEN) & 0xFFFFFFFF))


def walk_start_np(seed: int, walker: np.ndarray, n: int, base: int = 0) -> np.ndarray:
    """Deterministic start vertex of a walker (numpy half of
    walks.start_vertex; int64 per the walk dtype contract)."""
    return base + (walk_rand_np(seed ^ 0xA5A5, walker, 0)
                   % np.uint32(n)).astype(np.int64)
