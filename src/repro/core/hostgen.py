"""Pure-numpy mirrors of the counter-based generation math (no jax import).

The disk tier and the multi-process partitioned mode (core/phases.py) run on
the host, often inside worker processes where pulling in a jit stack per
phase call is pure overhead.  These mirrors perform the *identical* uint32
arithmetic as core/rmat.py's jnp reference — tests assert bit-exact equality
— so every consumer (device pipeline, streaming generator, partitioned
workers) observes the same edge stream and the same shuffle schedule.

All arithmetic is wrapping uint32, matching XLA's integer semantics.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

# Same avalanche constants as core/rmat.py.
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN = 0x9E3779B9


def mix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of rmat.mix32 (murmur3-finalizer variant, bijective)."""
    x = np.asarray(x, np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _M1
    x = x ^ (x >> np.uint32(15))
    x = x * _M2
    x = x ^ (x >> np.uint32(16))
    return x


def counter_uniform_u32_np(seed: int, index: np.ndarray, stream: int) -> np.ndarray:
    s = np.uint32((seed ^ (stream * _GOLDEN)) & 0xFFFFFFFF)
    return mix32_np(mix32_np(np.asarray(index, np.uint32) + s) ^ s)


def round_salt(seed: int, r: int) -> np.uint32:
    """Per-round shuffle salt — twin of shuffle._shuffle_rounds_body's
    mix32(seed + r * GOLDEN)."""
    s = (seed + r * _GOLDEN) & 0xFFFFFFFF
    return mix32_np(np.asarray([s], np.uint32))[0]


def shuffle_keys(values: np.ndarray, salt: np.uint32) -> np.ndarray:
    """Twin of shuffle._local_shuffle's sort keys: mix32(value ^ salt).

    Bijective in `value`, so keys are unique within any set of distinct
    vertex ids — external sort by these keys reproduces the device local
    shuffle exactly."""
    return mix32_np(np.asarray(values).astype(np.uint32) ^ salt)


def rmat_thresholds(a: float, b: float, c: float, d: float) -> Tuple[int, int, int]:
    """Integer cut points on the uint32 lattice (twin of types.quadrant_thresholds,
    duplicated here so worker processes need no jax-importing module)."""
    two32 = float(1 << 32)
    t_src = int((c + d) * two32)
    t_dst0 = int((b / (a + b)) * two32)
    t_dst1 = int((d / (c + d)) * two32)
    return t_src, t_dst0, t_dst1


def rmat_edges_np(
    scale: int,
    seed: int,
    start: int,
    count: int,
    a: float,
    b: float,
    c: float,
    d: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of rmat.rmat_edge_block: `count` edges with global ids
    [start, start+count), bit-identical to the jnp reference."""
    t_src, t_dst0, t_dst1 = rmat_thresholds(a, b, c, d)
    idx = np.uint32(start) + np.arange(count, dtype=np.uint32)
    src = np.zeros(count, np.uint32)
    dst = np.zeros(count, np.uint32)
    for level in range(scale):
        r1 = counter_uniform_u32_np(seed, idx, 2 * level)
        r2 = counter_uniform_u32_np(seed, idx, 2 * level + 1)
        src_bit = r1 < np.uint32(t_src)
        t_d = np.where(src_bit, np.uint32(t_dst1), np.uint32(t_dst0))
        dst_bit = r2 < t_d
        src = (src << np.uint32(1)) | src_bit.astype(np.uint32)
        dst = (dst << np.uint32(1)) | dst_bit.astype(np.uint32)
    return src.astype(np.int64), dst.astype(np.int64)


def rmat_edges_np_cfg(cfg, start: int, count: int) -> Tuple[np.ndarray, np.ndarray]:
    """Config-object convenience (any object with scale/seed/a/b/c/d)."""
    return rmat_edges_np(cfg.scale, cfg.seed, start, count, cfg.a, cfg.b, cfg.c, cfg.d)


# ---------------------------------------------------------------------------
# Keyed invertible permutation family (Funke et al.'s communication-free
# relabel): a Feistel network over mix32.  Because every round function is a
# pure counter hash, ANY host can recompute perm(v) — and therefore the new
# label and the owner of any edge endpoint — locally, with zero exchange.
# The forward/inverse pair below is the numpy source of truth; core/shuffle.py
# holds the jnp twin and kernels/rmat.py the Pallas kernel, all bit-exact.
# ---------------------------------------------------------------------------

# Default Feistel depth.  4 alternating rounds of a bijective-avalanche round
# function already decorrelate adjacent inputs far beyond what the R-MAT
# pipeline observes; must be EVEN so the half widths return to (hi, lo) and
# the output packs back into nbits.
FEISTEL_ROUNDS = 4

# Domain-separation constant: the pipeline's permutation key is
# seed ^ _FEISTEL_STREAM, so the Feistel round keys can never collide with
# the R-MAT streams (seed ^ stream*GOLDEN) or the shuffle salts
# (mix32(seed + r*GOLDEN)) derived from the same seed.
_FEISTEL_STREAM = 0xFE15_7E11


def perm_domain_bits(n: int) -> int:
    """ceil(log2(n)) clamped to >= 1: the Feistel domain [0, 2**nbits) is the
    smallest power of two covering [0, n); cycle-walking closes the gap."""
    return max(1, int(n - 1).bit_length())


def feistel_round_key_np(key: int, i: int) -> np.ndarray:
    """Round key rk_i = mix32(key + (i+1)*GOLDEN) — scalar uint32 (0-d).

    The sum is folded in PYTHON integers then reduced mod 2**32, so the jnp
    and Pallas twins can reproduce it exactly with one mix32 call."""
    s = (int(key) + (i + 1) * _GOLDEN) & 0xFFFFFFFF
    return mix32_np(np.asarray([s], np.uint32))[0]


def feistel_perm_np(x: np.ndarray, key: int, nbits: int,
                    rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """Keyed bijection on [0, 2**nbits) (unbalanced Feistel over mix32).

    The input splits into L (hi_bits = nbits - nbits//2) and R (lo_bits =
    nbits//2); each round computes F = mix32(R ^ rk_i), swaps halves, and
    masks the new R to the width the OLD L had — after an even number of
    rounds the widths are back to (hi, lo) and (L << lo_bits) | R is again an
    nbits value.  Bijective because every round is invertible (XOR with a
    function of the untouched half) — see feistel_perm_inv_np.

    Container is uint64 with uint32 halves: nbits <= 62 (each half <= 31
    bits, so the masks fit uint32).  Returns uint64.
    """
    if rounds < 2 or rounds % 2:
        raise ValueError(f"feistel rounds must be even and >= 2, got {rounds}")
    if not 1 <= nbits <= 62:
        raise ValueError(f"feistel domain needs 1 <= nbits <= 62, got {nbits}")
    lo_bits = nbits // 2
    x = np.asarray(x, np.uint64)
    L = (x >> np.uint64(lo_bits)).astype(np.uint32)
    R = (x & np.uint64((1 << lo_bits) - 1)).astype(np.uint32)
    wL, wR = nbits - lo_bits, lo_bits
    for i in range(rounds):
        F = mix32_np(R ^ feistel_round_key_np(key, i))
        L, R, wL, wR = R, (L ^ F) & np.uint32((1 << wL) - 1), wR, wL
    return (L.astype(np.uint64) << np.uint64(lo_bits)) | R.astype(np.uint64)


def feistel_perm_inv_np(y: np.ndarray, key: int, nbits: int,
                        rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """Inverse of feistel_perm_np: same round keys, walked in reverse."""
    if rounds < 2 or rounds % 2:
        raise ValueError(f"feistel rounds must be even and >= 2, got {rounds}")
    if not 1 <= nbits <= 62:
        raise ValueError(f"feistel domain needs 1 <= nbits <= 62, got {nbits}")
    lo_bits = nbits // 2
    y = np.asarray(y, np.uint64)
    L = (y >> np.uint64(lo_bits)).astype(np.uint32)
    R = (y & np.uint64((1 << lo_bits) - 1)).astype(np.uint32)
    wL, wR = nbits - lo_bits, lo_bits
    for i in reversed(range(rounds)):
        F = mix32_np(L ^ feistel_round_key_np(key, i))
        L, R, wL, wR = (R ^ F) & np.uint32((1 << wR) - 1), L, wR, wL
    return (L.astype(np.uint64) << np.uint64(lo_bits)) | R.astype(np.uint64)


def keyed_perm_np(x: np.ndarray, key: int, n: int,
                  rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """Keyed bijection on [0, n) for ARBITRARY n, by cycle-walking the
    power-of-two Feistel: out-of-range outputs are re-permuted until they
    land inside [0, n).  Terminates because the Feistel orbit of any x < n
    returns to x, so walking forward from x must hit an in-range element
    within one cycle (< 2**nbits steps; in expectation < 2 steps since the
    domain is at most 2n).  For power-of-two n — the pipeline's case, n =
    2**scale — the walk never triggers and the cost is exactly one Feistel
    evaluation per element.  Returns int64."""
    nbits = perm_domain_bits(n)
    x = np.asarray(x)
    flat = np.atleast_1d(x).astype(np.int64)
    if flat.size and (flat.min() < 0 or flat.max() >= n):
        raise ValueError(f"keyed_perm_np: inputs must lie in [0, {n})")
    out = np.atleast_1d(feistel_perm_np(flat, key, nbits, rounds))
    bad = out >= np.uint64(n)
    while bad.any():
        out[bad] = feistel_perm_np(out[bad], key, nbits, rounds)
        bad = out >= np.uint64(n)
    return out.astype(np.int64).reshape(np.shape(x))


def keyed_perm_inv_np(y: np.ndarray, key: int, n: int,
                      rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """Inverse of keyed_perm_np: the inverse walk retraces the forward
    cycle-walk backwards (all intermediates of the forward walk were >= n,
    so the first in-range preimage IS the original input)."""
    nbits = perm_domain_bits(n)
    y = np.asarray(y)
    flat = np.atleast_1d(y).astype(np.int64)
    if flat.size and (flat.min() < 0 or flat.max() >= n):
        raise ValueError(f"keyed_perm_inv_np: inputs must lie in [0, {n})")
    out = np.atleast_1d(feistel_perm_inv_np(flat, key, nbits, rounds))
    bad = out >= np.uint64(n)
    while bad.any():
        out[bad] = feistel_perm_inv_np(out[bad], key, nbits, rounds)
        bad = out >= np.uint64(n)
    return out.astype(np.int64).reshape(np.shape(y))


def graph_perm_key(seed: int) -> int:
    """The pipeline's permutation key for graph seed `seed`."""
    return (int(seed) ^ _FEISTEL_STREAM) & 0xFFFFFFFF


def graph_perm_np(seed: int, x: np.ndarray, n: int,
                  rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """pv[x] of the recomputable permutation family: what the external
    shuffle would have materialized, evaluated on demand (shuffle_variant=
    "recompute" / perm_family="feistel")."""
    return keyed_perm_np(x, graph_perm_key(seed), n, rounds)


def graph_perm_inv_np(seed: int, y: np.ndarray, n: int,
                      rounds: int = FEISTEL_ROUNDS) -> np.ndarray:
    """Original vertex id of new label y (pv^{-1}[y])."""
    return keyed_perm_inv_np(y, graph_perm_key(seed), n, rounds)


def walk_rand_np(seed: int, walker: np.ndarray, step: int) -> np.ndarray:
    """Counter RNG of the random-walk samplers (data/walks.py), keyed by
    (seed, walker_id, step).  Lives here, jax-free, because the external walk
    kernels (phases.py) run inside worker processes; data/walks.py aliases
    this same function so all three samplers share one bit-exact stream."""
    s = np.uint32(seed & 0xFFFFFFFF)
    return mix32_np(mix32_np(np.asarray(walker, np.uint32) ^ s)
                    + np.uint32((step * _GOLDEN) & 0xFFFFFFFF))


def walk_start_np(seed: int, walker: np.ndarray, n: int, base: int = 0) -> np.ndarray:
    """Deterministic start vertex of a walker (numpy half of
    walks.start_vertex; int64 per the walk dtype contract)."""
    return base + (walk_rand_np(seed ^ 0xA5A5, walker, 0)
                   % np.uint32(n)).astype(np.int64)
