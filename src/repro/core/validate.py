"""Graph500-style validation of a generated graph (deliverable of kernel 1).

Checks (host-side, exact):
  * pv is a bijection on [0:n)
  * edge count conservation through every phase (generation -> relabel ->
    redistribute -> CSR), including accounting for reported drops
  * relabel correctness: multiset of edges after relabel equals the multiset
    of (pv[u], pv[v]) of the generated edges
  * ownership: every edge landed on owner(src) (RP(n, nb))
  * CSR invariants: offv monotone, offv[-1] == edges owned, adjacency
    multiset matches owned edge multiset
  * de-biasing (the *reason* the paper shuffles): raw R-MAT endpoints are
    concentrated on small ids; relabeled endpoints are near-uniform
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from .types import GraphConfig


def check_permutation(pv) -> bool:
    pv = np.asarray(pv)
    n = pv.shape[0]
    seen = np.zeros(n, dtype=bool)
    seen[pv] = True
    return bool(seen.all())


def edge_multiset(src, dst) -> np.ndarray:
    """Canonical sorted array of packed (src,dst) pairs for multiset compare."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    packed = (src << 32) | (dst & 0xFFFFFFFF)
    return np.sort(packed)


def check_relabel(src, dst, new_src, new_dst, pv) -> bool:
    pv = np.asarray(pv)
    want = edge_multiset(pv[np.asarray(src)], pv[np.asarray(dst)])
    got = edge_multiset(new_src, new_dst)
    return bool(np.array_equal(want, got))


def check_ownership(owned_src, owned_valid, cfg: GraphConfig) -> bool:
    """Every valid edge on shard i must have src in [i*B, (i+1)*B)."""
    B = cfg.bucket_size
    src = np.asarray(owned_src).reshape(cfg.nb, -1)
    valid = np.asarray(owned_valid).reshape(cfg.nb, -1)
    for i in range(cfg.nb):
        s = src[i][valid[i]]
        if s.size and not ((s >= i * B) & (s < (i + 1) * B)).all():
            return False
    return True


def check_csr(csr, owned, cfg: GraphConfig) -> Dict[str, bool]:
    """CSR invariants + adjacency multiset vs the owned edges."""
    B = cfg.bucket_size
    offv = np.asarray(csr.offv).reshape(cfg.nb, B + 1)
    adjv = np.asarray(csr.adjv).reshape(cfg.nb, -1)
    src = np.asarray(owned.src).reshape(cfg.nb, -1)
    dst = np.asarray(owned.dst).reshape(cfg.nb, -1)
    valid = np.asarray(owned.valid).reshape(cfg.nb, -1)
    ok_monotone, ok_counts, ok_multiset = True, True, True
    for i in range(cfg.nb):
        o = offv[i]
        cnt = int(valid[i].sum())
        ok_monotone &= bool((np.diff(o) >= 0).all())
        ok_counts &= int(o[-1]) == cnt
        # multiset of (row, dst) reconstructed from CSR == owned edges
        rows = np.repeat(np.arange(B), np.diff(o))
        got = edge_multiset(rows + i * B, adjv[i][: cnt])
        want = edge_multiset(src[i][valid[i]], dst[i][valid[i]])
        ok_multiset &= bool(np.array_equal(got, want))
    return {"monotone": ok_monotone, "counts": ok_counts, "multiset": ok_multiset}


def endpoint_skew(src, dst, n: int, frac: int = 16) -> float:
    """Fraction of endpoints in the lowest n/frac ids (1/frac == unbiased)."""
    lo = n // frac
    src = np.asarray(src)
    dst = np.asarray(dst)
    cnt = int((src < lo).sum() + (dst < lo).sum())
    return cnt / float(src.size + dst.size)


def degree_stats(csr, cfg: GraphConfig) -> Dict[str, float]:
    B = cfg.bucket_size
    offv = np.asarray(csr.offv).reshape(cfg.nb, B + 1)
    deg = np.diff(offv, axis=1).reshape(-1)
    return {
        "max_degree": float(deg.max()),
        "mean_degree": float(deg.mean()),
        "gini_proxy": float((deg > 4 * deg.mean()).mean()),  # heavy-tail marker
    }
