"""Out-of-core (external memory) generation path — the paper's SSD tier.

The device pipeline (pipeline.py) is the TPU adaptation; this module is the
*literal* external-memory system: edge blocks live on disk (numpy memmap
files), main-memory usage is bounded by `chunk_edges` + one pv chunk, and
every phase is implemented as sequential scans over sorted runs — the
paper's Alg. 5-11 on a single host, with an I/O ledger that counts
sequential vs random block transfers so benchmarks can *measure* the claims
the paper makes about I/O complexity:

  generate      O(b*f / C_e) sequential writes          (Alg. 5)
  relabel       O(2*b*f*S(int) / C_e) sequential        (Alg. 6-7, sort-merge-join)
  redistribute  O(B*f / C_e) sequential                 (Alg. 8-9)
  csr_scatter   O(b) RANDOM                             (Alg. 10-11 — the Fig. 2 blowup)
  csr_sorted    O(B / C_e) sequential                   (§III-B7 — the predicted fix)

The ledger is the host-side "profile" for the §Perf iteration on the
generation workload.
"""

from __future__ import annotations

import dataclasses
import heapq
import os
import shutil
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .types import GraphConfig


@dataclasses.dataclass
class IOLedger:
    """Counts block-granular I/O, the paper's unit of cost (C_e edges/block)."""

    seq_reads: int = 0
    seq_writes: int = 0
    rand_reads: int = 0
    rand_writes: int = 0
    bytes_read: int = 0
    bytes_written: int = 0

    def read(self, nbytes: int, sequential: bool = True):
        self.bytes_read += nbytes
        if sequential:
            self.seq_reads += 1
        else:
            self.rand_reads += 1

    def write(self, nbytes: int, sequential: bool = True):
        self.bytes_written += nbytes
        if sequential:
            self.seq_writes += 1
        else:
            self.rand_writes += 1

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class RunStore:
    """A directory of fixed-capacity sorted/unsorted runs of (src, dst) pairs.

    The paper's external edgelist ADT: append, iterate blocks, never delete
    individual records (§III-A).  Each run is one .npy file of shape [k, 2].
    """

    def __init__(self, workdir: str, name: str, ledger: IOLedger):
        self.dir = os.path.join(workdir, name)
        os.makedirs(self.dir, exist_ok=True)
        self.ledger = ledger
        self._runs: List[str] = []

    def append_run(self, src: np.ndarray, dst: np.ndarray):
        arr = np.stack([src, dst], axis=1)
        path = os.path.join(self.dir, f"run_{len(self._runs):06d}.npy")
        np.save(path, arr)
        self.ledger.write(arr.nbytes)
        self._runs.append(path)

    @property
    def num_runs(self) -> int:
        return len(self._runs)

    def read_run(self, i: int, sequential: bool = True) -> Tuple[np.ndarray, np.ndarray]:
        arr = np.load(self._runs[i], mmap_mode=None)
        self.ledger.read(arr.nbytes, sequential)
        return arr[:, 0], arr[:, 1]

    def iter_runs(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for i in range(self.num_runs):
            yield self.read_run(i)

    def total_edges(self) -> int:
        return sum(np.load(p, mmap_mode="r").shape[0] for p in self._runs)

    def destroy(self):
        shutil.rmtree(self.dir, ignore_errors=True)


def external_sort_runs(store: RunStore, out: RunStore, key_col: int = 0, chunk: Optional[int] = None):
    """Phase 1 of external merge sort: sort each run in memory, rewrite.

    (The paper's Alg. 7 lines 1-5: read chunk, sort, write back.)
    """
    for i in range(store.num_runs):
        s, d = store.read_run(i)
        key = s if key_col == 0 else d
        order = np.argsort(key, kind="stable")
        out.append_run(s[order], d[order])


def external_merge(store: RunStore, key_col: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Phase 2: streaming k-way merge of sorted runs via a heap of cursors.

    Yields merged blocks of ~one run's size.  Memory: one block per run head
    — the paper's bounded-buffer merge (fig. 1).
    """
    heads = []
    runs = []
    for i in range(store.num_runs):
        s, d = store.read_run(i)
        runs.append((s, d))
        if s.size:
            key = s if key_col == 0 else d
            heapq.heappush(heads, (int(key[0]), i, 0))
    out_s, out_d = [], []
    block = max(1, runs[0][0].size if runs else 1)
    while heads:
        _, ri, pos = heapq.heappop(heads)
        s, d = runs[ri]
        # emit the maximal prefix of run ri that stays below the next head
        nxt = heads[0][0] if heads else np.iinfo(np.int64).max
        key = s if key_col == 0 else d
        end = int(np.searchsorted(key[pos:], nxt, side="right")) + pos
        out_s.append(s[pos:end])
        out_d.append(d[pos:end])
        if end < s.size:
            heapq.heappush(heads, (int(key[end]), ri, end))
        emitted = sum(x.size for x in out_s)
        if emitted >= block:
            yield np.concatenate(out_s), np.concatenate(out_d)
            out_s, out_d = [], []
    if out_s:
        yield np.concatenate(out_s), np.concatenate(out_d)


class StreamingGenerator:
    """Single-host out-of-core generator: bounded RAM, disk-resident edges.

    Mirrors the distributed pipeline phase by phase;  `nb` here plays the
    role of the paper's compute nodes — per-owner partition files stand in
    for the MPI packets, so the same code measures the I/O cost of the
    redistribute pattern without a network.
    """

    def __init__(self, cfg: GraphConfig, workdir: str):
        self.cfg = cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.ledger = IOLedger()

    # -- phase 1: permutation ------------------------------------------------
    def permutation(self) -> np.ndarray:
        """pv via the device shuffle (scale permitting) written to a memmap,
        read back chunk-at-a-time by relabel.  (The paper also keeps shuffle
        main-memory-resident and flags the external shuffle as future work —
        §IV-A 'the limitation on the shuffle is artificial'.)"""
        from ..distributed.collectives import flat_mesh
        from .shuffle import distributed_shuffle

        cfg1 = self.cfg.with_(nb=1)
        pv = np.asarray(distributed_shuffle(cfg1, flat_mesh(1)))
        path = os.path.join(self.workdir, "pv.npy")
        np.save(path, pv)
        self.ledger.write(pv.nbytes)
        return np.load(path, mmap_mode="r")

    # -- phase 2: edge generation ---------------------------------------------
    def generate_edges(self) -> RunStore:
        from .rmat import rmat_edges_host

        store = RunStore(self.workdir, "edges", self.ledger)
        m = self.cfg.m
        blk = self.cfg.chunk_edges
        for start in range(0, m, blk):
            cnt = min(blk, m - start)
            s, d = rmat_edges_host(self.cfg, start, cnt)
            store.append_run(s, d)
        return store

    # -- phase 3: relabel (sort-merge-join, Alg. 6-7) --------------------------
    def relabel(self, edges: RunStore, pv: np.ndarray) -> RunStore:
        """Two passes, each keyed on column 1 and emitting (pv[col1], col0):

            pass 1: (src, dst)      -> (pv[dst], src)
            pass 2: (pv[dst], src)  -> (pv[src], pv[dst])

        i.e. the paper's order — destination field first, then source — with
        a column swap instead of two different sort keys.
        """
        cur = edges
        for pass_ix in range(2):
            sorted_store = RunStore(self.workdir, f"sorted_p{pass_ix}", self.ledger)
            external_sort_runs(cur, sorted_store, key_col=1)
            out = RunStore(self.workdir, f"relabeled_p{pass_ix}", self.ledger)
            chunk_v = max(1, self.cfg.chunk_edges)
            for s, d in external_merge(sorted_store, key_col=1):
                key = d
                new_key = np.empty_like(key)
                # stream pv chunks that overlap this merged block only:
                # both sides advance monotonically = sort-merge-join.
                lo = 0
                while lo < key.size:
                    base = (int(key[lo]) // chunk_v) * chunk_v
                    hi = int(np.searchsorted(key, base + chunk_v, side="left"))
                    pv_chunk = np.asarray(pv[base : base + chunk_v])
                    self.ledger.read(pv_chunk.nbytes)
                    new_key[lo:hi] = pv_chunk[key[lo:hi] - base]
                    lo = hi
                out.append_run(new_key, s)
            sorted_store.destroy()
            if cur is not edges:
                cur.destroy()
            cur = out
        # after the second pass columns are (new_src, new_dst)
        return cur

    # -- phase 4: redistribute (Alg. 8-9) --------------------------------------
    def redistribute(self, edges: RunStore) -> List[RunStore]:
        nb, B = self.cfg.nb, self.cfg.bucket_size
        owners = [RunStore(self.workdir, f"owned_{i:03d}", self.ledger) for i in range(nb)]
        for s, d in edges.iter_runs():
            dest = s // B
            order = np.argsort(dest, kind="stable")
            s, d, dest = s[order], d[order], dest[order]
            starts = np.searchsorted(dest, np.arange(nb))
            ends = np.searchsorted(dest, np.arange(nb), side="right")
            for i in range(nb):
                if ends[i] > starts[i]:
                    owners[i].append_run(s[starts[i]:ends[i]], d[starts[i]:ends[i]])
        return owners

    # -- phase 5: CSR ----------------------------------------------------------
    def build_csr_sorted(self, owners: List[RunStore]) -> List[Tuple[np.ndarray, np.ndarray]]:
        """§III-B7: external sort by src + streaming Alg. 1.  Sequential."""
        nb, B = self.cfg.nb, self.cfg.bucket_size
        results = []
        for i, store in enumerate(owners):
            sorted_store = RunStore(self.workdir, f"owned_sorted_{i:03d}", self.ledger)
            external_sort_runs(store, sorted_store, key_col=0)
            base = i * B
            degv = np.zeros(B, np.int64)
            adj_parts = []
            for s, d in external_merge(sorted_store, key_col=0):
                np.add.at(degv, s - base, 1)  # sorted -> this is a segment count
                adj_parts.append(d)
            offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
            adjv = np.concatenate(adj_parts) if adj_parts else np.zeros(0, np.int64)
            self.ledger.write(adjv.nbytes)
            results.append((offv, adjv))
            sorted_store.destroy()
        return results

    def build_csr_scatter(self, owners: List[RunStore]) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Alg. 10-11: unordered scan with a bounded associative map flushed
        into a memmap'd adjv — every flush is a RANDOM write burst.  This is
        the variant whose I/O the paper measured blowing up (Fig. 2)."""
        nb, B = self.cfg.nb, self.cfg.bucket_size
        flush_at = max(16, self.cfg.chunk_edges // 256)  # mmc analogue
        results = []
        for i, store in enumerate(owners):
            base = i * B
            degv = np.zeros(B, np.int64)
            for s, _ in store.iter_runs():
                np.add.at(degv, s - base, 1)
            offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
            path = os.path.join(self.workdir, f"adjv_{i:03d}.npy")
            adjv = np.lib.format.open_memmap(path, mode="w+", dtype=np.int64, shape=(int(offv[-1]),))
            cursor = np.zeros(B, np.int64)
            adjvh: Dict[int, List[int]] = {}
            held = 0
            for s, d in store.iter_runs():
                for sv, dv in zip((s - base).tolist(), d.tolist()):
                    adjvh.setdefault(sv, []).append(dv)
                    held += 1
                    if held >= flush_at:
                        for v, lst in adjvh.items():  # random write per vertex
                            o = offv[v] + cursor[v]
                            adjv[o : o + len(lst)] = lst
                            cursor[v] += len(lst)
                            self.ledger.write(8 * len(lst), sequential=False)
                        adjvh, held = {}, 0
            for v, lst in adjvh.items():
                o = offv[v] + cursor[v]
                adjv[o : o + len(lst)] = lst
                cursor[v] += len(lst)
                self.ledger.write(8 * len(lst), sequential=False)
            adjv.flush()
            results.append((offv, np.asarray(adjv)))
        return results

    # -- driver ----------------------------------------------------------------
    def run(self, csr_variant: Optional[str] = None):
        csr_variant = csr_variant or self.cfg.csr_variant
        pv = self.permutation()
        edges = self.generate_edges()
        relabeled = self.relabel(edges, pv)
        owners = self.redistribute(relabeled)
        if csr_variant == "sorted":
            csr = self.build_csr_sorted(owners)
        else:
            csr = self.build_csr_scatter(owners)
        return pv, csr, self.ledger
