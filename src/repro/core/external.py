"""Out-of-core (external memory) generation path — the paper's SSD tier.

The device pipeline (pipeline.py) is the TPU adaptation; this module is the
*literal* external-memory system, rebuilt as three layers:

  storage        core/blockstore.py — BlockStore (typed multi-column runs),
                 external sort (sort_runs + merge_runs over block-buffered
                 cursors), bounded bucket partitioning (partition_runs), and
                 the MonotoneLookup sort-merge-join cursor.  Every byte moved
                 is charged to an IOLedger (sequential vs random, the
                 paper's cost unit); every buffer materialized is reported
                 to a MemoryGauge so tests can *assert* bounded memory.
  phases         core/phases.py — bucket-level phase kernels addressed by
                 store naming convention, the PhaseOrchestrator (named,
                 resumable phases with per-phase ledger deltas), and the
                 multi-process PartitionedGenerator.
  driver         StreamingGenerator (this file) — runs the five phases in
                 one process through the orchestrator.

Phase algebra and I/O complexity (paper Alg. 2-11, §III-B):

  shuffle       "device": pv via the on-device shuffle, spilled to bucket
                files — fast, but holds pv in RAM: the §IV-A "artificial
                limitation on the shuffle" the paper calls out.
                "external": paper Alg. 2-4 ON DISK — pv is built as nb
                bucket files via log_nb(n) rounds of {external sort by
                counter-hash key, positional slice exchange}.  Peak RSS is
                O(chunk_edges) at ANY scale, all I/O sequential.
                "recompute": communication-free (Funke et al.'s hash-derived
                permutation) — pv is the keyed invertible Feistel family
                (hostgen.graph_perm_np), evaluated wherever a label is
                needed.  ZERO shuffle phases: no pv store, no shuffle-round
                I/O, no exchange bytes.  The permutation's cost moves from
                the I/O column to the compute column: O(1) mix32 rounds per
                evaluation, charged to ledger.hash_evals.
  generate      O(b*f / C_e) sequential writes          (Alg. 5)
  relabel       O(2*b*f*S(int) / C_e) sequential        (Alg. 6-7): edges
                external-sorted by the key field, pv *runs* streamed past
                them (MonotoneLookup) — a sort-merge-join against bucket
                files, never a memmapped monolith.
                "recompute": the two relabel passes AND redistribute fuse
                into ONE O(b*f / C_e) sequential scan that maps
                u -> perm(u) in-stream (2 hash evals per edge, 0 exchange
                bytes beyond the owner exchange below): both external sorts,
                both scatter exchanges and the pv join vanish.
  redistribute  O(B*f / C_e) sequential                 (Alg. 8-9)
  csr_scatter   O(b) RANDOM                             (Alg. 10-11 — the Fig. 2 blowup)
  csr_sorted    O(B / C_e) sequential                   (§III-B7 — the predicted fix)

Measured via (core/trace.py — every cost term above is attributable on a
real timeline, not only predicted; run with cfg.trace=True, merge with
`python -m repro.launch.cluster trace`, load in Perfetto):

  term          measured via
  ------------  ---------------------------------------------------------
  shuffle       "phase"-cat spans "shuffle" / per-round shuffle phases;
                ledger seq_read/seq_write bytes in the span args.
                "recompute": no spans at all — its cost is ledger.hash_evals.
  generate      "kernel"-cat span "generate" (or the fused
                "gen_relabel_recompute"); ledger rows/bytes written.
  relabel       "kernel" spans "relabel_sort"/"relabel_join"; "io"-cat spans
                "sort:<store>" / "merge:<store>" for each external sort pass.
  redistribute  "kernel" span "redistribute"; "io" span "partition:<store>".
  csr_scatter   "kernel" span "csr_scatter"; ledger rand_write counter —
                the Fig. 2 blowup shows up as dur with few bytes/sec.
  csr_sorted    "kernel" spans "csr_sort"/"csr_emit"; "io" spans
                "sort:csr*" + "merge:csr*".
  exchange E_x  "wire"-cat spans "send:<store>" / instants "recv:<store>";
                TransportStats bytes_sent/bytes_recv in unified_snapshot.
  migration     "wire" span "migrate:<relpath>"; TransportStats migrate_bytes.
  overlap       "stall"-cat spans "read_stall"/"write_stall" (>= 1 ms only);
                full totals in ledger read_wait_s/write_wait_s/overlap_s.
  barriers      "ctrl"-cat spans "barrier:<kernel>" on the controller lane;
                per-task "task_report" instants carry host + seconds.

Phase wall times are the "phase"-cat spans — one per completed
orchestrator phase, args = the nonzero ledger delta for that phase (the
same rows orchestrator.report() prints).

Network-exchange term (core/transport.py): every bucket exchange above
(shuffle slice exchange, relabel scatter, redistribute, per-hop walk-frontier
exchange) moves E_x exchanged bytes through the configured Transport:

  transport="fs"      O(2 * E_x / C_e) interconnect transfers — on a shared
                      (network) filesystem every exchanged byte crosses the
                      wire twice: sender -> shared store, then shared store
                      -> receiver at drain time.  The reference backend, and
                      exact on one host where "interconnect" is the disk.
  transport="socket"  O(E_x / C_e) framed-TCP transfers + one O(E_x / C_e)
                      sequential local write at the receiver — bytes cross
                      the wire once (the paper's MPI shape: exchange overlaps
                      the receiver's sequential disk I/O), acked per frame so
                      the in-flight window is one writer-bounded run and the
                      O(chunk_edges) memory bound holds end to end.  Output
                      bytes are identical either way; only the motion differs.

Multi-host sharded-collect term (core/cluster.py + core/corpus.py): on an
H-host cluster the walk-history collect writes each bucket's corpus shard on
its OWNER host — O(W*(L+1)*S(int) / C_e) sequential writes in total, but at
most a 1/H bucket-balanced share of them on any single host's disk, and ZERO
corpus bytes on the controller (it writes only the O(nb)-entry manifest).
The single-workdir alternative would add one full O(corpus / C_e) network
copy to gather the shards onto one host; the sharded collect deletes that
term — training streams per-batch rows from the shard files where they lie
(data/loader.ExternalWalkLoader over the manifest).  The same placement
holds for the graph itself: bucket CSR files live only on their owner host.

Multi-job scheduling term (core/jobqueue.py over core/cluster.py): a drain
of J concurrent jobs adds ONLY control-plane bytes — per host-poll one
lease of up to lease_size tasks and one report per task, each a
header-only control frame of O(100) bytes; steals ride the same poll
frames, so lease/steal control traffic is O((T/lease_size + T) * 100 B)
for T total tasks across all jobs, a vanishing term next to any E_x.
Data-bearing tasks never migrate (placement stays with the bucket owner's
disk); only communication-free recompute tasks are stealable, and a steal
moves ZERO input bytes — the thief regenerates from (cfg, bucket) alone.
What the queue buys is the OVERLAP FACTOR, serial_makespan /
queued_makespan >= 1: while one job's barrier waits on a straggler the
fleet runs other jobs' sequential I/O and exchanges, so fleet utilization
(busy-seconds / H * makespan) rises toward 1 without changing any job's
per-phase I/O terms above — and k same-length corpora submitted as one
fused walk job (walk_hop_fused) share each hop's O(B / C_e) CSR scan,
dividing that read term by k.

Shard-migration term (core/shardmap.py + core/cluster.py): a skew
rebalance at a phase barrier moves the migrated buckets' shard files —
stores, CSR arrays, corpus shards — from straggler to cold host over the
exchange transport, one O(bytes(b) / C_e) sequential read + framed send +
sequential durable write per migrated bucket b.  The planner is fed by the
IOLedger's per-bucket byte counters (`bucket_bytes[b]`, surfaced in every
BENCH_*.json), moves each bucket at most once per barrier, and only when
the move strictly shrinks the host-load spread, so migration bytes are
bounded by the skew actually observed — a uniform graph pays ZERO.  Every
later phase term above is unchanged in total but re-balanced per host:
the 1/H shares stop being nominal and track bytes, which is the whole
point.  Migration is resumable per file (ack-after-durable + per-file
micro-phases), so a crash never re-pays completed shard transfers.

I/O-overlap term (cfg.io_overlap, default on — blockstore.PrefetchReader /
WriteBehindWriter): every pass above is a read stream R, a compute term C,
and a write stream W that the serial path pays as R + C + W.  With overlap
on, merge-cursor refills prefetch on a background I/O thread (depth 2,
double-buffered) and run/partition/exchange emission completes write-behind
with one chunk in flight, so the effective per-pass cost drops toward
max(R, C, W) — the paper's dedicated-I/O-thread model.  The byte counts in
every term above are UNCHANGED (the flag is timing-only and bit-identical;
result_config_key normalizes it out), resident memory at most doubles (one
in-flight buffer per direction, MemoryGauge-tracked), and the time NOT
hidden is measured: ledger.read_wait_s (consumer stalled on prefetch),
ledger.write_wait_s (producer stalled on the in-flight chunk), and
ledger.overlap_s (I/O seconds actually hidden behind compute) appear in the
per-phase orchestrator deltas and BENCH json.  Buffers below the async
byte floor (blockstore._ASYNC_IO_MIN_BYTES) move synchronously even with
the flag on — for tiny blocks the thread handoff costs more than the
transfer it would hide, so overlap engages only where R or W is real.

Every external merge above pays an extra O(log_merge_fanin(nruns))-deep
cascade of sequential read+write passes whenever a store's run count exceeds
cfg.merge_fanin (blockstore.merge_runs): the bounded-fan-in multiway merge
trades those log-depth passes for an open-file count and merge heap bounded
by merge_fanin at ANY store size — with nruns <= merge_fanin (the common
case at paper scales) the term is zero and the costs are exactly the flat
merge's.  With cfg.pooled_cascade the partitioned/cluster CSR sort runs the
SAME cascade as phase-level (bucket, group) tasks through the worker pool —
identical pass count and bytes, wall time divided by the pool width at every
intermediate level (one extra final pass when 1 < nruns <= fanin, the price
of pool-dispatching the last merge).

`StreamingGenerator(cfg, dir).run()` returns (pv memmap, per-bucket CSR,
ledger); `gen.orchestrator.report()` gives the per-phase ledger deltas that
benchmarks/bench_csr_variants.py and bench_external_shuffle.py print.
"""

from __future__ import annotations

import os
from typing import Iterator, List, Optional, Tuple

import numpy as np

from .blockstore import (  # noqa: F401  (IOLedger re-exported for compat)
    BlockStore,
    IOLedger,
    MemoryGauge,
    MonotoneLookup,
    merge_runs,
    partition_runs,
    sort_runs,
    write_behind,
)
from .hostgen import rmat_edges_np_cfg
from .phases import (
    PhaseOrchestrator,
    attach_pv_buckets,
    csr_bucket_sorted,
    csr_adjv_path,
    csr_offv_path,
    drive_shuffle,
    load_bucket_csr,
    plain_config,
    pv_store_name,
    result_config_key,
    validate_external_shape,
)
from .trace import maybe_install_tracer
from .transport import FilesystemTransport
from .types import GraphConfig


# Store names of the sequential driver, shared by the producer sites AND the
# checkpoint-GC frees declarations in run() — clean_store() ignores missing
# dirs, so a name drifting between the two would silently disable GC.
EDGES_STORE = "edges"


def relabeled_store_name(pass_ix: int) -> str:
    return f"relabeled_p{pass_ix}"


def seq_owned_store_name(i: int) -> str:
    return f"owned_{i:03d}"


class RunStore(BlockStore):
    """(src, dst) pair store — the original external edgelist ADT, now a
    two-column BlockStore (kept as a named type for call-site readability)."""

    def __init__(self, workdir: str, name: str, ledger: IOLedger,
                 gauge: Optional[MemoryGauge] = None, fresh: bool = False):
        super().__init__(workdir, name, ledger, columns=("src", "dst"), gauge=gauge,
                         fresh=fresh)

    def total_edges(self) -> int:
        return self.total_rows()


def external_sort_runs(store: BlockStore, out: BlockStore, key_col: int = 0,
                       chunk: Optional[int] = None) -> BlockStore:
    """Phase 1 of external merge sort (paper Alg. 7 lines 1-5): sort each
    writer-bounded run in memory, rewrite.  Thin wrapper over
    blockstore.sort_runs, kept under its historical name."""
    return sort_runs(store, out, key=key_col)


def external_merge(store: BlockStore, key_col: int = 0, block_rows: int = 0,
                   max_fanin: int = 0) -> Iterator[Tuple[np.ndarray, ...]]:
    """Phase 2: streaming k-way merge of sorted runs (paper's bounded-buffer
    merge, fig. 1).  Resident memory is one chunk split across the run
    cursors — never the whole store.  `max_fanin` >= 2 bounds the cursor
    count via the log-depth cascade (see blockstore.merge_runs)."""
    return merge_runs(store, key=key_col, block_rows=block_rows,
                      max_fanin=max_fanin)


class StreamingGenerator:
    """Single-host out-of-core generator: bounded RAM, disk-resident edges
    AND (with shuffle_variant="external") a disk-resident permutation.

    `nb` plays the role of the paper's compute nodes — per-owner partition
    files stand in for the MPI packets, so the same code measures the I/O
    cost of every phase without a network.  The multi-process twin
    (phases.PartitionedGenerator) runs the same bucket kernels with real
    process parallelism.
    """

    def __init__(self, cfg: GraphConfig, workdir: str,
                 checkpoint: Optional[bool] = None):
        self.cfg = cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.ledger = IOLedger()
        self.gauge = MemoryGauge(budget_rows=int(cfg.chunk_edges))
        ck = cfg.checkpoint_phases if checkpoint is None else checkpoint
        self._pcfg = plain_config(cfg)
        maybe_install_tracer(workdir, enabled=self._pcfg.trace)
        if self._pcfg.transport != "fs":
            raise ValueError(
                "StreamingGenerator is the single-process reference driver "
                "and exchanges through the filesystem only; use "
                "PartitionedGenerator for transport='socket'")
        self._transport = FilesystemTransport(workdir, self.ledger, self.gauge)
        if cfg.shuffle_variant == "external":
            validate_external_shape(self._pcfg)
        # shuffle_variant (and the rest of the variant knobs) live inside
        # result_config_key now — no need to append them separately.
        self.orchestrator = PhaseOrchestrator(
            workdir, self.ledger, checkpoint=ck,
            config_key=repr(result_config_key(self._pcfg)),
            keep_all=bool(getattr(cfg, "keep_phase_stores", False)))

    # -- phase 1: permutation ------------------------------------------------
    def permutation(self) -> List[BlockStore]:
        """Build pv as nb disk-resident bucket stores; bucket i holds
        pv[i*B : (i+1)*B] in run order."""
        if self.cfg.shuffle_variant == "device":
            return self._permutation_device()
        if self.cfg.shuffle_variant == "external":
            return self._permutation_external()
        if self.cfg.shuffle_variant == "recompute":
            raise ValueError(
                "shuffle_variant='recompute' materializes no pv stores — "
                "evaluate hostgen.graph_perm_np(seed, ids, n) (or its "
                "inverse) instead")
        raise ValueError(self.cfg.shuffle_variant)

    def _permutation_device(self) -> List[BlockStore]:
        """pv via the device shuffle (scale permitting), spilled to bucket
        files.  Holds the whole vector in RAM once — the §IV-A limitation —
        which the gauge records honestly."""
        from ..distributed.collectives import flat_mesh
        from .shuffle import distributed_shuffle

        cfg1 = self.cfg.with_(nb=1)
        pv = np.asarray(distributed_shuffle(cfg1, flat_mesh(1)))
        self.gauge.track(pv.size)
        B, chunk = self.cfg.bucket_size, self.cfg.chunk_edges
        buckets = []
        for i in range(self.cfg.nb):
            store = BlockStore(self.workdir, pv_store_name(self._pcfg.rounds, i),
                               self.ledger, columns=("v",), gauge=self.gauge,
                               fresh=True)
            for lo in range(i * B, (i + 1) * B, chunk):
                store.append_run(pv[lo : min(lo + chunk, (i + 1) * B)].astype(np.int64))
            buckets.append(store)
        return buckets

    def _run_kernels_inline(self, kernel: str, argss) -> List:
        """In-process map strategy for the shared phase drivers: same bucket
        kernels the partitioned workers run, against this driver's ledger
        and (filesystem) transport.  Returns the kernel outputs (the pooled
        drivers plan cascades from counts-returning sort kernels)."""
        from .phases import _KERNELS

        return [_KERNELS[kernel](self._pcfg, self.workdir, *args,
                                 ledger=self.ledger, gauge=self.gauge,
                                 transport=self._transport)
                for args in argss]

    def _permutation_external(self) -> List[BlockStore]:
        """Paper Alg. 2-4 on disk: rounds of {chunked local shuffle via
        external sort by counter-hash key, positional bucket exchange}.
        Peak RSS O(chunk_edges); every transfer sequential.  Bit-identical
        to distributed_shuffle on an nb-shard mesh (tested)."""
        p = self._pcfg
        drive_shuffle(p, self.workdir, self._run_kernels_inline,
                      transport=self._transport)
        return attach_pv_buckets(p, self.workdir, self.ledger, self.gauge)

    def export_pv(self, buckets: List[BlockStore]) -> np.ndarray:
        """Assemble pv into one memmap for callers/validation — streamed in
        chunk-sized blocks (the array returned is disk-backed, not resident)."""
        path = os.path.join(self.workdir, "pv.npy")
        out = np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                        shape=(self.cfg.n,))
        pos = 0
        for store in buckets:
            for (v,) in store.iter_blocks(self.cfg.chunk_edges):
                out[pos : pos + v.size] = v
                self.ledger.write(v.nbytes)
                pos += v.size
        out.flush()
        del out
        return np.load(path, mmap_mode="r")

    def export_pv_recompute(self) -> np.ndarray:
        """Assemble pv for callers/validation under shuffle_variant=
        'recompute': there are no bucket stores to stream, so each chunk is
        pure hash evaluation — pv[lo:hi] = perm([lo, hi)) — written straight
        to the memmap.  Bit-identical to export_pv over an
        external+feistel run of the same config (tested)."""
        from .hostgen import graph_perm_np

        p = self._pcfg
        path = os.path.join(self.workdir, "pv.npy")
        out = np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                        shape=(self.cfg.n,))
        chunk = self.cfg.chunk_edges
        for lo in range(0, self.cfg.n, chunk):
            ids = np.arange(lo, min(lo + chunk, self.cfg.n), dtype=np.int64)
            self.ledger.hashes(ids.size)
            v = graph_perm_np(p.seed, ids, p.n, rounds=p.feistel_rounds)
            out[lo : lo + ids.size] = v
            self.ledger.write(v.nbytes)
        out.flush()
        del out
        return np.load(path, mmap_mode="r")

    # -- phase 2: edge generation ---------------------------------------------
    def generate_edges(self) -> RunStore:
        """Alg. 5 via the numpy counter-RNG mirror (bit-identical to the
        device stream — tested), chunk-bounded runs."""
        store = RunStore(self.workdir, EDGES_STORE, self.ledger, gauge=self.gauge, fresh=True)
        m, blk = self.cfg.m, self.cfg.chunk_edges
        for start in range(0, m, blk):
            cnt = min(blk, m - start)
            s, d = rmat_edges_np_cfg(self.cfg, start, cnt)
            store.append_run(s, d)
        return store

    # -- phase 3: relabel (sort-merge-join, Alg. 6-7) --------------------------
    def relabel(self, edges: BlockStore, pv_buckets: List[BlockStore]) -> BlockStore:
        """Two passes, each keyed on column 1 and emitting (pv[col1], col0):

            pass 1: (src, dst)      -> (pv[dst], src)
            pass 2: (pv[dst], src)  -> (pv[src], pv[dst])

        i.e. the paper's order — destination field first, then source — with
        a column swap instead of two different sort keys.  The probe side is
        the external-sorted edge stream; the build side is the pv *runs*
        streamed forward by MonotoneLookup.  Both sides advance monotonically
        => pure sequential I/O.
        """
        cur = edges
        ov = self._pcfg.io_overlap
        for pass_ix in range(2):
            sorted_store = RunStore(self.workdir, f"sorted_p{pass_ix}",
                                    self.ledger, gauge=self.gauge, fresh=True)
            sort_runs(cur, sorted_store, key=1, overlap=ov)
            out = RunStore(self.workdir, relabeled_store_name(pass_ix),
                           self.ledger, gauge=self.gauge, fresh=True)
            lookup = MonotoneLookup(pv_buckets, block_rows=self.cfg.chunk_edges,
                                    gauge=self.gauge)
            with write_behind([out], self.ledger, self.gauge,
                              enabled=ov) as sinks:
                for s, d in merge_runs(sorted_store, key=1,
                                       block_rows=self.cfg.merge_block_rows,
                                       max_fanin=self.cfg.merge_fanin,
                                       overlap=ov):
                    sinks[0].append_run(lookup.lookup(d), s)
            sorted_store.destroy()
            if cur is not edges:
                cur.destroy()
            cur = out
        # after the second pass columns are (new_src, new_dst)
        return cur

    # -- phase 3': communication-free relabel (recompute) ----------------------
    def relabel_recompute(self, edges: BlockStore) -> List[RunStore]:
        """shuffle_variant='recompute': ONE streaming scan applies
        u -> perm(u) to both endpoints by hash evaluation (no pv store, no
        external sorts, no join) and partitions straight to the owner
        stores — relabel (both passes) and redistribute fused.  Twin of
        phases.relabel_recompute_bucket."""
        from .hostgen import graph_perm_np

        p = self._pcfg
        nb, B = self.cfg.nb, self.cfg.bucket_size

        def relabel(s, d):
            self.ledger.hashes(s.size + d.size)
            return (graph_perm_np(p.seed, s, p.n, rounds=p.feistel_rounds),
                    graph_perm_np(p.seed, d, p.n, rounds=p.feistel_rounds))

        owners = [RunStore(self.workdir, seq_owned_store_name(i), self.ledger,
                           gauge=self.gauge, fresh=True) for i in range(nb)]
        partition_runs(edges, owners, lambda s, d: s // B, transform=relabel,
                       overlap=p.io_overlap)
        return owners

    # -- phase 4: redistribute (Alg. 8-9) --------------------------------------
    def redistribute(self, edges: BlockStore) -> List[RunStore]:
        nb, B = self.cfg.nb, self.cfg.bucket_size
        owners = [RunStore(self.workdir, seq_owned_store_name(i), self.ledger,
                           gauge=self.gauge, fresh=True) for i in range(nb)]
        partition_runs(edges, owners, lambda s, d: s // B,
                       overlap=self._pcfg.io_overlap)
        return owners

    # -- phase 5: CSR ----------------------------------------------------------
    def build_csr_sorted(self, owners: List[BlockStore]) -> List[Tuple[np.ndarray, np.ndarray]]:
        """§III-B7: external sort by src + streaming Alg. 1.  Sequential;
        adjv streams into a per-bucket memmap, never resident.  Delegates to
        the shared bucket kernel (phases.csr_bucket_sorted) so both drivers
        build CSR with literally the same code."""
        results = []
        for i, store in enumerate(owners):
            offv_path, adjv_path = csr_bucket_sorted(
                self._pcfg, self.workdir, i, ledger=self.ledger,
                gauge=self.gauge, in_name=store.name)
            results.append(load_bucket_csr(offv_path, adjv_path,
                                           self.ledger, self.gauge))
        return results

    def build_csr_scatter(self, owners: List[BlockStore]) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Alg. 10-11: unordered scan with a bounded associative map flushed
        into a memmap'd adjv — every flush is a RANDOM write burst.  This is
        the variant whose I/O the paper measured blowing up (Fig. 2)."""
        if self._pcfg.perm_family == "feistel":
            # Scatter-CSR adjacency order is ENCOUNTER order; recompute and
            # external deliver the same owned-edge multiset in different
            # arrival orders, so the feistel family's bit-identity contract
            # requires the (src, dst)-sorted variant.
            raise ValueError(
                "csr_variant='scatter' is incompatible with "
                "perm_family='feistel': its adjacency lists are in arrival "
                "order, which the recomputable-permutation paths do not "
                "reproduce; use csr_variant='sorted'")
        B = self.cfg.bucket_size
        flush_at = max(16, self.cfg.chunk_edges // 256)  # mmc analogue
        results = []
        for i, store in enumerate(owners):
            base = i * B
            degv = np.zeros(B, np.int64)
            self.gauge.track(B)
            # Block-sized degree pass: iter_runs would load whole run files
            # (read_run's whole-run contract), spiking residency past chunk.
            for s, _ in store.iter_blocks(self.cfg.chunk_edges):
                np.add.at(degv, s - base, 1)
            offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
            path = os.path.join(self.workdir, f"adjv_{i:03d}.npy")
            adjv = np.lib.format.open_memmap(path, mode="w+", dtype=np.int64,
                                             shape=(int(offv[-1]),))
            cursor = np.zeros(B, np.int64)
            adjvh = {}
            held = 0
            for s, d in store.iter_blocks(self.cfg.chunk_edges):
                for sv, dv in zip((s - base).tolist(), d.tolist()):
                    adjvh.setdefault(sv, []).append(dv)
                    held += 1
                    if held >= flush_at:
                        for v, lst in adjvh.items():  # random write per vertex
                            o = offv[v] + cursor[v]
                            adjv[o : o + len(lst)] = lst
                            cursor[v] += len(lst)
                            self.ledger.write(8 * len(lst), sequential=False)
                        adjvh, held = {}, 0
            for v, lst in adjvh.items():
                o = offv[v] + cursor[v]
                adjv[o : o + len(lst)] = lst
                cursor[v] += len(lst)
                self.ledger.write(8 * len(lst), sequential=False)
            adjv.flush()
            results.append((offv, np.asarray(adjv)))
        return results

    # -- driver ----------------------------------------------------------------
    def _save_stores(self, stores) -> dict:
        if isinstance(stores, BlockStore):
            return {"stores": [stores.manifest()], "single": True}
        return {"stores": [s.manifest() for s in stores], "single": False}

    def _load_stores(self, payload: dict):
        stores = [BlockStore.from_manifest(m, self.workdir, self.ledger, self.gauge)
                  for m in payload["stores"]]
        return stores[0] if payload["single"] else stores

    def run(self, csr_variant: Optional[str] = None):
        """Run all phases through the orchestrator.  Returns
        (pv memmap, [(offv, adjv)] per bucket, IOLedger); per-phase ledger
        deltas via `self.orchestrator.report()`.

        Checkpoint GC: every phase declares (via `frees`) the stores it is
        the last consumer of, so unless cfg.keep_phase_stores the workdir
        retains only the final artifacts (CSR bucket files + pv.npy) plus
        whatever the pipeline's current frontier still needs — the disk
        footprint is bounded instead of accumulating every intermediate.
        """
        csr_variant = csr_variant or self.cfg.csr_variant
        nb = self.cfg.nb
        orch = self.orchestrator
        sv, ld = self._save_stores, self._load_stores
        recompute = self.cfg.shuffle_variant == "recompute"
        if recompute:
            # Communication-free path: no shuffle phase at all (the
            # permutation is a hash family, not a store), and relabel +
            # redistribute fuse into one scan.
            edges = orch.run_phase("generate", self.generate_edges,
                                   save=sv, load=ld)
            owners = orch.run_phase(
                "relabel_recompute", lambda: self.relabel_recompute(edges),
                save=sv, load=ld, frees=[EDGES_STORE])
        else:
            pv_buckets = orch.run_phase("shuffle", self.permutation,
                                        save=sv, load=ld)
            edges = orch.run_phase("generate", self.generate_edges,
                                   save=sv, load=ld)
            relabeled = orch.run_phase(
                "relabel", lambda: self.relabel(edges, pv_buckets),
                save=sv, load=ld, frees=[EDGES_STORE])
            owners = orch.run_phase(
                "redistribute", lambda: self.redistribute(relabeled),
                save=sv, load=ld, frees=[relabeled_store_name(1)])

        def _load_csr(_m):
            return [load_bucket_csr(csr_offv_path(self.workdir, i),
                                    csr_adjv_path(self.workdir, i),
                                    self.ledger, self.gauge)
                    for i in range(nb)]

        if csr_variant == "sorted":
            # The CSR files are the durable output; the manifest only needs
            # to mark completion (paths are the naming convention's).
            csr = orch.run_phase(
                "csr_sorted", lambda: self.build_csr_sorted(owners),
                save=lambda _res: {"nb": nb}, load=_load_csr,
                frees=[seq_owned_store_name(i) for i in range(nb)])
        elif csr_variant == "scatter":
            # scatter keeps offv in RAM only — not checkpointable, so its
            # inputs are never freed by THIS run (a resume must be able to
            # rerun it).  A prior 'sorted' run over the same checkpoint may
            # have freed them already though — fail with guidance, not with
            # a FileNotFoundError deep inside np.load.
            gone = sum(len(s.missing_runs()) for s in owners)
            if gone:
                raise ValueError(
                    f"csr_variant='scatter' needs the redistribute output "
                    f"stores, but {gone} run file(s) were already "
                    "garbage-collected by a checkpointed csr_sorted run; "
                    "rerun with keep_phase_stores=True or a fresh workdir")
            csr = orch.run_phase("csr_scatter", lambda: self.build_csr_scatter(owners))
        else:
            raise ValueError(csr_variant)
        if recompute:
            pv = orch.run_phase(
                "export_pv", self.export_pv_recompute,
                save=lambda _res: {"path": "pv.npy"},
                load=lambda m: np.load(os.path.join(self.workdir, m["path"]),
                                       mmap_mode="r"))
        else:
            pv = orch.run_phase(
                "export_pv", lambda: self.export_pv(pv_buckets),
                save=lambda _res: {"path": "pv.npy"},
                load=lambda m: np.load(os.path.join(self.workdir, m["path"]),
                                       mmap_mode="r"),
                frees=[pv_store_name(self._pcfg.rounds, i) for i in range(nb)]
                      if csr_variant == "sorted" else [])
        return pv, csr, self.ledger
