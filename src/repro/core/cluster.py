"""Multi-host cluster runtime: the paper's actual deployment shape.

PR 4 made every bucket exchange ride a pluggable Transport whose socket
backend takes arbitrary `peer_addrs` — this module supplies the missing
piece: something that actually STARTS workers + exchange servers on N
machines, rendezvouses them, drives the bulk-synchronous phases across them,
and keeps going (or resumes) when a host dies.  Four layers:

  ClusterSpec        the host manifest: which hosts exist, where each one's
                     private workdir lives, and which contiguous bucket
                     range each owns (the paper's RP(n, nb) applied to
                     hosts).  JSON round-trippable; never contains ephemeral
                     ports — those are discovered at rendezvous.
  HostRunner         the worker-host daemon: sweeps its workdir, starts the
                     local ExchangeServer, registers with the controller,
                     then polls for kernel tasks and executes them (in
                     process, or through a local spawn pool) against its own
                     per-host checkpoint state — so a relaunched host skips
                     every task it already completed, recomputing nothing
                     of its peers' work.
  ClusterController  rendezvous + heartbeats + phase barriers over the same
                     length-prefixed framing the exchange transport uses
                     (a control RPC is a header-only frame; the reply rides
                     the ack).  Dispatches each bucket kernel to the host
                     owning args[0]'s bucket, detects dead hosts (exec
                     handle exit or heartbeat silence), relaunches them
                     through the exec backend, and retries transport-failed
                     tasks once the peer map heals — GraphD's explicit
                     failure handling for disk-resident small clusters.
  ClusterGenerator   PartitionedGenerator with the pool swapped for the
                     cluster: same phase drivers, fine-grained checkpointed
                     clean/barrier phases (see drive_shuffle), sharded
                     collect (per-host corpus shards + manifest — no single
                     workdir ever holds the full corpus), and a graph
                     manifest instead of a driver-side CSR load.

Exec backends: `LocalExecBackend` spawns `python -m repro.launch.cluster
host ...` subprocesses with per-host isolated workdirs (the reference
backend, and the loopback "two-host" CI shape); `CommandTemplateBackend`
formats an arbitrary command template (`ssh {host} ... --host-id {host_id}`)
so srun/ssh/k8s launches are a string, not a subclass.

Determinism is what makes the failure story simple: every run tag and every
run's bytes are a pure function of (config, bucket, phase), so re-executing
a half-finished task overwrites identical files — a resumed exchange never
needs distributed rollback, only the "clean exactly once per phase"
discipline the fine-grained checkpoint phases provide.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import re
import shlex
import socket
import subprocess
import sys
import threading
import time
from collections import deque
from concurrent.futures import ProcessPoolExecutor
from multiprocessing import get_context
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .blockstore import (
    IOLedger,
    MemoryGauge,
    clean_cascade_stores,
    split_counter_key,
)
from .shardmap import ShardMap, ShardMapError, plan_rebalance
from .trace import (
    TRACE_DIR,
    get_tracer,
    maybe_install_tracer,
    unified_snapshot,
)
from .phases import (
    PartitionedGenerator,
    PhaseOrchestrator,
    PlainCfg,
    WalkCfg,
    _MARK,
    _SKIP,
    _resolve_trace,
    _run_kernel,
    csr_adjv_path,
    csr_offv_path,
    plain_config,
    result_config_key,
    task_key,
    validate_external_shape,
)
from .transport import (
    ExchangeServer,
    SocketTransport,
    TransportError,
    TransportStats,
    PART_SUFFIX,
    _ACK,
    _HDR,
    _MAGIC,
    _MAX_HEADER_BYTES,
    _PLEN,
    _check_subdir,
    _recv_exact,
    _send_frame,
    store_bucket,
    sweep_partial_frames,
)

# Control-plane frame kind: rides the exchange transport's wire format
# (magic, kind, header JSON) but is served by the ControlServer, never by an
# ExchangeServer.  Requests are header-only; the JSON reply rides the ack
# message field.
_KIND_CTRL = 2


class ClusterError(RuntimeError):
    """A cluster-level failure: lost host past its restart budget, barrier
    timeout, or a non-retriable kernel error reported by a host.  When the
    failure is task-scoped, `task_key` and `attempts` name exactly which
    task died and how many dispatches it burned (`job` names the owning
    queue job, when any) — structured so schedulers can park the job
    instead of parsing the message."""

    def __init__(self, msg: str, *, task_key: Optional[str] = None,
                 attempts: Optional[int] = None, job: Optional[str] = None):
        super().__init__(msg)
        self.task_key = task_key
        self.attempts = attempts
        self.job = job


class TaskError(ClusterError):
    """One task exhausted its lease/retry budget.  JOB-scoped, not
    cluster-scoped: the hosts are healthy and other jobs keep draining —
    the job-queue scheduler catches this, dead-letters the owning job, and
    moves on, where a plain ClusterError aborts the whole cluster run."""


def heartbeat_period(timeout: float) -> float:
    """Heartbeat send period derived from the controller's advertised
    heartbeat_timeout: timeout/8 (several beats must fit in one timeout
    window so a single dropped RPC never flaps the host), clamped to
    [0.2s, 15s] so short-timeout tests don't spin and long-timeout
    deployments don't fall to one beat per epoch."""
    return min(max(float(timeout) / 8.0, 0.2), 15.0)


# ---------------------------------------------------------------------------
# ClusterSpec — the host manifest
# ---------------------------------------------------------------------------


def format_peer_addrs(addrs: Sequence[str]) -> str:
    """peer_addrs tuple -> the comma-joined CLI form."""
    return ",".join(str(a) for a in addrs)


def parse_peer_addrs(s: str) -> Tuple[str, ...]:
    """CLI "host:port,host:port" -> validated peer_addrs tuple.  Round-trips
    with format_peer_addrs (property-tested)."""
    out = []
    for part in s.split(","):
        part = part.strip()
        host, sep, port = part.rpartition(":")
        if not sep or not host:
            raise ValueError(f"peer address {part!r} is not host:port")
        int(port)  # raises ValueError on a non-numeric port
        out.append(part)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """One worker host: id, its PRIVATE workdir (never shared with peers),
    and the launch target a command template may address (ssh host name)."""

    host_id: int
    workdir: str
    host: str = "127.0.0.1"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Host manifest + bucket ownership.  Host h owns the contiguous bucket
    range [h*nb//H, (h+1)*nb//H) — the paper's range partition applied at
    host granularity, so a host's buckets (and their vertex ranges) are one
    contiguous span and per-host recomputation never touches a peer's data
    (Funke et al.'s recomputable-partition shape)."""

    nb: int
    hosts: Tuple[HostSpec, ...]
    controller_host: str = "127.0.0.1"
    controller_port: int = 0   # 0 = ephemeral, discovered at start

    def __post_init__(self):
        ids = sorted(h.host_id for h in self.hosts)
        if not self.hosts:
            raise ValueError("ClusterSpec needs at least one host")
        if ids != list(range(len(self.hosts))):
            raise ValueError(f"host_ids must be 0..H-1, got {ids}")
        if len({h.workdir for h in self.hosts}) != len(self.hosts):
            raise ValueError("host workdirs must be distinct (per-host "
                             "isolation is the whole point)")
        if self.nb < len(self.hosts):
            raise ValueError(
                f"nb={self.nb} buckets cannot cover {len(self.hosts)} hosts")

    @property
    def num_hosts(self) -> int:
        return len(self.hosts)

    def buckets_of(self, host_id: int) -> range:
        H = self.num_hosts
        return range(host_id * self.nb // H, (host_id + 1) * self.nb // H)

    def owner_of(self, bucket: int) -> int:
        if not 0 <= bucket < self.nb:
            raise ValueError(f"bucket {bucket} outside [0, {self.nb})")
        # Inverse of buckets_of's balanced contiguous split: host h owns
        # [h*nb//H, (h+1)*nb//H), so owner(b) = floor((b*H + H - 1) / nb)
        # ... which is fiddly with uneven splits; a direct scan over H hosts
        # is exact and H is tiny.
        return next(h for h in range(self.num_hosts)
                    if bucket in self.buckets_of(h))

    def workdir_of(self, bucket: int) -> str:
        return self.hosts[self.owner_of(bucket)].workdir

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> Dict:
        return {"nb": self.nb,
                "controller": f"{self.controller_host}:{self.controller_port}",
                "hosts": [dataclasses.asdict(h) for h in self.hosts]}

    @classmethod
    def from_json(cls, d: Dict) -> "ClusterSpec":
        chost, _, cport = str(d.get("controller", "127.0.0.1:0")).rpartition(":")
        return cls(nb=int(d["nb"]),
                   hosts=tuple(HostSpec(int(h["host_id"]), str(h["workdir"]),
                                        str(h.get("host", "127.0.0.1")))
                               for h in d["hosts"]),
                   controller_host=chost or "127.0.0.1",
                   controller_port=int(cport or 0))

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "ClusterSpec":
        with open(path) as f:
            return cls.from_json(json.load(f))

    @classmethod
    def local(cls, num_hosts: int, root: str, nb: int,
              controller_host: str = "127.0.0.1") -> "ClusterSpec":
        """The single-box N-host layout: per-host workdirs under `root`."""
        return cls(nb=nb, controller_host=controller_host,
                   hosts=tuple(HostSpec(h, os.path.join(root, f"host{h}"))
                               for h in range(num_hosts)))


# ---------------------------------------------------------------------------
# Control-plane wire (the exchange framing, reused)
# ---------------------------------------------------------------------------


def _ctrl_request(sock: socket.socket, obj: Dict) -> Dict:
    """One control RPC: header-only frame out, JSON reply in the ack."""
    _send_frame(sock, _KIND_CTRL, obj)
    status, mlen = _ACK.unpack(_recv_exact(sock, _ACK.size))
    if mlen > _MAX_HEADER_BYTES:
        raise ClusterError(f"oversized control reply ({mlen} bytes)")
    body = _recv_exact(sock, mlen).decode() if mlen else "{}"
    if status != 0:
        raise ClusterError(f"controller refused request: {body}")
    return json.loads(body)


class ControlServer:
    """Threaded request/reply server over the exchange frame format.  Every
    accepted connection loops {frame in -> handler(meta) -> JSON ack out};
    `handler` runs on the connection thread and must be thread-safe (the
    controller guards its state with one lock)."""

    def __init__(self, handler: Callable[[Dict], Dict],
                 host: str = "127.0.0.1", port: int = 0):
        self._handler = handler
        self._sock = socket.create_server((host, port))
        bound = self._sock.getsockname()
        self.addr = f"{bound[0]}:{bound[1]}"
        self._lock = threading.Lock()
        self._live: set = set()
        self._stopping = False
        self._thread = threading.Thread(target=self._accept_loop,
                                        name=f"control-server-{bound[1]}",
                                        daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            with self._lock:
                self._live.add(conn)
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn: socket.socket) -> None:
        try:
            with conn:
                while True:
                    conn.settimeout(None)        # idle between RPCs is fine
                    try:
                        first = conn.recv(1)
                    except OSError:
                        return
                    if not first:
                        return
                    conn.settimeout(30.0)        # mid-frame stall is not
                    try:
                        head = first + _recv_exact(conn, _HDR.size - 1)
                        magic, kind, hlen = _HDR.unpack(head)
                        if magic != _MAGIC or kind != _KIND_CTRL:
                            raise ClusterError("bad control frame")
                        if hlen > _MAX_HEADER_BYTES:
                            raise ClusterError("oversized control header")
                        meta = json.loads(_recv_exact(conn, hlen).decode())
                        (plen,) = _PLEN.unpack(_recv_exact(conn, _PLEN.size))
                        if plen:
                            raise ClusterError("control frames carry no payload")
                        body = json.dumps(self._handler(meta)).encode()
                        conn.sendall(_ACK.pack(0, len(body)) + body)
                    except (ClusterError, ValueError, KeyError, TypeError,
                            json.JSONDecodeError, OSError) as e:
                        msg = str(e).encode()[:4096]
                        try:
                            conn.sendall(_ACK.pack(1, len(msg)) + msg)
                        except OSError:
                            pass
                        return
        finally:
            with self._lock:
                self._live.discard(conn)

    def stop(self) -> None:
        if self._stopping:
            return
        self._stopping = True
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=5.0)
        with self._lock:
            live = list(self._live)
        for c in live:
            try:
                c.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Exec backends
# ---------------------------------------------------------------------------


class ExecBackend:
    """How worker-host processes come into existence.  `launch` returns a
    handle; `alive(handle)` is the liveness probe the controller pairs with
    heartbeats; `stop(handle)` is best-effort teardown."""

    def launch(self, spec: ClusterSpec, host: HostSpec, controller_addr: str,
               attempt: int = 0):
        raise NotImplementedError

    def alive(self, handle) -> bool:
        return handle is not None and handle.poll() is None

    def stop(self, handle) -> None:
        if handle is None or handle.poll() is not None:
            return
        handle.terminate()
        try:
            handle.wait(timeout=5.0)
        except subprocess.TimeoutExpired:
            handle.kill()


class LocalExecBackend(ExecBackend):
    """Reference backend: one `python -m repro.launch.cluster host ...`
    subprocess per host, each with its own isolated workdir — the paper's
    64-node cluster collapsed onto one box, but with REAL process and
    filesystem isolation (nothing shared but the sockets)."""

    def __init__(self, python: str = sys.executable, workers: int = 0,
                 env: Optional[Dict[str, str]] = None):
        self.python = python
        self.workers = workers
        self.env = env

    def host_args(self, host: HostSpec, attempt: int) -> List[str]:
        """Extra CLI args per launch — overridable (tests inject crash hooks
        like --max-tasks on the FIRST attempt only)."""
        return []

    def launch(self, spec: ClusterSpec, host: HostSpec, controller_addr: str,
               attempt: int = 0):
        cmd = [self.python, "-m", "repro.launch.cluster", "host",
               "--controller", controller_addr,
               "--host-id", str(host.host_id),
               "--workdir", host.workdir,
               "--workers", str(self.workers)]
        cmd += self.host_args(host, attempt)
        env = dict(os.environ)
        if self.env:
            env.update(self.env)
        return subprocess.Popen(cmd, env=env)


class CommandTemplateBackend(ExecBackend):
    """Launch through a formatted command template — the ssh/srun shape:

        CommandTemplateBackend(
            "ssh {host} env PYTHONPATH=/repo/src {python} -m "
            "repro.launch.cluster host --controller {controller} "
            "--host-id {host_id} --workdir {workdir}")

    Placeholders: {host} {host_id} {workdir} {controller} {python} {attempt}.
    The handle is the local launcher process (ssh/srun), whose exit mirrors
    the remote daemon's for liveness purposes."""

    def __init__(self, template: str, python: str = sys.executable):
        self.template = template
        self.python = python

    def launch(self, spec: ClusterSpec, host: HostSpec, controller_addr: str,
               attempt: int = 0):
        cmd = self.template.format(
            host=host.host, host_id=host.host_id, workdir=host.workdir,
            controller=controller_addr, python=self.python, attempt=attempt)
        return subprocess.Popen(shlex.split(cmd))


# ---------------------------------------------------------------------------
# Wire helpers
# ---------------------------------------------------------------------------


def _pcfg_to_wire(pcfg: PlainCfg) -> Dict:
    d = dataclasses.asdict(pcfg)
    if d.get("peer_addrs") is not None:
        d["peer_addrs"] = list(d["peer_addrs"])
    return d


def _pcfg_from_wire(d: Dict) -> PlainCfg:
    d = dict(d)
    if d.get("peer_addrs") is not None:
        d["peer_addrs"] = tuple(d["peer_addrs"])
    pcfg = PlainCfg(**d)
    # The wire pcfg bakes in trace as resolved at SUBMIT time; re-apply the
    # env override so `REPRO_TRACE=1 ... drain` arms spans for jobs queued
    # earlier without it.  Safe: result_config_key normalizes trace out, so
    # checkpoint keys (and therefore resume) are unaffected.
    resolved = _resolve_trace(pcfg)
    if resolved != pcfg.trace:
        pcfg = dataclasses.replace(pcfg, trace=resolved)
    return pcfg


def _jsonable(x):
    if isinstance(x, (tuple, list)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    return x


# ---------------------------------------------------------------------------
# Shard migration (MIGRATE frames over the exchange transport)
# ---------------------------------------------------------------------------

# CSR bucket files carry their bucket as a bare index (`csr_offv_003.npy`),
# not the `_b{ddd}` store suffix — the one naming family store_bucket
# cannot see.
_CSR_FILE_RE = re.compile(r"^csr_(?:offv|adjv)_(\d{3})\.npy$")


def _bucket_of_entry(name: str) -> Optional[int]:
    """Which bucket a workdir entry (store dir, shard file, CSR file)
    belongs to, or None for unbucketed entries (checkpoint state, specs)."""
    b = store_bucket(name)
    if b is not None:
        return b
    m = _CSR_FILE_RE.match(name)
    return int(m.group(1)) if m else None


def bucket_file_relpaths(workdir: str, bucket: int) -> List[str]:
    """Every FILE in `workdir` belonging to `bucket`, as slash-relative
    paths, spanning the top level and one namespace (job subdir) level —
    migration moves every job's data for a bucket, not one namespace's.
    Store directories are flat, so a matched store contributes its run
    files individually (file-granular resume).  `.part`/`.tmp` staging and
    `.json` checkpoint state never migrate."""
    out: List[str] = []

    def scan(rel: str, full: str) -> None:
        if os.path.isdir(full):
            for f in sorted(os.listdir(full)):
                if (not f.endswith((PART_SUFFIX, ".tmp"))
                        and os.path.isfile(os.path.join(full, f))):
                    out.append(f"{rel}/{f}")
        else:
            out.append(rel)

    for e in sorted(os.listdir(workdir)):
        if e.endswith((PART_SUFFIX, ".tmp", ".json")):
            continue
        full = os.path.join(workdir, e)
        if _bucket_of_entry(e) == bucket:
            scan(e, full)
        elif os.path.isdir(full):
            for s in sorted(os.listdir(full)):
                if s.endswith((PART_SUFFIX, ".tmp", ".json")):
                    continue
                if _bucket_of_entry(s) == bucket:
                    scan(f"{e}/{s}", os.path.join(full, s))
    return out


def _cleanup_bucket_dirs(workdir: str, bucket: int) -> None:
    """Best-effort rmdir of emptied per-bucket store dirs after a
    migration, so a later listing on the old owner can't see ghost stores
    of a bucket it no longer serves."""
    def _try(path: str) -> None:
        try:
            os.rmdir(path)
        except OSError:
            pass   # non-empty (a .part landed) or already gone — both fine

    for e in os.listdir(workdir):
        full = os.path.join(workdir, e)
        if not os.path.isdir(full):
            continue
        if _bucket_of_entry(e) == bucket:
            _try(full)
        else:
            for s in os.listdir(full):
                sf = os.path.join(full, s)
                if os.path.isdir(sf) and _bucket_of_entry(s) == bucket:
                    _try(sf)


def migrate_bucket_files(workdir: str, bucket: int, dest_addr: str,
                         transport: SocketTransport,
                         orch: Optional[PhaseOrchestrator] = None,
                         key: str = "") -> Dict[str, int]:
    """Move every file of `bucket` from this host's workdir to the
    ExchangeServer at `dest_addr`.  Each file is one resumable micro-phase
    (when `orch` is given) with a strict ordering that makes resume exact:

      send (ack-after-durable) -> unlink local copy -> checkpoint

    so on a mid-migration crash: a checkpointed file is skipped outright; a
    missing-but-unchecked file was fully acked (the crash hit between
    unlink and checkpoint) and completes as a no-op; a present file
    re-sends from offset 0, which the receiver's `.part` staging truncates
    and the deterministic bytes make an idempotent overwrite."""
    sent = {"files": 0, "bytes": 0}
    for rel in bucket_file_relpaths(workdir, bucket):
        def _send(rel=rel):
            src = os.path.join(workdir, *rel.split("/"))
            if os.path.exists(src):
                n = transport.send_file(dest_addr, src, rel)
                os.unlink(src)   # strictly after the final durable ack
                sent["files"] += 1
                sent["bytes"] += n

        if orch is not None:
            orch.run_phase(f"{key}:shard:{rel}", _send, save=_MARK, load=_SKIP)
        else:
            _send()
    _cleanup_bucket_dirs(workdir, bucket)
    return sent


# ---------------------------------------------------------------------------
# HostRunner — the worker-host daemon
# ---------------------------------------------------------------------------


class HostRunner:
    """One worker host: local ExchangeServer + task-execution loop.

    Startup order matters: the workdir stray sweep (cascade scratch,
    partial `.part` frames) runs BEFORE the ExchangeServer starts accepting
    — once peers know our address a sweep could race a live receive — and
    registration happens after, so no frame can arrive pre-sweep.

    Per-host resume: completed tasks are checkpointed in
    `<workdir>/host_phases.json` keyed by the controller-assigned task key
    (a pure function of namespace + kernel + args, NOT of dispatch order,
    so keys survive controller relaunches).  A relaunched host therefore
    re-executes only what it never finished; peers recompute nothing.
    Deterministic run tags make the reruns idempotent overwrites.

    `max_tasks` is a crash-test hook: the process hard-exits (os._exit)
    after executing that many fresh tasks — the CI host-kill scenario.
    """

    def __init__(self, workdir: str, host_id: int, controller_addr: str,
                 workers: int = 0, checkpoint: bool = True,
                 poll_interval: float = 0.05, max_tasks: int = 0,
                 exchange_host: str = "127.0.0.1"):
        self.workdir = workdir
        self.host_id = int(host_id)
        self.controller_addr = controller_addr
        self.workers = int(workers)
        self.checkpoint = checkpoint
        self.poll_interval = poll_interval
        self.max_tasks = int(max_tasks)
        os.makedirs(workdir, exist_ok=True)
        # Sweep stray cascade scratch and partial frames BEFORE the server
        # accepts — at the top level AND inside every job subdir (namespaced
        # exchanges land in <workdir>/<job>/; sweep_partial_frames already
        # walks recursively).
        clean_cascade_stores(workdir)
        for entry in os.scandir(workdir):
            if entry.is_dir():
                clean_cascade_stores(entry.path)
        sweep_partial_frames(workdir)
        self.server = ExchangeServer(workdir, host=exchange_host)
        self._orchs: Dict[str, PhaseOrchestrator] = {}
        self._orch_ledger = IOLedger()
        self._pool: Optional[ProcessPoolExecutor] = None
        self._executed = 0
        # Byte offset already shipped to the controller, per trace file
        # (this host's own + its pool workers', across job subdirs).
        self._trace_offsets: Dict[str, int] = {}

    # -- checkpoint state ----------------------------------------------------
    def _task_workdir(self, task: Dict) -> str:
        sub = task.get("subdir")
        if not sub:
            return self.workdir
        return os.path.join(self.workdir, _check_subdir(str(sub)))

    def _orchestrator(self, pcfg: PlainCfg, task: Dict) -> PhaseOrchestrator:
        """Per-JOB checkpoint state: each job subdir keeps its own
        host_phases.json (plus the default '' namespace for bare cluster
        runs), so concurrent jobs' task checkpoints never interleave and a
        dead-lettered job's state dies with its subdir."""
        sub = str(task.get("subdir") or "")
        orch = self._orchs.get(sub)
        if orch is None:
            wdir = self._task_workdir(task)
            os.makedirs(wdir, exist_ok=True)
            orch = self._orchs[sub] = PhaseOrchestrator(
                wdir, self._orch_ledger, checkpoint=self.checkpoint,
                state_name="host_phases.json",
                config_key=repr(("host", result_config_key(pcfg))),
                sweep=False)   # swept in __init__, before the server accepts
        return orch

    # -- execution -----------------------------------------------------------
    def _kernel_task(self, task: Dict) -> Tuple:
        pcfg = _pcfg_from_wire(task["pcfg"])
        args = list(task["args"])
        if task.get("wcfg"):
            args.append(WalkCfg(**task["wcfg"]))
        if task.get("wcfgs"):
            args.append([WalkCfg(**d) for d in task["wcfgs"]])
        return (task["kernel"], pcfg, self._task_workdir(task), tuple(args))

    def _migrate_task(self, task: Dict, orch: PhaseOrchestrator) -> Tuple:
        """Execute one MIGRATE task in-process (never in the spawn pool —
        its checkpoint micro-phases live in this process's orchestrator):
        ship every file of the bucket to the new owner's ExchangeServer,
        one resumable micro-phase per file in host_phases.json.  The
        destination may own no buckets yet (a just-admitted host), so its
        address rides the task (`dest_addr`), not the peer map.  Returns
        the same (out, ledger, peak, stats) shape kernels return."""
        b = int(task["args"][0])
        dest_addr = str(task["dest_addr"])
        ledger = IOLedger()
        tr = SocketTransport(
            self.workdir, ledger, peers=(dest_addr,),
            map_version=task["pcfg"].get("shard_map_version"))
        try:
            sent = migrate_bucket_files(self.workdir, b, dest_addr, tr,
                                        orch=orch, key=task["key"])
        finally:
            stats = dataclasses.asdict(tr.stats)
            tr.close()
        return sent, ledger.as_dict(), 0, stats

    def _execute(self, tasks: List[Dict]):
        """Run a batch of tasks (resumed ones skip; fresh ones run in-process
        or through the local spawn pool), YIELDING one report per task as it
        finishes — the caller sends each report immediately, so the
        controller's liveness view advances task by task, not batch by
        batch."""
        if not tasks:
            return
        futs: Dict[int, object] = {}
        if self.workers > 0:
            fresh = [t for t in tasks
                     if t["kernel"] != "migrate"
                     and not self._orchestrator(_pcfg_from_wire(t["pcfg"]),
                                                t).completed(t["key"])]
            if len(fresh) > 1:
                if self._pool is None:
                    self._pool = ProcessPoolExecutor(
                        max_workers=self.workers,
                        mp_context=get_context("spawn"))
                for t in fresh:
                    futs[t["id"]] = self._pool.submit(_run_kernel,
                                                      self._kernel_task(t))
        for t in tasks:
            rep: Dict = {"op": "report", "host_id": self.host_id,
                         "task_id": t["id"]}
            t0 = time.monotonic()
            try:
                pcfg = _pcfg_from_wire(t["pcfg"])
                # First traced task installs this host process's tracer
                # (pool workers install their own in _run_kernel).
                maybe_install_tracer(self._task_workdir(t),
                                     enabled=pcfg.trace, host=self.host_id)
                orch = self._orchestrator(pcfg, t)
                if orch.completed(t["key"]):
                    out = orch.run_phase(t["key"], lambda: None,
                                         load=lambda m: m.get("out"))
                    rep.update(ok=True, resumed=True, out=out, ledger={},
                               peak=0, stats={})
                else:
                    fut = futs.get(t["id"])
                    if t["kernel"] == "migrate":
                        fn = lambda t=t, orch=orch: self._migrate_task(t, orch)
                    else:
                        fn = (fut.result if fut is not None
                              else lambda t=t: _run_kernel(self._kernel_task(t)))
                    res = orch.run_phase(
                        t["key"], fn,
                        save=lambda r: {"out": _jsonable(r[0])},
                        load=lambda m: m.get("out"))
                    out, ldict, peak, sdict = res
                    rep.update(ok=True, resumed=False, out=_jsonable(out),
                               ledger=ldict, peak=int(peak), stats=sdict)
                    self._executed += 1
            except BaseException as e:  # noqa: BLE001 - reported, not hidden
                rep.update(ok=False, resumed=False,
                           error=f"{type(e).__name__}: {e}",
                           retriable=isinstance(e, (TransportError, OSError)),
                           ledger={}, peak=0, stats={})
            # Busy-seconds for the controller's fleet-utilization accounting
            # (resumed checkpoint replays cost ~0 and report as such).
            rep["seconds"] = time.monotonic() - t0
            # Receiver-side accounting accumulated since the last report —
            # folded into the controller's per-phase deltas at the barrier.
            sl, sg = IOLedger(), MemoryGauge()
            sstats = self.server.drain_accounting(sl, sg)
            rep.update(server_ledger=sl.as_dict(), server_peak=sg.peak_rows,
                       server_stats=dataclasses.asdict(sstats))
            yield rep

    # Lines per "trace" control op stay bounded so the JSON header never
    # approaches the server's _MAX_HEADER_BYTES frame bound.
    _TRACE_BATCH_BYTES = 256 << 10

    def _ship_trace(self, sock) -> None:
        """Ship newly-written trace lines to the controller (the "trace"
        control op) — called after each executed lease batch (the barrier
        cadence the issue asks for) and once at stop.  Reads every
        per-process trace file under this host's workdir (its own + its
        pool workers', including job subdirs) from the last-shipped byte
        offset, forwarding only COMPLETE lines in bounded batches.  Best
        effort by design: lines a dying host never ships are still on its
        disk for a local merge."""
        tracer = get_tracer()
        if not tracer.enabled:
            return
        tracer.flush()
        paths = glob.glob(os.path.join(self.workdir, TRACE_DIR,
                                       "trace_*.jsonl"))
        paths += glob.glob(os.path.join(self.workdir, "*", TRACE_DIR,
                                        "trace_*.jsonl"))
        batch: List[str] = []
        size = 0

        def send() -> None:
            nonlocal batch, size
            if batch:
                _ctrl_request(sock, {"op": "trace", "host_id": self.host_id,
                                     "lines": batch})
                batch, size = [], 0

        for p in sorted(paths):
            off = self._trace_offsets.get(p, 0)
            try:
                with open(p, "rb") as f:
                    f.seek(off)
                    data = f.read()
            except OSError:
                continue
            end = data.rfind(b"\n")
            if end < 0:
                continue   # no complete new line yet
            self._trace_offsets[p] = off + end + 1
            for line in data[:end].decode("utf-8", "replace").splitlines():
                if line:
                    batch.append(line)
                    size += len(line)
                    if size >= self._TRACE_BATCH_BYTES:
                        send()
        send()

    def _heartbeat_loop(self, stop: threading.Event, period: float) -> None:
        """Liveness side-channel on its OWN connection: a kernel can sort for
        longer than the controller's heartbeat_timeout, and the main loop's
        socket is busy-synchronous while it does — without this thread an
        externally-launched (handle-less) host doing honest work would be
        declared dead."""
        try:
            host, _, port = self.controller_addr.rpartition(":")
            s = socket.create_connection((host, int(port)), timeout=30.0)
        except OSError:
            return
        with s:
            while not stop.wait(period):
                try:
                    _ctrl_request(s, {"op": "heartbeat",
                                      "host_id": self.host_id})
                except (OSError, ClusterError):
                    return

    # -- the loop ------------------------------------------------------------
    def run(self) -> None:
        host, _, port = self.controller_addr.rpartition(":")
        sock = socket.create_connection((host, int(port)), timeout=60.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        hb_stop = threading.Event()
        try:
            hello = _ctrl_request(sock, {"op": "hello",
                                         "host_id": self.host_id,
                                         "exchange_addr": self.server.addr,
                                         "pid": os.getpid()})
            # Heartbeat cadence follows the controller's configured timeout
            # (hello reply), so short-timeout tests don't flap and
            # long-timeout deployments don't spam the control socket.
            period = heartbeat_period(float(hello.get("heartbeat_timeout",
                                                      16.0)))
            threading.Thread(target=self._heartbeat_loop,
                             args=(hb_stop, period), daemon=True).start()
            while True:
                # Long-poll: the controller parks this RPC on its condition
                # variable until tasks/stop arrive (or the wait expires), so
                # an idle host costs one RPC per wait window, not a
                # sleep-spin.
                r = _ctrl_request(sock, {"op": "poll",
                                         "host_id": self.host_id,
                                         "wait": 2.0})
                if "mapv" in r:
                    # Rebalance fence: the controller's map moved past what
                    # some in-flight sender routed under — ratchet the local
                    # server so stale-routed DATA/MIGRATE frames are refused
                    # (their senders retry against the fresh map).
                    self.server.set_min_map_version(int(r["mapv"]))
                if r["cmd"] == "stop":
                    return
                if r["cmd"] == "idle":
                    time.sleep(self.poll_interval)
                    continue
                for rep in self._execute(r["tasks"]):
                    _ctrl_request(sock, rep)
                    if self.max_tasks and self._executed >= self.max_tasks:
                        # Crash-test hook: die HARD mid-phase, like kill -9 —
                        # no server shutdown, no pool teardown, no report for
                        # the remaining tasks.
                        os._exit(17)
                try:
                    self._ship_trace(sock)
                except (OSError, ClusterError):
                    pass   # telemetry must never kill a healthy host
        finally:
            hb_stop.set()
            try:
                self._ship_trace(sock)
            except (OSError, ClusterError):
                pass
            try:
                sock.close()
            except OSError:
                pass
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self.server.stop()


# ---------------------------------------------------------------------------
# ClusterController — rendezvous, barriers, heartbeats, restarts
# ---------------------------------------------------------------------------


class ClusterController:
    """The driver-side half of the control plane, and (since the job queue)
    a multi-job scheduler: every task carries its owning `job`, each job has
    its own wire pcfg (exchange namespace, graph shape), hosts PULL bounded
    lease batches, and an idle host STEALS migratable tasks from a busy
    peer's queue tail — so one job's straggler never idles the fleet.

    All mutable state is guarded by one lock (with a condition variable for
    the barrier/poll waits) and touched from two directions: ControlServer
    connection threads (hello/poll/report) and generator threads — plural:
    concurrent jobs each run their own barrier loop over this controller.

    `lease_size` bounds how many tasks one poll hands out (0 = the host's
    whole queue, the single-job batch behavior); small leases are what make
    work-stealing effective, because un-leased tasks are still stealable.
    Only tasks dispatched with `stealable=True` (no local state — e.g. the
    fused regenerate+relabel kernel) ever migrate; everything else stays
    with the bucket owner whose disk holds its inputs."""

    def __init__(self, spec: ClusterSpec, backend: Optional[ExecBackend] = None,
                 heartbeat_timeout: float = 60.0, max_restarts: int = 1,
                 task_retries: int = 3, advertise: Optional[str] = None,
                 lease_size: int = 0, task_log_cap: int = 1024,
                 trace_dir: Optional[str] = None):
        # `advertise` is the controller address HANDED TO workers when it
        # differs from the bind address (bind 0.0.0.0, advertise the routable
        # interface); a bare hostname gets the bound port appended.
        # `task_log_cap` bounds the in-memory task log (a deque: a
        # multi-week multi-job controller keeps the most recent N reports,
        # not all of them); the full stream rotates into the trace subsystem
        # as "ctrl" events when tracing is on.  `trace_dir` is where hosts'
        # shipped trace lines land (`host{h}.jsonl`) — None drops them.
        self.spec = spec
        self.backend = backend
        self.heartbeat_timeout = heartbeat_timeout
        self.max_restarts = max_restarts
        self.task_retries = task_retries
        self.lease_size = int(lease_size)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._revive_lock = threading.Lock()
        self._exchange_addrs: Dict[int, Optional[str]] = {
            h.host_id: None for h in spec.hosts}
        self._last_seen: Dict[int, float] = {}
        self._queues: Dict[int, deque] = {h.host_id: deque()
                                          for h in spec.hosts}
        self._inflight: Dict[int, Dict[int, Dict]] = {h.host_id: {}
                                                      for h in spec.hosts}
        self._reports: Dict[int, Dict] = {}
        self._tasks: Dict[int, Dict] = {}
        self._task_seq = 0
        self._job_pcfg: Dict[str, Dict] = {}
        self._job_tids: Dict[str, set] = {}
        self._stopping = False
        self.peers_version = 0
        self.restarts: Dict[int, int] = {h.host_id: 0 for h in spec.hosts}
        self._handles: Dict[int, object] = {}
        # (host, key, job, resumed) per report — most recent task_log_cap
        # entries only (satellite of the trace subsystem: the unbounded list
        # leaked controller memory over long multi-job runs).
        self.task_log: deque = deque(maxlen=max(1, int(task_log_cap)))
        self.busy_seconds: Dict[int, float] = {h.host_id: 0.0
                                               for h in spec.hosts}
        self.steals = 0
        self.trace_dir = trace_dir
        self._trace_write_lock = threading.Lock()
        # Per-host unified telemetry, folded in from every task report
        # (kernel + receiver side): what `status` serves and --watch renders.
        self.host_ledgers: Dict[int, IOLedger] = {
            h.host_id: IOLedger() for h in spec.hosts}
        self.host_stats: Dict[int, TransportStats] = {
            h.host_id: TransportStats() for h in spec.hosts}
        self.host_last_key: Dict[int, str] = {}
        self.host_tasks_done: Dict[int, int] = {h.host_id: 0
                                                for h in spec.hosts}
        # Live routing directory, seeded with the historical contiguous
        # split — a cluster that never rebalances is bit-identical to the
        # static map.  Rewritten ONLY at phase barriers (apply_shard_moves)
        # or by restore_shard_state on a resumed run.
        self.shard_map = ShardMap.contiguous(spec.nb, spec.num_hosts)
        # Per-bucket observed I/O (bytes), folded in from every task
        # report's kernel- and receiver-side bucket counters: the
        # rebalancer's skew signal.
        self.bucket_loads: Dict[int, int] = {}
        self.rebalance_requested = False
        self.server = ControlServer(self._handle, host=spec.controller_host,
                                    port=spec.controller_port)
        self.addr = self.server.addr
        bound_port = self.addr.rsplit(":", 1)[1]
        self.public_addr = (self.addr if not advertise
                            else advertise if ":" in advertise
                            else f"{advertise}:{bound_port}")

    # -- control RPC handler (server threads) --------------------------------
    def _lease_locked(self, h: int) -> List[Dict]:
        """Pop a lease batch for host h under the lock: up to lease_size
        tasks from its own queue, else STEAL stealable tasks from the
        longest peer queue's tail (the classic work-stealing discipline:
        owners pop their own head, thieves take the cold tail)."""
        out: List[Dict] = []
        cap = self.lease_size
        while self._queues[h] and (not cap or len(out) < cap):
            task = self._queues[h].popleft()
            self._inflight[h][task["id"]] = task
            out.append(task)
        if out:
            return out
        victims = sorted((o for o in self._queues if o != h),
                         key=lambda o: -len(self._queues[o]))
        for o in victims:
            q = self._queues[o]
            # Scan the tail for stealable tasks without reordering the rest.
            keep = deque()
            while q and (not cap or len(out) < cap):
                task = q.pop()
                if task.get("stealable"):
                    self._inflight[h][task["id"]] = task
                    out.append(task)
                    self.steals += 1
                else:
                    keep.appendleft(task)
            q.extend(keep)
            if out:
                break
        return out

    def _handle(self, req: Dict) -> Dict:
        op = req.get("op")
        if op == "admin":
            # Operator plane (`rebalance`/`admit`/`status` CLI verbs): not
            # bound to a registered host, so it dispatches before the
            # host-id check below.
            return self._admin(req)
        h = int(req.get("host_id", -1))
        if h not in self._queues:
            raise ClusterError(f"unknown host_id {h}")
        now = time.monotonic()
        if op == "hello":
            with self._lock:
                self._exchange_addrs[h] = str(req["exchange_addr"])
                self._last_seen[h] = now
                # A (re)registering host lost whatever it had taken; work
                # goes back to its OWNER's queue (a stolen task's home).
                for tid, task in self._inflight[h].items():
                    self._queues[task.get("owner", h)].appendleft(task)
                self._inflight[h].clear()
                self.peers_version += 1
                self._cond.notify_all()
            return {"ok": True, "hosts": self.spec.num_hosts,
                    "nb": self.spec.nb,
                    "heartbeat_timeout": self.heartbeat_timeout}
        if op == "heartbeat":
            with self._lock:
                self._last_seen[h] = now
            return {}
        if op == "poll":
            # Long-poll: park on the condition variable until work, stop,
            # or the host's requested wait expires — the host side spends
            # the window blocked on the RPC, not sleep-spinning.
            wait = min(float(req.get("wait", 0.0)), 10.0)
            deadline = now + wait
            with self._lock:
                self._last_seen[h] = now
                while True:
                    if self._stopping:
                        return {"cmd": "stop"}
                    peers = self._peer_addrs_locked()
                    if peers is not None:
                        out = self._lease_locked(h)
                        # A MIGRATE task's destination may own no buckets
                        # yet (a just-admitted host), so its address is not
                        # in the peer map — resolve it here, and requeue the
                        # task if the destination has not registered yet.
                        ready = []
                        for task in out:
                            dest = None
                            if task["kernel"] == "migrate":
                                dest = self._exchange_addrs.get(
                                    int(task["args"][2]))
                                if dest is None:
                                    self._inflight[h].pop(task["id"], None)
                                    self._queues[task.get("owner", h)].append(
                                        task)
                                    continue
                            ready.append((task, dest))
                        if ready:
                            tasks = []
                            for task, dest in ready:
                                pcfg = dict(
                                    self._job_pcfg[task["job"]],
                                    transport="socket",
                                    peer_addrs=list(peers),
                                    shard_map_version=self.shard_map.version)
                                t = dict(task, pcfg=pcfg)
                                if dest is not None:
                                    t["dest_addr"] = dest
                                tasks.append(t)
                            return {"cmd": "tasks", "tasks": tasks,
                                    "mapv": self.shard_map.version}
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return {"cmd": "idle",
                                "mapv": self.shard_map.version}
                    self._cond.wait(timeout=remaining)
                    self._last_seen[h] = time.monotonic()
        if op == "report":
            with self._lock:
                self._last_seen[h] = now
                tid = int(req["task_id"])
                self._inflight[h].pop(tid, None)
                task = self._tasks.get(tid)
                if task is None:
                    # A cancelled (dead-lettered) job's straggler report —
                    # the job is gone; drop it.
                    return {}
                self._reports[tid] = req
                self.busy_seconds[h] += float(req.get("seconds", 0.0))
                # Fold per-bucket byte counters (kernel side AND receiver
                # side) into the rebalancer's skew signal, and the whole
                # counter dicts into the per-host telemetry the `status`
                # RPC serves.
                for ld in (req.get("ledger") or {},
                           req.get("server_ledger") or {}):
                    for ck, v in ld.items():
                        cname, idx = split_counter_key(ck)
                        if cname == "bucket_bytes" and idx is not None:
                            self.bucket_loads[idx] = (
                                self.bucket_loads.get(idx, 0) + int(v))
                    self.host_ledgers[h].merge(ld)
                fields = TransportStats.__dataclass_fields__
                for sd in (req.get("stats") or {},
                           req.get("server_stats") or {}):
                    if sd:
                        self.host_stats[h].add(TransportStats(
                            **{k: v for k, v in sd.items() if k in fields}))
                self.host_last_key[h] = task["key"]
                self.host_tasks_done[h] += 1
                self.task_log.append({
                    "host": h, "key": task["key"], "job": task.get("job", ""),
                    "ok": bool(req.get("ok")),
                    "resumed": bool(req.get("resumed"))})
                self._cond.notify_all()
            # The unbounded task history lives in the trace stream now, not
            # in controller memory: one "ctrl" instant per report.
            tracer = get_tracer()
            if tracer.enabled:
                tracer.instant(
                    "task_report", cat="ctrl", host=h, key=task["key"],
                    job=task.get("job", ""), ok=bool(req.get("ok")),
                    resumed=bool(req.get("resumed")),
                    seconds=float(req.get("seconds", 0.0)))
            return {}
        if op == "trace":
            # Hosts ship their trace files in bounded line batches at
            # barriers (HostRunner._ship_trace); the controller lands them
            # in `<trace_dir>/host{h}.jsonl` for launch/cluster.py `trace`
            # to merge.  No trace_dir configured -> the lines are dropped.
            lines = req.get("lines") or []
            if self.trace_dir and lines:
                path = os.path.join(self.trace_dir, f"host{h}.jsonl")
                with self._trace_write_lock:
                    os.makedirs(self.trace_dir, exist_ok=True)
                    with open(path, "a") as f:
                        for line in lines:
                            f.write(str(line).rstrip("\n") + "\n")
            return {}
        raise ClusterError(f"unknown control op {op!r}")

    def _peer_addrs_locked(self) -> Optional[Tuple[str, ...]]:
        # Routing goes through the live shard map, not the spec's static
        # split — after a rebalance, bucket b's slot points at its NEW
        # owner's exchange server.  (A bucket-less admitted host is absent
        # here by construction and so never blocks peer completeness.)
        addrs = []
        for b in range(self.spec.nb):
            a = self._exchange_addrs[self.shard_map.owner_of(b)]
            if a is None:
                return None
            addrs.append(a)
        return tuple(addrs)

    def peer_addrs(self) -> Tuple[str, ...]:
        with self._lock:
            peers = self._peer_addrs_locked()
        if peers is None:
            raise ClusterError("not all hosts have registered")
        return peers

    def wait_peer_addrs(self, timeout: float = 0.0) -> Tuple[str, ...]:
        """peer_addrs that tolerates a revive in flight on another thread:
        a dead host's slot is None from the moment `_revive` requeues its
        lease until the relaunch says hello, and any job thread building a
        transport inside that window must park on the registration signal
        rather than abort its phase."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while True:
                peers = self._peer_addrs_locked()
                if peers is not None:
                    return peers
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ClusterError("not all hosts have registered")
                self._cond.wait(timeout=min(0.5, remaining))

    # -- shard map: rebalancing + elastic hosts ------------------------------
    def owner_of(self, bucket: int) -> int:
        """Live owner of `bucket` — the directory lookup every placement
        decision (task dispatch, shard manifests) goes through."""
        with self._lock:
            return self.shard_map.owner_of(bucket)

    def workdir_of(self, bucket: int) -> str:
        with self._lock:
            return self.spec.hosts[self.shard_map.owner_of(bucket)].workdir

    def map_version(self) -> int:
        with self._lock:
            return self.shard_map.version

    def bucket_loads_snapshot(self) -> Dict[int, int]:
        with self._lock:
            return dict(self.bucket_loads)

    def rebalance_pending(self) -> bool:
        with self._lock:
            return self.rebalance_requested

    def plan_moves(self, max_moves: int = 0) -> List[Tuple[int, int, int]]:
        """Deterministic rebalance plan against the CURRENT map + observed
        loads (pure planning — nothing moves until apply_shard_moves)."""
        with self._lock:
            return plan_rebalance(self.shard_map, dict(self.bucket_loads),
                                  max_moves=max_moves)

    def quiesce(self, timeout: float = 30.0) -> bool:
        """Soft barrier for rebalancing: wait until no task is queued or in
        flight anywhere.  The generator calls this at its phase barrier
        (where its own tasks are already drained); the wait covers
        concurrent jobs sharing the fleet."""
        deadline = time.monotonic() + timeout
        with self._lock:
            while True:
                if not any(self._queues[h] or self._inflight[h]
                           for h in self._queues):
                    return True
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cond.wait(timeout=min(0.25, remaining))

    def apply_shard_moves(
            self, moves: Sequence[Tuple[int, int, int]]) -> int:
        """Commit a migration at a barrier: rewrite the directory, bump the
        map version (stale-route fence) and the peers version (transports
        rebuild their routes lazily).  Returns the new map version."""
        with self._lock:
            for (b, src, dst) in moves:
                if self.shard_map.owner_of(int(b)) != int(src):
                    raise ShardMapError(
                        f"stale plan: bucket {b} owned by "
                        f"{self.shard_map.owner_of(int(b))}, plan expected "
                        f"{src}")
                self.shard_map.assign(int(b), int(dst))
            self.peers_version += 1
            self._cond.notify_all()
            return self.shard_map.version

    def restore_shard_state(self, map_json: Dict,
                            hosts_json: Sequence[Dict] = ()) -> int:
        """Resume path: a relaunched controller starts from the contiguous
        map, but a previously committed rebalance may have moved buckets
        (and admitted hosts).  Re-admit any hosts beyond the spec, then
        adopt the checkpointed map if it is newer than the live one."""
        for hj in sorted(hosts_json, key=lambda d: int(d["host_id"])):
            if int(hj["host_id"]) >= self.spec.num_hosts:
                self.admit_host(str(hj["workdir"]),
                                host=str(hj.get("host", "127.0.0.1")))
        with self._lock:
            smap = ShardMap.from_json(map_json)
            if smap.nb != self.spec.nb or smap.num_hosts != self.spec.num_hosts:
                raise ClusterError(
                    f"checkpointed shard map shape ({smap.nb} buckets, "
                    f"{smap.num_hosts} hosts) does not fit the cluster "
                    f"({self.spec.nb} buckets, {self.spec.num_hosts} hosts)")
            if smap.version > self.shard_map.version:
                self.shard_map = smap
                self.peers_version += 1
                self._cond.notify_all()
            return self.shard_map.version

    def admit_host(self, workdir: str, host: str = "127.0.0.1",
                   launch: bool = True) -> int:
        """Admit a late-joining host mid-run.  It owns no buckets (and so
        blocks no barrier) until a rebalance assigns it some; `launch=False`
        registers the slot for an externally-started HostRunner.  Returns
        the new host id."""
        with self._lock:
            hid = self.spec.num_hosts
            hspec = HostSpec(hid, workdir, host)
            # replace() re-runs ClusterSpec validation: distinct workdirs,
            # and nb >= H (you cannot admit more hosts than buckets).
            self.spec = dataclasses.replace(
                self.spec, hosts=self.spec.hosts + (hspec,))
            if self.shard_map.admit_host() != hid:
                raise ClusterError("shard map and spec disagree on host ids")
            self._exchange_addrs[hid] = None
            self._queues[hid] = deque()
            self._inflight[hid] = {}
            self.restarts[hid] = 0
            self.busy_seconds[hid] = 0.0
            self.host_ledgers[hid] = IOLedger()
            self.host_stats[hid] = TransportStats()
            self.host_tasks_done[hid] = 0
            self.peers_version += 1
            self._cond.notify_all()
        if launch and self.backend is not None:
            self._handles[hid] = self.backend.launch(
                self.spec, hspec, self.public_addr, attempt=0)
        return hid

    def _admin(self, req: Dict) -> Dict:
        cmd = req.get("cmd")
        if cmd == "status":
            now = time.monotonic()
            with self._lock:
                live = {}
                for hs in self.spec.hosts:
                    hid = hs.host_id
                    seen = self._last_seen.get(hid)
                    live[str(hid)] = {
                        # The live fleet view `status --watch` renders: what
                        # each host last worked on, how deep its queue is,
                        # and its unified counters — same snapshot schema as
                        # BENCH json (trace.unified_snapshot).
                        "phase": self.host_last_key.get(hid, ""),
                        "queue": len(self._queues[hid]),
                        "inflight": len(self._inflight[hid]),
                        "tasks_done": self.host_tasks_done.get(hid, 0),
                        "busy_seconds": round(
                            self.busy_seconds.get(hid, 0.0), 3),
                        "restarts": self.restarts.get(hid, 0),
                        "heartbeat_age_s": (None if seen is None
                                            else round(now - seen, 3)),
                        "registered": self._exchange_addrs.get(hid)
                                      is not None,
                        "metrics": unified_snapshot(
                            ledger=self.host_ledgers[hid],
                            stats=self.host_stats[hid]),
                    }
                return {"ok": True, "map": self.shard_map.to_json(),
                        "hosts": [dataclasses.asdict(h)
                                  for h in self.spec.hosts],
                        "hosts_live": live,
                        "steals": self.steals,
                        "bucket_loads": {str(k): v for k, v in
                                         sorted(self.bucket_loads.items())},
                        "rebalance_requested": self.rebalance_requested}
        if cmd == "rebalance":
            # Arm the flag; the actual plan/migrate/commit runs at the
            # driving generator's next phase barrier (never mid-phase).
            with self._lock:
                self.rebalance_requested = True
            return {"ok": True}
        if cmd == "admit":
            hid = self.admit_host(str(req["workdir"]),
                                  host=str(req.get("host", "127.0.0.1")),
                                  launch=bool(req.get("launch", True)))
            return {"ok": True, "host_id": hid}
        raise ClusterError(f"unknown admin cmd {cmd!r}")

    # -- lifecycle -----------------------------------------------------------
    def launch_hosts(self) -> None:
        if self.backend is None:
            return   # hosts are started externally (manual / tests)
        for h in self.spec.hosts:
            self._handles[h.host_id] = self.backend.launch(
                self.spec, h, self.public_addr, attempt=0)

    def wait_for_hosts(self, timeout: float = 120.0) -> None:
        deadline = time.monotonic() + timeout
        while True:
            # Registration (hello) notifies the condition variable, so this
            # wait is event-driven; the bounded timeout only exists to
            # re-probe exec handles for a host that died before saying hello.
            with self._lock:
                missing = [h for h, a in self._exchange_addrs.items()
                           if a is None]
                if not missing:
                    return
                remaining = deadline - time.monotonic()
                if remaining > 0:
                    self._cond.wait(timeout=min(0.5, remaining))
                missing = [h for h, a in self._exchange_addrs.items()
                           if a is None]
            if not missing:
                return
            for h in missing:
                handle = self._handles.get(h)
                if handle is not None and not self.backend.alive(handle):
                    raise ClusterError(
                        f"host {h} exited (rc={handle.poll()}) before "
                        "registering")
            if time.monotonic() > deadline:
                raise ClusterError(f"rendezvous timeout: hosts {missing} "
                                   "never registered")

    def stop(self) -> None:
        with self._lock:
            self._stopping = True
            self._cond.notify_all()
        # Hosts exit at their next poll; reap backend handles either way.
        # Exponential backoff, not a tight poll — handle exit is the slow
        # external event here.
        deadline = time.monotonic() + 5.0
        for h, handle in self._handles.items():
            if handle is None:
                continue
            delay = 0.02
            while self.backend.alive(handle) and time.monotonic() < deadline:
                time.sleep(delay)
                delay = min(delay * 2.0, 0.25)
            self.backend.stop(handle)
        self.server.stop()

    # -- failure handling ----------------------------------------------------
    def _host_dead(self, h: int) -> bool:
        handle = self._handles.get(h)
        if handle is not None:
            return not self.backend.alive(handle)
        seen = self._last_seen.get(h)
        return seen is not None and (
            time.monotonic() - seen > self.heartbeat_timeout)

    def _revive(self, h: int) -> None:
        """A host with outstanding work died: requeue what it held (stolen
        tasks go home to their owner's queue) and relaunch it through the
        backend (within the restart budget)."""
        with self._lock:
            for tid, task in self._inflight[h].items():
                self._queues[task.get("owner", h)].appendleft(task)
            self._inflight[h].clear()
            self._exchange_addrs[h] = None
            self.peers_version += 1
            self._cond.notify_all()
        if self.backend is None or self.restarts[h] >= self.max_restarts:
            raise ClusterError(
                f"host {h} died mid-phase and the restart budget "
                f"({self.max_restarts}) is spent — relaunch the cluster to "
                "resume from the hosts' checkpoints")
        self.restarts[h] += 1
        self._handles[h] = self.backend.launch(
            self.spec, self.spec.hosts[h], self.public_addr,
            attempt=self.restarts[h])
        self.wait_for_hosts(timeout=self.heartbeat_timeout)

    def revive_dead_hosts(self) -> None:
        """Controller-side recovery hook for non-barrier failures (e.g. a
        CLEAN broadcast hitting a host that died BETWEEN barriers): relaunch
        every dead host within the restart budget, then return — the caller
        retries its operation against the healed peer map.  Serialized
        under its own lock: concurrent job threads both spotting the same
        dead host must produce ONE relaunch, not two."""
        with self._revive_lock:
            for h in list(self._queues):
                if self._host_dead(h):
                    self._revive(h)

    def heal_peers(self, since_version: int, timeout: float) -> None:
        """Recover from a controller-side transport failure observed against
        peer map version `since_version`.  A hard-killed host resets its
        sockets a few milliseconds BEFORE its exec handle polls as exited, so
        an immediate `revive_dead_hosts` can be a no-op and an immediate
        retry redials the same dead port — instead, poll until either the
        peer map has moved past the failed version with every host
        registered (a revive healed it, here or on another job thread) or
        the grace period expires with everyone still alive (the failure was
        transient; let the caller retry against the unchanged map)."""
        deadline = time.monotonic() + timeout
        while True:
            self.revive_dead_hosts()
            with self._lock:
                changed = self.peers_version != since_version
                complete = self._peer_addrs_locked() is not None
            if (changed and complete) or time.monotonic() >= deadline:
                return
            time.sleep(0.05)

    # -- the barrier ---------------------------------------------------------
    def run_tasks(self, kernel: str, argss: Sequence[Tuple], pcfg: PlainCfg,
                  namespace: str, timeout: float = 600.0, job: str = "",
                  stealable: bool = False,
                  lease_budget: int = 1) -> List[Dict]:
        """Dispatch one kernel invocation per args tuple to the owner host of
        bucket args[0], wait for every report (the phase barrier), and return
        the reports in args order.  Task keys are content-addressed
        (namespace:kernel:args, see phases.task_key) so per-host checkpoints
        survive controller relaunches and re-dispatch after failures.

        `job` scopes the barrier to one queue job (its pcfg — exchange
        namespace included — rides every lease); concurrent jobs run their
        own run_tasks threads against this one controller.  `stealable`
        marks the tasks migratable (no local inputs) so idle hosts may pull
        them.  `lease_budget` is how many DISPATCHES a deterministically
        failing (non-retriable) task gets before the barrier gives up;
        exhaustion raises TaskError naming the task key and attempt count —
        job-scoped, so a scheduler dead-letters that job while the fleet
        keeps going.  (Retriable transport failures keep the separate
        task_retries budget.)"""
        tracer = get_tracer()
        t_wall, perf0 = time.time(), time.perf_counter()
        tids = []
        pcfg_wire = _pcfg_to_wire(pcfg)
        subdir = getattr(pcfg, "exchange_namespace", None)
        with self._lock:
            self._job_pcfg[job] = pcfg_wire
            job_tids = self._job_tids.setdefault(job, set())
            for args in argss:
                wire_args, wcfg, wcfgs = [], None, None
                for a in args:
                    if isinstance(a, WalkCfg):
                        wcfg = dataclasses.asdict(a)
                    elif (isinstance(a, (list, tuple)) and a
                          and all(isinstance(w, WalkCfg) for w in a)):
                        wcfgs = [dataclasses.asdict(w) for w in a]
                    else:
                        wire_args.append(a)
                tid = self._task_seq
                self._task_seq += 1
                key = task_key(namespace, kernel, wire_args,
                               ns=(wcfg or {}).get("ns", ""))
                owner = self.shard_map.owner_of(int(wire_args[0]))
                task = {"id": tid, "key": key, "kernel": kernel,
                        "args": wire_args, "wcfg": wcfg, "wcfgs": wcfgs,
                        "attempt": 0, "job": job, "subdir": subdir,
                        "stealable": bool(stealable), "owner": owner}
                self._tasks[tid] = task
                job_tids.add(tid)
                self._queues[owner].append(task)
                tids.append(tid)
            self._cond.notify_all()
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                pending = [t for t in tids if t not in self._reports]
                failed = [(t, self._reports[t]) for t in tids
                          if t in self._reports
                          and not self._reports[t].get("ok")]
            for tid, rep in failed:
                task = self._tasks[tid]
                retriable = bool(rep.get("retriable"))
                budget = self.task_retries if retriable else lease_budget - 1
                if task["attempt"] < budget:
                    task["attempt"] += 1
                    with self._lock:
                        self._reports.pop(tid, None)
                        self._queues[task["owner"]].append(task)
                        self._cond.notify_all()
                else:
                    raise TaskError(
                        f"task {task['key']} failed after "
                        f"{task['attempt'] + 1} attempt(s): "
                        f"{rep.get('error')}",
                        task_key=task["key"],
                        attempts=task["attempt"] + 1, job=job)
            if not pending and not failed:
                break
            # Liveness: while a barrier is in progress EVERY host must be
            # alive, not just the ones owing reports — a host with no tasks
            # left is still every peer's exchange RECEIVER, and its death
            # shows up as retriable TransportErrors on the senders.  Reviving
            # it (rather than letting the senders burn their retry budget
            # against a dead server) is what heals those retries: once the
            # host re-registers, re-dispatched tasks get the fresh peer map.
            # (Revive is serialized against concurrent job threads; the
            # double-check under the revive lock keeps it single-shot.)
            with self._revive_lock:
                for h in list(self._queues):
                    if self._host_dead(h):
                        self._revive(h)
            if time.monotonic() > deadline:
                raise ClusterError(
                    f"barrier timeout waiting for {kernel} "
                    f"({len(pending)} tasks outstanding)", job=job)
            # Event-driven barrier: reports/requeues notify; the bounded
            # timeout only paces the liveness re-check above.
            with self._lock:
                if all(t in self._reports for t in tids):
                    continue
                self._cond.wait(timeout=0.5)
        with self._lock:
            out = [self._reports.pop(t) for t in tids]
            job_tids = self._job_tids.get(job)
            if job_tids is not None:
                job_tids.difference_update(tids)
        if tracer.enabled:
            # One barrier span per dispatched phase: dispatch -> last report.
            tracer.event(f"barrier:{kernel}", "ctrl", t_wall,
                         time.perf_counter() - perf0,
                         args={"tasks": len(tids), "job": job} if job
                         else {"tasks": len(tids)})
        return out

    def cancel_job(self, job: str) -> None:
        """Purge every queued task of `job` (dead-letter path): unqueue,
        forget reports, and drop the job's pcfg.  Inflight tasks on hosts
        finish and their straggler reports are ignored (the report handler
        drops unknown tids)."""
        with self._lock:
            tids = self._job_tids.pop(job, set())
            for h in list(self._queues):
                self._queues[h] = deque(
                    t for t in self._queues[h] if t["id"] not in tids)
                for tid in list(self._inflight[h]):
                    if tid in tids:
                        self._inflight[h].pop(tid)
            for tid in tids:
                self._reports.pop(tid, None)
                self._tasks.pop(tid, None)
            self._job_pcfg.pop(job, None)
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# ClusterGenerator — PartitionedGenerator over the cluster pool
# ---------------------------------------------------------------------------


class _ControllerTransport:
    """The controller's clean/flush transport, rebuilt whenever cluster
    membership changes (a restarted host's ExchangeServer has a new
    ephemeral port).  Only the driver-side operations exist — the controller
    never sends data frames; kernels exchange host-to-host."""

    kind = "cluster"

    def __init__(self, gen: "ClusterGenerator"):
        self._gen = gen
        self._tr: Optional[SocketTransport] = None
        self._ver = -1

    def _cur(self) -> SocketTransport:
        ctl = self._gen.controller
        if self._tr is None or self._ver != ctl.peers_version:
            if self._tr is not None:
                self._tr.close()
            self._tr = SocketTransport(
                self._gen.workdir, self._gen.ledger, self._gen.gauge,
                peers=ctl.wait_peer_addrs(timeout=ctl.heartbeat_timeout),
                namespace=getattr(self._gen.pcfg, "exchange_namespace", None),
                map_version=ctl.map_version())
            self._ver = ctl.peers_version
        return self._tr

    def clean_inboxes(self, names: Sequence[str]) -> None:
        # A peer can die between barriers (no task owed, so the barrier
        # loop's liveness never saw it).  Revive within the controller's
        # max_restarts budget and retry against each healed peer map; once
        # the budget is spent the failure is real and surfaces as a
        # structured ClusterError naming the sweep and attempt count.  The
        # retried CLEAN is idempotent — inboxes already swept on surviving
        # hosts just get swept again.
        ctl = self._gen.controller
        budget = max(1, int(ctl.max_restarts))
        for attempt in range(budget + 1):
            try:
                self._cur().clean_inboxes(names)
                return
            except (TransportError, OSError) as e:
                failed_ver = self._ver   # map version the failed dial used
                if self._tr is not None:
                    self._tr.close()
                    self._tr = None
                if attempt >= budget:
                    raise ClusterError(
                        f"clean_inboxes failed after {attempt + 1} "
                        f"attempt(s) ({len(names)} inbox(es), first "
                        f"{names[0] if names else '<none>'!r}): {e}",
                        task_key=f"clean:{names[0] if names else ''}",
                        attempts=attempt + 1) from e
                ctl.heal_peers(failed_ver, timeout=ctl.heartbeat_timeout)

    def purge_namespace(self) -> None:
        """Dead-letter GC: remove this generator's exchange namespace dir on
        every peer (partial inbound stores of a cancelled job)."""
        self._cur().purge_namespace()

    def flush(self) -> None:
        pass

    def close(self) -> None:
        if self._tr is not None:
            self._tr.close()
            self._tr = None


class ClusterGenerator(PartitionedGenerator):
    """The partitioned driver with its worker pool swapped for a cluster of
    HostRunners: same phase drivers, same kernels, bit-identical outputs —
    but generation, walks, and the pooled cascade's merge groups all execute
    on whichever host owns each bucket, exchanges cross the wire once, CSR
    bucket files and corpus shards live ONLY on their owner host's workdir,
    and the controller's workdir holds nothing but checkpoint state and
    manifests.

    Fine-grained phases (every clean and every barrier its own checkpoint)
    plus per-host task checkpoints give the failure story the acceptance
    criterion demands: kill a host mid-phase, relaunch (automatically via
    the exec backend within `max_restarts`, or by rerunning the whole
    launcher), and only that host's unfinished tasks re-execute.

    run() returns (graph_manifest_path, ledger); walk_corpus() returns a
    ShardedWalks over the per-host shards.  `load_csr()` assembles the CSR
    the single-host way — only meaningful where every host workdir is
    reachable (one box, or a shared view for analysis).
    """

    _fine_phases = True

    def __init__(self, cfg, spec: ClusterSpec, workdir: str,
                 backend: Optional[ExecBackend] = None,
                 checkpoint: bool = True, keep_all: Optional[bool] = None,
                 heartbeat_timeout: float = 60.0, max_restarts: int = 1,
                 rendezvous_timeout: float = 120.0,
                 barrier_timeout: float = 600.0,
                 advertise: Optional[str] = None,
                 controller: Optional[ClusterController] = None,
                 job: str = "", lease_budget: int = 1,
                 rebalance: bool = False):
        pcfg = validate_external_shape(
            cfg if isinstance(cfg, PlainCfg) else plain_config(cfg))
        if pcfg.transport != "socket":
            raise ValueError("cluster runs exchange over sockets; build the "
                             "config with transport='socket'")
        if pcfg.peer_addrs is not None:
            raise ValueError("peer_addrs are discovered at rendezvous — "
                             "leave them unset for cluster runs")
        if spec.nb != pcfg.nb:
            raise ValueError(f"spec.nb={spec.nb} != cfg.nb={pcfg.nb}")
        self.spec = spec
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        # The controller/driver process traces too (barrier + phase spans);
        # "ctrl" as the host label keeps its lane distinct from host ids.
        maybe_install_tracer(workdir, enabled=pcfg.trace, host="ctrl",
                             job=job or None)
        self.ledger = IOLedger()
        self.gauge = MemoryGauge()
        self.exchange_stats = TransportStats()
        self._servers: List[ExchangeServer] = []   # none local: hosts own them
        self._pool = None
        self.max_workers = 0
        self.barrier_timeout = barrier_timeout
        self.lease_budget = lease_budget
        self._namespace = "gen"
        self._job = job
        # Skew-aware rebalancing at every phase barrier; a one-shot
        # rebalance can instead be armed at runtime through the controller's
        # `rebalance` admin op.  Committed rebalances replay on resume even
        # when the flag is off (the checkpointed map must be restored).
        self.rebalance = bool(rebalance)
        if job:
            # Multi-tenant: every exchange frame and every host-side store of
            # this generator lives under the job's namespace subdir, so
            # concurrent jobs on one fleet never share an inbox and a
            # dead-lettered job's partials can be purged by one rmtree.
            pcfg = dataclasses.replace(pcfg, exchange_namespace=job)
        if keep_all is None:
            keep_all = bool(getattr(cfg, "keep_phase_stores", False))
        self.keep_all = keep_all
        self._owns_controller = controller is None
        if controller is None:
            controller = ClusterController(
                spec, backend=backend, heartbeat_timeout=heartbeat_timeout,
                max_restarts=max_restarts, advertise=advertise,
                trace_dir=(os.path.join(workdir, TRACE_DIR) if pcfg.trace
                           else None))
            try:
                controller.launch_hosts()
                controller.wait_for_hosts(rendezvous_timeout)
            except BaseException:
                controller.stop()
                raise
        elif pcfg.trace and controller.trace_dir is None:
            # A shared (scheduler-owned) controller starts collecting host
            # traces the moment any traced job runs through it.
            controller.trace_dir = os.path.join(workdir, TRACE_DIR)
        self.controller = controller
        self.pcfg = dataclasses.replace(
            pcfg, peer_addrs=self.controller.peer_addrs(),
            shard_map_version=self.controller.map_version())
        self.transport = _ControllerTransport(self)
        self.orchestrator = PhaseOrchestrator(
            workdir, self.ledger, checkpoint=checkpoint,
            config_key=repr(("cluster", result_config_key(self.pcfg))),
            keep_all=keep_all, stats=self.exchange_stats,
            cleaner=lambda names: self.transport.clean_inboxes(names))

    # -- pool plumbing --------------------------------------------------------
    def _submit(self, kernel: str, tasks: Sequence[Tuple]) -> List:
        # Recompute-shuffle generation reads nothing local (the RMAT chunk
        # regenerates from (pcfg, lo) alone), so those leases may migrate to
        # idle hosts; everything else is pinned to the bucket owner's disk.
        reports = self.controller.run_tasks(
            kernel, [t[3] for t in tasks], self.pcfg, self._namespace,
            timeout=self.barrier_timeout, job=self._job,
            stealable=(kernel == "gen_relabel_recompute"),
            lease_budget=self.lease_budget)
        results = []
        for rep in reports:
            self.ledger.merge(rep.get("server_ledger", {}))
            self.gauge.track(int(rep.get("server_peak", 0)))
            self.exchange_stats.add(
                TransportStats(**rep.get("server_stats", {})))
            out = rep.get("out")
            results.append((tuple(out) if isinstance(out, list) else out,
                            rep.get("ledger", {}), int(rep.get("peak", 0)),
                            rep.get("stats", {})))
        return results

    def _map(self, kernel, argss):
        tasks = [(kernel, self.pcfg, None, args) for args in argss]
        results = self._submit(kernel, tasks)
        outs = []
        for out, ldict, peak, sdict in results:
            self.ledger.merge(ldict)
            self.gauge.track(peak)
            if sdict:
                self.exchange_stats.add(TransportStats(**sdict))
            outs.append(out)
        return outs

    # -- placement hooks ------------------------------------------------------
    # All placement goes through the controller's LIVE shard map, not the
    # spec's static split — after a rebalance (or an elastic admission) the
    # spec no longer describes where buckets live.
    def _host_dir(self, b: int) -> str:
        base = self.controller.workdir_of(b)
        ns = getattr(self.pcfg, "exchange_namespace", None)
        return os.path.join(base, ns) if ns else base

    def _csr_dir(self, i: int) -> str:
        return self._host_dir(i)

    def _shard_dir_of(self, j: int) -> str:
        return self._host_dir(j)

    def _shard_host_of(self, j: int) -> int:
        return self.controller.owner_of(j)

    # -- rebalancing (phase barriers only) ------------------------------------
    def _maybe_rebalance(self, tag: str) -> None:
        """Skew-aware shard rebalance, run at a phase barrier as three
        checkpointed phases so a crash anywhere in the sequence resumes
        exactly:

          rebalance_plan[tag]     quiesce, snapshot per-bucket loads, and
                                  compute the deterministic greedy plan —
                                  saved verbatim, so a resumed run replays
                                  the identical plan
          rebalance_migrate[tag]  one MIGRATE task per move to the source
                                  host (file-granular resumable micro-phases
                                  in its host_phases.json)
          rebalance_commit[tag]   rewrite the directory + bump the map
                                  version — saved with the full map and host
                                  manifest, so a RELAUNCHED controller
                                  restores ownership (and re-admits elastic
                                  hosts) before any later phase routes
        """
        ctl = self.controller
        plan_phase = f"rebalance_plan[{tag}]"
        if not (self.rebalance or ctl.rebalance_pending()
                or self.orchestrator.completed(plan_phase)):
            return
        moves = self.orchestrator.run_phase(
            plan_phase, self._plan_moves,
            save=lambda mv: {"moves": mv},
            load=lambda m: [list(x) for x in m["moves"]])
        if not moves:
            return
        self.orchestrator.run_phase(
            f"rebalance_migrate[{tag}]",
            lambda: self._migrate_moves(moves, tag),
            save=_MARK, load=_SKIP)

        def _commit():
            ver = ctl.apply_shard_moves([(int(b), int(s), int(d))
                                         for b, s, d in moves])
            with ctl._lock:
                ctl.rebalance_requested = False
                return {"version": ver, "map": ctl.shard_map.to_json(),
                        "hosts": [dataclasses.asdict(hs)
                                  for hs in ctl.spec.hosts]}

        def _load_commit(m):
            ctl.restore_shard_state(m["map"], m.get("hosts", ()))
            return m

        self.orchestrator.run_phase(f"rebalance_commit[{tag}]", _commit,
                                    save=lambda m: m, load=_load_commit)
        self._refresh_routes()

    def _plan_moves(self) -> List[List[int]]:
        ctl = self.controller
        # Our own barrier just drained, so this only waits on OTHER jobs
        # sharing the fleet — rebalancing never happens under live traffic.
        if not ctl.quiesce(timeout=min(30.0, self.barrier_timeout)):
            raise ClusterError("rebalance needs a quiet fleet: tasks still "
                               "queued or in flight at the barrier")
        return [[int(b), int(s), int(d)] for b, s, d in ctl.plan_moves()]

    def _migrate_moves(self, moves: Sequence[Sequence[int]],
                       tag: str) -> None:
        ctl = self.controller
        # (bucket, gen, dest): args[0] places the task at the CURRENT owner
        # (the source), the split generation keys this migration apart from
        # any later move of the same bucket, args[2] routes the bytes.
        argss = [(int(b), int(ctl.shard_map.gen_of(int(b))), int(d))
                 for b, _, d in moves]
        ctl.run_tasks("migrate", argss, self.pcfg, f"rebalance[{tag}]",
                      timeout=self.barrier_timeout, job=self._job,
                      lease_budget=self.lease_budget)

    def _refresh_routes(self) -> None:
        """Post-commit: subsequent dispatches must ride the new map —
        fresh peer_addrs (bucket -> new owner's server) and the bumped map
        version (the stale-route fence's stamp).  The controller-side clean
        transport rebuilds itself lazily off peers_version."""
        ctl = self.controller
        self.pcfg = dataclasses.replace(
            self.pcfg,
            peer_addrs=ctl.wait_peer_addrs(timeout=ctl.heartbeat_timeout),
            shard_map_version=ctl.map_version())

    # -- driver ---------------------------------------------------------------
    def run(self, csr_variant: str = "sorted"):
        """All generation phases across the cluster; returns
        (graph_manifest_path, ledger).  The manifest records, per bucket,
        the owner host and its CSR file paths — the cluster twin of
        PartitionedGenerator.run()'s in-memory CSR list."""
        paths = self._run_phases(csr_variant)
        manifest_path = os.path.join(self.workdir, "graph_manifest.json")

        def _manifest():
            payload = {
                "version": 1, "nb": self.pcfg.nb,
                "scale": self.pcfg.scale, "edge_factor": self.pcfg.edge_factor,
                "csr_variant": csr_variant,
                "buckets": [
                    {"bucket": i, "host": self.controller.owner_of(i),
                     "workdir": self._host_dir(i),
                     "offv": os.path.basename(o), "adjv": os.path.basename(a)}
                    for i, (o, a) in enumerate(paths)],
            }
            tmp = manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, manifest_path)

        self.orchestrator.run_phase("graph_manifest", _manifest,
                                    save=_MARK, load=_SKIP)
        return manifest_path, self.ledger

    def load_csr(self):
        """Assemble [(offv, adjv memmap)] per bucket by reading each owner
        host's files — colocated/shared-view deployments only."""
        from .phases import load_bucket_csr
        return [load_bucket_csr(csr_offv_path(self._host_dir(i), i),
                                csr_adjv_path(self._host_dir(i), i),
                                self.ledger, self.gauge)
                for i in range(self.pcfg.nb)]

    def walk_corpus(self, num_walkers: int, length: int, seed: int = 0,
                    out_name: str = "walks.npy", checkpoint: bool = True):
        self._namespace = f"walk:{num_walkers}:{length}:{seed}:{out_name}"
        try:
            return super().walk_corpus(num_walkers, length, seed=seed,
                                       out_name=out_name,
                                       checkpoint=checkpoint)
        finally:
            self._namespace = "gen"

    def walk_corpus_fused(self, specs, checkpoint: bool = True):
        """Batched corpora over the cluster: one fused hop barrier per
        bucket per step advances every (num_walkers, length, seed, out_name)
        spec through a single CSR scan on the owner host — PR 2's carried
        upside, now a first-class job-queue fusion."""
        self._namespace = "walkf:" + ";".join(
            f"{w}:{l}:{s}:{o}" for w, l, s, o in specs)
        try:
            return super().walk_corpus_fused(specs, checkpoint=checkpoint)
        finally:
            self._namespace = "gen"

    def close(self):
        try:
            if self._owns_controller:
                self.controller.stop()
        finally:
            self.transport.close()
