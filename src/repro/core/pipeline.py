"""End-to-end distributed graph generation (the paper's driver routine).

generate(cfg) wires the phases in the paper's order:

    shuffle -> generate edges -> relabel -> redistribute -> build CSR

Each phase is independently jitted so benchmarks can time them separately
(the paper's Fig. 2/4 are per-phase measurements).  The whole pipeline runs
under shard_map on a 1-D mesh whose shards play the paper's "compute nodes".

Device-memory variant here; the true out-of-core variant (host memmap
streaming, the paper's SSD tier) is core/external.py's StreamingGenerator.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.collectives import flat_mesh, shard_map
from .csr import CSRShards, build_csr_scatter, build_csr_sorted
from .redistribute import OwnedEdges, redistribute, redistribute_sorted
from .relabel import relabel_alltoall, relabel_recompute, relabel_ring
from .rmat import rmat_edge_block
from .shuffle import distributed_shuffle, shuffle_argsort, shuffle_recompute
from .types import GraphConfig


class GraphResult(NamedTuple):
    pv: jnp.ndarray
    src: jnp.ndarray          # relabeled, pre-redistribute (generation order)
    dst: jnp.ndarray
    owned: OwnedEdges
    csr: CSRShards
    dropped_relabel: jnp.ndarray
    dropped_redistribute: jnp.ndarray


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def generate_edges(cfg: GraphConfig, mesh: Mesh, axis: str = "shards"):
    """Paper Alg. 5: each shard generates its bin of B*f edges.  The
    counter-based RNG makes every shard's stream independent of nb — the
    same graph is produced at any shard count (tested), which is also what
    makes regeneration-instead-of-checkpoint possible for this phase."""
    eps = cfg.edges_per_shard

    def per_shard(_):
        bid = jax.lax.axis_index(axis)
        start = (bid * eps).astype(jnp.uint32)
        return rmat_edge_block(cfg, start, eps)

    fn = shard_map(
        per_shard, mesh=mesh, in_specs=(P(axis),), out_specs=(P(axis), P(axis))
    )
    return fn(jnp.zeros((mesh.shape[axis],), jnp.int32))


def generate(
    cfg: GraphConfig,
    mesh: Optional[Mesh] = None,
    axis: str = "shards",
    shuffle_variant: str = "paper",        # "paper" | "argsort" | "recompute"
) -> GraphResult:
    """Run the full pipeline.  Returns device arrays (sharded over `axis`)."""
    mesh = mesh if mesh is not None else flat_mesh(cfg.nb, axis)
    assert mesh.shape[axis] == cfg.nb

    # 1. permutation phase
    if shuffle_variant == "paper":
        pv = distributed_shuffle(cfg, mesh, axis)
    elif shuffle_variant == "argsort":
        pv = shuffle_argsort(cfg, mesh, axis)
    elif shuffle_variant == "recompute":
        # Communication-free: the permutation is the keyed Feistel family.
        # pv is materialized only because GraphResult exposes it — the
        # relabel below recomputes labels directly and never reads it.
        pv = shuffle_recompute(cfg, mesh, axis)
    else:
        raise ValueError(shuffle_variant)

    # 2. edge generation phase
    src, dst = generate_edges(cfg, mesh, axis)

    # 3. relabeling phase
    dropped_rel = jnp.zeros((), jnp.int32)
    if shuffle_variant == "recompute":
        # Zero collectives: both endpoints relabel as hash evaluations.
        new_src, new_dst = relabel_recompute(cfg, mesh, src, dst, axis)
    elif cfg.relabel_variant == "ring":
        new_src, new_dst = relabel_ring(cfg, mesh, src, dst, pv, axis)
    elif cfg.relabel_variant == "alltoall":
        new_src, new_dst, dropped_rel = relabel_alltoall(cfg, mesh, src, dst, pv, axis)
    else:
        raise ValueError(cfg.relabel_variant)

    # 4+5. redistribute + CSR
    if cfg.csr_variant == "sorted":
        owned = redistribute_sorted(cfg, mesh, new_src, new_dst, axis)
        csr = build_csr_sorted(cfg, mesh, owned, axis)
    elif cfg.csr_variant == "scatter":
        owned = redistribute(cfg, mesh, new_src, new_dst, axis)
        csr = build_csr_scatter(cfg, mesh, owned, axis)
    else:
        raise ValueError(cfg.csr_variant)

    return GraphResult(pv, new_src, new_dst, owned, csr, dropped_rel, owned.dropped)


# ---------------------------------------------------------------------------
# The memory-resident hash baseline (what the paper is replacing)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def generate_baseline_hash(cfg: GraphConfig):
    """Graph500 'hashing based' kernel: generate, hash-relabel in place, sort,
    CSR — all memory-resident, no permutation vector, no communication.
    The single-node reference for benchmarks/bench_hash_vs_sort.py."""
    from .hashing import hash_relabel

    src, dst = rmat_edge_block(cfg, jnp.uint32(0), cfg.m)
    src, dst = hash_relabel(cfg, src, dst)
    order = jnp.argsort(src)
    src_s, dst_s = src[order], dst[order]
    offv = jnp.searchsorted(src_s, jnp.arange(cfg.n + 1, dtype=src_s.dtype), side="left").astype(jnp.int32)
    return offv, dst_s
