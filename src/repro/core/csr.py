"""Build the CSR representation (paper Alg. 1, 10, 11 and §III-B7).

Each shard owns vertices [bid*B, (bid+1)*B) and all edges whose relabeled
source is in that range (post-redistribute).  CSR per shard:

  offv: [B+1] offsets into adjv  (global vertex `v` -> local row `v - bid*B`)
  adjv: [cap_m] destination ids, valid prefix per row given by offv

Two variants, matching the paper:

  build_csr_scatter   adapts Alg. 10/11.  The paper increments an in-memory
      associative map and flushes with atomic CAS.  TPUs have no useful
      scatter-atomics, so the *insight-faithful* adaptation is: degree via
      scatter-add (XLA serializes deterministically), offsets via exclusive
      scan, and adjacency placement via offv[src] + within-source rank.  The
      rank needs a sort anyway — which is precisely the paper's observation
      that unordered CSR construction is the scaling bottleneck (Fig. 2's
      super-linear CSR curve).  The *measured* random-I/O blowup is
      reproduced on the host/external path (external.py + benchmarks), where
      scatter really does hit memmap pages randomly.

  build_csr_sorted    Alg. 1 on §III-B7 output: edges arrive sorted by src,
      so offsets are a searchsorted and adjv is the dst column verbatim —
      O(m) sequential access, the paper's predicted fix.  This is the
      default (csr_variant="sorted").
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.collectives import shard_map
from .redistribute import OwnedEdges
from .types import GraphConfig


class CSRShards(NamedTuple):
    """Distributed CSR: shard i owns rows [i*B, (i+1)*B)."""

    offv: jnp.ndarray    # global [nb*(B+1)]  (per-shard [B+1])
    adjv: jnp.ndarray    # global [nb*cap_m]  (per-shard [cap_m], valid prefix)
    num_edges: jnp.ndarray  # global [nb] edges owned per shard


def _degrees(src_local: jnp.ndarray, valid: jnp.ndarray, base: jnp.ndarray, B: int) -> jnp.ndarray:
    """Alg. 10 adapted: masked scatter-add into the local degree vector."""
    rows = jnp.clip(src_local - base, 0, B - 1)
    return jnp.zeros((B,), jnp.int32).at[rows].add(valid.astype(jnp.int32))


def _offsets(degv: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(degv, dtype=jnp.int32)])


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def build_csr_scatter(cfg: GraphConfig, mesh: Mesh, owned: OwnedEdges, axis: str = "shards") -> CSRShards:
    """Unordered-input CSR (paper Alg. 10/11 adapted to sort-rank placement)."""
    B = cfg.bucket_size

    def per_shard(src, dst, valid):
        bid = lax.axis_index(axis)
        base = bid * B
        s, d, v = src.reshape(-1), dst.reshape(-1), valid.reshape(-1)
        degv = _degrees(s, v, base, B)
        offv = _offsets(degv)
        # adjacency: position = offv[row] + within-row rank.  After a stable
        # sort by row key (invalid -> B, sinks to the end) the sorted order
        # IS that placement: edge i of the sorted stream lands at adjv[i].
        # This sort is exactly the cost the paper's Fig. 2 charges to the
        # unordered CSR variant; §III-B7 (build_csr_sorted) avoids it.
        rows = jnp.where(v, jnp.clip(s - base, 0, B - 1), B)
        order = jnp.argsort(rows, stable=True)              # the hidden sort
        cnt = jnp.sum(v.astype(jnp.int32))
        adjv = jnp.where(jnp.arange(order.shape[0]) < cnt, d[order], 0)
        return offv, adjv, cnt[None]

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    offv, adjv, cnt = fn(owned.src, owned.dst, owned.valid)
    return CSRShards(offv, adjv, cnt)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def build_csr_sorted(cfg: GraphConfig, mesh: Mesh, owned: OwnedEdges, axis: str = "shards") -> CSRShards:
    """Sorted-input CSR (paper Alg. 1 / §III-B7 fast path): offsets by
    searchsorted, adjacency verbatim.  Input must be redistribute_sorted
    output (flattened per-shard arrays sorted by src)."""
    B = cfg.bucket_size

    def per_shard(src, dst, valid):
        bid = lax.axis_index(axis)
        base = bid * B
        s, d, v = src.reshape(-1), dst.reshape(-1), valid.reshape(-1)
        cnt = jnp.sum(v.astype(jnp.int32))
        # rows sorted ascending over the valid prefix (invalid sorted to end
        # by redistribute_sorted's sentinel keys).
        keyed = jnp.where(v, s - base, B)
        offv_full = jnp.searchsorted(keyed, jnp.arange(B + 1, dtype=keyed.dtype), side="left")
        offv = offv_full.astype(jnp.int32)
        adjv = jnp.where(jnp.arange(d.shape[0]) < cnt, d, 0)
        return offv, adjv, cnt[None]

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    offv, adjv, cnt = fn(owned.src, owned.dst, owned.valid)
    return CSRShards(offv, adjv, cnt)


def csr_to_host(csr: CSRShards, cfg: GraphConfig):
    """Assemble the distributed CSR into one host (offv [n+1], adjv [m]) pair.

    Per-shard offsets are local; rebase and concatenate the valid prefixes.
    Used by the host random-walk sampler (data/) and validation.
    """
    import numpy as np

    B = cfg.bucket_size
    nb = cfg.nb
    offv_s = np.asarray(csr.offv).reshape(nb, B + 1)
    cap_m = csr.adjv.shape[0] // nb
    adjv_s = np.asarray(csr.adjv).reshape(nb, cap_m)
    cnt = np.asarray(csr.num_edges)
    parts = [adjv_s[i, : cnt[i]] for i in range(nb)]
    base = np.concatenate([[0], np.cumsum(cnt)]).astype(np.int64)
    offv = np.concatenate(
        [offv_s[i, :-1].astype(np.int64) + base[i] for i in range(nb)]
        + [[base[-1]]]
    )
    return offv, np.concatenate(parts) if parts else np.zeros((0,), np.int32)


def csr_neighbors(csr: CSRShards, cfg: GraphConfig, v: int):
    """Host-side convenience: adjacency list of global vertex v (for tests
    and the random-walk sampler)."""
    B = cfg.bucket_size
    shard = v // B
    row = v - shard * B
    offv = csr.offv.reshape(cfg.nb, B + 1)[shard]
    cap_m = csr.adjv.shape[0] // cfg.nb
    adjv = csr.adjv.reshape(cfg.nb, cap_m)[shard]
    return adjv[offv[row]:offv[row + 1]]
