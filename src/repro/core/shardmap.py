"""Versioned directory shard map: bucket -> (host, split-generation).

ClusterSpec's closed-form contiguous split (``range(h*nb//H, (h+1)*nb//H)``)
fixes placement for the lifetime of a run, so RMAT degree skew turns the
hosts owning hot buckets into stragglers.  The ShardMap replaces that
closed form with an explicit directory the controller may rewrite **at
phase barriers only**:

  * ``owners[b]`` is the host that owns bucket ``b`` right now — every
    ownership lookup (task placement, exchange routing, shard manifests,
    lease planning) goes through the map instead of the closed form.
  * ``gens[b]`` is the bucket's split generation: bumped on every
    reassignment so a migration of bucket ``b`` at generation ``g`` can be
    told apart from a later one, and so resumable migration micro-phases
    key on ``(bucket, gen)`` rather than wall-clock identity.
  * ``version`` is a map-wide monotone counter.  Frames routed under an
    old map carry their sender's ``mapv``; receivers refuse anything below
    their ratcheted minimum (see :func:`frame_version_ok`), so a host that
    missed a barrier cannot deliver bytes to a stale owner.

The map is pure data + pure planning.  Mutation of live cluster state
(queues, exchange addresses, transports) stays in core/cluster.py; moving
the bytes stays in core/transport.py (MIGRATE frames).  Keeping this
module dependency-free makes the rebalancing laws property-testable in
isolation (tests/test_cluster_property.py).

``contiguous(nb, num_hosts)`` reproduces ClusterSpec's historical split
exactly, so a cluster that never rebalances is bit-for-bit the static
map — the map changes *where* bytes live, never *what* they are.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple


class ShardMapError(RuntimeError):
    pass


@dataclasses.dataclass
class ShardMap:
    """Directory of bucket ownership.  Mutable on purpose: the controller
    owns the single live instance and rewrites it under its lock; every
    mutation bumps ``version`` so stale routes are detectable."""

    nb: int
    num_hosts: int
    owners: List[int]
    gens: List[int]
    version: int = 0

    # -- construction -------------------------------------------------

    @classmethod
    def contiguous(cls, nb: int, num_hosts: int) -> "ShardMap":
        """The historical static split, verbatim: host h owns
        ``range(h*nb//H, (h+1)*nb//H)``.  Version 0, all gens 0."""
        if num_hosts < 1 or nb < num_hosts:
            raise ShardMapError(f"need nb >= num_hosts >= 1, got nb={nb} "
                                f"num_hosts={num_hosts}")
        owners = [0] * nb
        for h in range(num_hosts):
            for b in range(h * nb // num_hosts, (h + 1) * nb // num_hosts):
                owners[b] = h
        return cls(nb=nb, num_hosts=num_hosts, owners=owners,
                   gens=[0] * nb, version=0)

    def __post_init__(self) -> None:
        self.validate()

    # -- lookups ------------------------------------------------------

    def owner_of(self, bucket: int) -> int:
        return self.owners[self._check_bucket(bucket)]

    def gen_of(self, bucket: int) -> int:
        return self.gens[self._check_bucket(bucket)]

    def buckets_of(self, host: int) -> List[int]:
        """All buckets owned by ``host``, ascending (the static map's
        ``range`` order, so callers iterating it are order-stable)."""
        return [b for b in range(self.nb) if self.owners[b] == host]

    def _check_bucket(self, bucket: int) -> int:
        b = int(bucket)
        if not 0 <= b < self.nb:
            raise ShardMapError(f"bucket {b} out of range [0, {self.nb})")
        return b

    # -- mutation (controller-side, at phase barriers only) -----------

    def assign(self, bucket: int, host: int) -> None:
        """Reassign ``bucket`` to ``host``; bumps the bucket's split
        generation and the map version.  No-op reassignments are
        rejected — every version bump must mean a real route change."""
        b = self._check_bucket(bucket)
        h = int(host)
        if not 0 <= h < self.num_hosts:
            raise ShardMapError(f"host {h} out of range [0, {self.num_hosts})")
        if self.owners[b] == h:
            raise ShardMapError(f"bucket {b} already owned by host {h}")
        self.owners[b] = h
        self.gens[b] += 1
        self.version += 1
        self.validate()

    def admit_host(self) -> int:
        """Admit a late-joining host.  It owns nothing until a rebalance
        assigns it buckets; returns the new host id (== old num_hosts).
        Bumps the version: peers must learn the enlarged host set."""
        hid = self.num_hosts
        self.num_hosts += 1
        self.version += 1
        return hid

    # -- invariants ---------------------------------------------------

    def validate(self) -> None:
        """Ownership must stay a partition of ``range(nb)`` over known
        hosts (hosts MAY own zero buckets: a just-admitted host does)."""
        if len(self.owners) != self.nb or len(self.gens) != self.nb:
            raise ShardMapError("owners/gens length != nb")
        for b, h in enumerate(self.owners):
            if not 0 <= h < self.num_hosts:
                raise ShardMapError(f"bucket {b} owned by unknown host {h}")
        for b, g in enumerate(self.gens):
            if g < 0:
                raise ShardMapError(f"bucket {b} has negative gen {g}")
        if self.version < 0:
            raise ShardMapError(f"negative version {self.version}")

    # -- serialization ------------------------------------------------

    def to_json(self) -> Dict[str, object]:
        return {"nb": self.nb, "num_hosts": self.num_hosts,
                "owners": list(self.owners), "gens": list(self.gens),
                "version": self.version}

    @classmethod
    def from_json(cls, d: Dict[str, object]) -> "ShardMap":
        return cls(nb=int(d["nb"]), num_hosts=int(d["num_hosts"]),
                   owners=[int(x) for x in d["owners"]],
                   gens=[int(x) for x in d["gens"]],
                   version=int(d["version"]))

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "ShardMap":
        with open(path) as f:
            return cls.from_json(json.load(f))


def frame_version_ok(frame_mapv: Optional[int], min_version: int) -> bool:
    """Should a receiver accept a frame routed under map version
    ``frame_mapv``?  ``None`` means the sender predates map versioning
    (or versioning is off) — always accepted for compatibility; otherwise
    the frame must be at or past the receiver's ratcheted minimum."""
    if frame_mapv is None:
        return True
    return int(frame_mapv) >= int(min_version)


def plan_rebalance(smap: ShardMap, loads: Dict[int, float],
                   max_moves: int = 0) -> List[Tuple[int, int, int]]:
    """Deterministic greedy rebalance plan: ``[(bucket, src, dst), ...]``.

    ``loads`` maps bucket -> observed cost (bytes or rows from the
    IOLedger's per-bucket counters).  Repeatedly move the hottest bucket
    from the most-loaded host to the least-loaded host while that
    strictly improves the imbalance; a host with no recorded load (a
    late joiner) naturally attracts moves.  Ties break on lowest id, so
    the plan is a pure function of (map, loads) — a resumed rebalance
    replays the identical plan from the same snapshot.

    The plan is advisory: it never splits below one bucket per move and
    terminates because each accepted move strictly lowers the sum of
    squared host loads (``new_dst < old_src`` implies the exchanged load
    shrinks the spread).
    """
    nb, H = smap.nb, smap.num_hosts
    if H < 2:
        return []
    load = {b: float(v) for b, v in loads.items()
            if 0 <= int(b) < nb and float(v) > 0.0}
    owner = list(smap.owners)
    host_load = [0.0] * H
    for b, v in load.items():
        host_load[owner[int(b)]] += v
    cap = int(max_moves) if max_moves else nb
    moves: List[Tuple[int, int, int]] = []
    # Each bucket moves AT MOST once per plan: all of a plan's migrations
    # run in one barrier, and two moves of the same bucket would race.
    already = set()
    while len(moves) < cap:
        src = max(range(H), key=lambda h: (host_load[h], -h))
        dst = min(range(H), key=lambda h: (host_load[h], -h))
        # src ties break to the lowest id, dst ties to the highest id —
        # a freshly admitted (empty) host wins so late joiners fill first
        if src == dst or host_load[src] <= host_load[dst]:
            break
        moved = False
        for b in sorted((b for b in load
                         if owner[int(b)] == src and b not in already),
                        key=lambda b: (-load[b], b)):
            w = load[b]
            # strict improvement: after the move the destination must
            # still sit below the source's old level
            if host_load[dst] + w < host_load[src]:
                moves.append((int(b), src, dst))
                already.add(b)
                owner[int(b)] = dst
                host_load[src] -= w
                host_load[dst] += w
                moved = True
                break
        if not moved:
            break
    return moves


def apply_moves(smap: ShardMap,
                moves: Sequence[Tuple[int, int, int]]) -> None:
    """Apply a plan from :func:`plan_rebalance` to the map.  Each move's
    ``src`` must still be the current owner (the plan was computed under
    this exact map — a mismatch means a concurrent rewrite happened and
    the plan is void)."""
    for (b, src, dst) in moves:
        if smap.owner_of(b) != int(src):
            raise ShardMapError(
                f"stale plan: bucket {b} owned by {smap.owner_of(b)}, "
                f"plan expected {src}")
        smap.assign(b, dst)
