"""BlockStore — the storage layer of the disk tier (paper §III-A's external
edgelist ADT, generalized).

Every phase of the out-of-core pipeline (external.py) used to hand-roll its
own run management; this module centralizes the four primitives they all
reduce to, each with *bounded* main memory and ledger-accounted I/O:

  BlockStore           a directory of immutable, typed, multi-column runs
                       (append / stream / manifest / destroy).  A "run" is
                       one .npy file of shape [rows, ncols]; rows per run are
                       capped by the writer (cfg.chunk_edges), which is what
                       bounds memory everywhere downstream.
  sort_runs            pass 1 of external merge sort: sort each run in RAM
                       (<= chunk rows at a time), rewrite (paper Alg. 7 l.1-5).
  merge_runs           pass 2: streaming k-way merge over *block-buffered*
                       cursors — resident memory is fan-in x merge block,
                       never a whole store (the paper's bounded-buffer merge).
                       With max_fanin set, stores with more runs than the
                       fan-in budget cascade through log-depth intermediate
                       merge passes (STXXL-style multiway merge), so open
                       files and heap size are bounded at ANY store size.
  partition_runs       bounded-memory bucket partition: stream runs, stable
                       sort each chunk by destination bucket, append slices
                       to per-bucket stores (paper Alg. 8's "append to elp_d,
                       ship when full" — the bucket exchange used by both the
                       external shuffle and redistribute).

  PrefetchReader /     the asynchronous I/O layer (GraphConfig.io_overlap):
  WriteBehindWriter    the paper's dedicated-I/O-thread model.  All four
                       primitives above accept `overlap=True`, which
                       double-buffers reads (next block fetched on an I/O
                       thread while the current one is consumed) and
                       completes appends/Transport sends off-thread with at
                       most one chunk in flight — a pass then costs
                       ~max(read, compute, write) instead of their sum.
                       Timing-only by construction: merges are stable and
                       the single FIFO writer preserves append order, so
                       output bytes are identical with overlap on or off;
                       I/O-thread errors rethrow at the consuming call
                       site; residency at most DOUBLES (gauge-tracked).

IOLedger counts block-granular sequential vs random transfers (the paper's
cost unit, C_e edges per block) plus the overlap stall counters
read_wait_s / write_wait_s / overlap_s; MemoryGauge records the largest
buffer the disk tier ever materializes — including in-flight prefetch and
write-behind buffers — so tests can *assert* the bounded-memory claim
instead of trusting it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import heapq
import json
import os
import queue
import re
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from .trace import STALL_MIN_S, get_tracer

KeySpec = Union[int, Callable[..., np.ndarray]]


def clean_store(workdir: str, name: str) -> None:
    """Remove a store directory (pre-barrier cleanup of multi-writer
    exchange stores; see BlockStore `fresh`)."""
    shutil.rmtree(os.path.join(workdir, name), ignore_errors=True)


def stack_columns(cols: Sequence[np.ndarray], columns: Sequence[str],
                  dtype) -> np.ndarray:
    """THE place record columns become a run array — shared by
    BlockStore.append_run and the socket transport's frame encoder
    (transport._SocketChannel), so both exchange backends stack and coerce
    identically: any future change here changes both, preserving the
    bit-identity contract between them."""
    assert len(cols) == len(columns), (len(cols), columns)
    return np.stack([np.asarray(c, np.dtype(dtype)) for c in cols], axis=1)


def auto_run_tag(seq: int) -> str:
    """Default (single-writer) run naming, shared for the same reason."""
    return f"{seq:06d}"


# Flattened-counter key format: dict-valued ledger fields serialize as
# "field[index]" so every ledger ever becomes (and merges from) a flat
# {str: int} — reports, checkpoints, and BENCH json stay schema-free.
_COUNTER_KEY_RE = re.compile(r"^([a-z_]+)\[(\d+)\]$")


def split_counter_key(key: str) -> Tuple[str, Optional[int]]:
    """Parse a flattened ledger key: "bucket_bytes[3]" -> ("bucket_bytes", 3),
    plain "bytes_read" -> ("bytes_read", None)."""
    m = _COUNTER_KEY_RE.match(key)
    if m:
        return m.group(1), int(m.group(2))
    return key, None


@dataclasses.dataclass
class IOLedger:
    """Counts block-granular I/O, the paper's unit of cost (C_e edges/block)."""

    seq_reads: int = 0
    seq_writes: int = 0
    rand_reads: int = 0
    rand_writes: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    # Per-element hash evaluations (the recompute-shuffle cost unit: what the
    # communication-free relabel pays INSTEAD of exchange bytes — Funke et
    # al.'s trade, made visible next to the byte counters it displaces).
    hash_evals: int = 0
    # Rows appended to stores (writer-side: BlockStore.append_run;
    # receiver-side: the exchange server's durable frame writes).  The row
    # twin of bytes_written — the skew signal in row units.
    rows_written: int = 0
    # Per-bucket skew signal: bytes/rows attributable to a specific bucket,
    # from kernel attribution (phases._run_kernel) and receive-side store
    # naming (transport.ExchangeServer).  The rebalancer's load input and the
    # BENCH_*.json skew surface share these counters.
    bucket_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    bucket_rows: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Overlap stall counters (seconds), fed by the async I/O layer
    # (PrefetchReader / WriteBehindWriter): read_wait_s is consumer time
    # blocked on a prefetched block (the read side failed to hide behind
    # compute), write_wait_s is producer time blocked on the in-flight
    # write slot, and overlap_s is I/O-thread time that DID hide behind
    # compute — the measured win.  Serial paths leave all three at 0.
    read_wait_s: float = 0.0
    write_wait_s: float = 0.0
    overlap_s: float = 0.0

    # Counter updates arrive from the consuming thread AND the async I/O
    # threads concurrently (`+=` is not atomic), so every mutator below
    # takes a lock.  The lock is deliberately NOT a dataclass field:
    # as_dict()/fields() never see it, and pickling drops/rebuilds it.
    def __post_init__(self):
        self._lock = threading.Lock()

    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_lock", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def hashes(self, count: int):
        with self._lock:
            self.hash_evals += count

    def read(self, nbytes: int, sequential: bool = True):
        with self._lock:
            self.bytes_read += nbytes
            if sequential:
                self.seq_reads += 1
            else:
                self.rand_reads += 1

    def write(self, nbytes: int, sequential: bool = True):
        with self._lock:
            self.bytes_written += nbytes
            if sequential:
                self.seq_writes += 1
            else:
                self.rand_writes += 1

    def wrote_rows(self, rows: int) -> None:
        """Writer-side row accounting (append_run + the exchange server's
        durable frame writes) — locked because write-behind appends land on
        the I/O thread while the consumer charges reads."""
        with self._lock:
            self.rows_written += int(rows)

    def stall(self, read_wait_s: float = 0.0, write_wait_s: float = 0.0,
              overlap_s: float = 0.0) -> None:
        """Charge overlap stall/win time (seconds; see the field comments)."""
        with self._lock:
            self.read_wait_s += read_wait_s
            self.write_wait_s += write_wait_s
            self.overlap_s += overlap_s

    def bucket(self, bucket: int, nbytes: int, rows: int = 0) -> None:
        """Attribute I/O to a bucket (the per-bucket skew counters)."""
        b = int(bucket)
        with self._lock:
            if nbytes:
                self.bucket_bytes[b] = self.bucket_bytes.get(b, 0) + int(nbytes)
            if rows:
                self.bucket_rows[b] = self.bucket_rows.get(b, 0) + int(rows)

    def as_dict(self) -> Dict[str, float]:
        """Flat {str: number}: dict-valued fields flatten to "field[index]"
        keys (see split_counter_key), so snapshot/delta/merge/JSON all keep
        working on one flat namespace.  Integer counters stay ints; the
        stall counters are float seconds.  Taken under the lock so a
        snapshot read concurrently with I/O-thread charges is consistent."""
        out: Dict[str, float] = {}
        with self._lock:
            for f in dataclasses.fields(self):
                v = getattr(self, f.name)
                if isinstance(v, dict):
                    for idx in sorted(v):
                        out[f"{f.name}[{int(idx)}]"] = int(v[idx])
                else:
                    out[f.name] = v
        return out

    def merge(self, counters: Dict[str, int]) -> None:
        """Add a flat counter dict (another ledger's as_dict / a report's
        delta) into this ledger — the one sanctioned way to combine
        ledgers, replacing ad-hoc per-field setattr loops.  Unknown keys
        are ignored so old reports merge into newer ledgers.  Float-valued
        counters (the stall seconds) add exactly like the int ones."""
        with self._lock:
            for k, v in counters.items():
                name, idx = split_counter_key(k)
                if idx is not None:
                    d = getattr(self, name, None)
                    if isinstance(d, dict):
                        d[idx] = d.get(idx, 0) + int(v)
                elif hasattr(self, name) and not isinstance(getattr(self, name), dict):
                    setattr(self, name, getattr(self, name) + v)

    def snapshot(self) -> Dict[str, int]:
        return self.as_dict()

    def delta_since(self, snap: Dict[str, int]) -> Dict[str, int]:
        """Per-phase accounting: ledger counters accumulated since `snap`."""
        return {k: v - snap.get(k, 0) for k, v in self.as_dict().items()}


@dataclasses.dataclass
class MemoryGauge:
    """High-water mark of rows materialized in RAM by the disk tier.

    Every point where store code turns disk bytes into a resident ndarray
    reports its row count here; `peak_rows` is the largest single working set
    observed.  Tests cap `chunk_edges` far below n and assert
    peak_rows = O(chunk_edges) — the measurable form of the paper's "main
    memory usage is independent of graph size".

    `budget_rows` is the disk tier's row budget (the writer chunk bound,
    cfg.chunk_edges) where the driver knows it; 0 = unknown.  Merge cursors
    derive their refill block size from budget / fan-in (`cursor_rows`), so
    deep cascades cannot exceed the budget even when prefetch doubles
    residency — overlapped working sets stay <= 2x the serial chunk bound,
    never more.
    """

    peak_rows: int = 0
    budget_rows: int = 0

    # Overlap means the I/O thread and the consumer report buffers
    # concurrently; the max update is read-modify-write, so it is locked.
    # Like IOLedger's, the lock is not a field and never pickles.
    def __post_init__(self):
        self._lock = threading.Lock()

    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_lock", None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def track(self, rows: int) -> None:
        with self._lock:
            if rows > self.peak_rows:
                self.peak_rows = int(rows)

    def cursor_rows(self, fan: int, max_run: int, overlap: bool = False) -> int:
        """Refill block size for a fan-in-`fan` merge cursor: an even split
        of the largest run across the cursors, capped by budget_rows / fan
        so the TOTAL cursor residency never exceeds the budget — halved
        again under overlap, where each cursor holds its current block plus
        one prefetched block in flight.  Block size is timing-only: merges
        are stable, so any positive value yields identical output bytes."""
        brows = max(1, int(max_run) // max(1, int(fan)))
        if self.budget_rows > 0:
            cap = self.budget_rows // max(1, int(fan))
            if overlap:
                cap //= 2
            brows = min(brows, max(1, cap))
        return brows


class BlockStore:
    """A directory of immutable typed runs of column-oriented records.

    Append-only (the paper's edgelist ADT never deletes individual records);
    each run is one .npy of shape [rows, ncols].  Reading a run charges the
    ledger; writers bound run size, so every read is a bounded buffer.
    """

    def __init__(
        self,
        workdir: str,
        name: str,
        ledger: IOLedger,
        columns: Sequence[str] = ("src", "dst"),
        dtype=np.int64,
        gauge: Optional[MemoryGauge] = None,
        fresh: bool = False,
    ):
        # `fresh=True` wipes leftovers from a previous (crashed/invalidated)
        # run — required for single-writer stores because attach() recovers
        # runs from the filesystem and stale files would be indistinguishable
        # from real ones.  Multi-writer exchange stores must NOT use it (the
        # writers would wipe each other); their driver calls clean_store()
        # once before the barrier instead.
        self.dir = os.path.join(workdir, name)
        if fresh:
            shutil.rmtree(self.dir, ignore_errors=True)
        os.makedirs(self.dir, exist_ok=True)
        self.name = name
        self.columns = tuple(columns)
        self.dtype = np.dtype(dtype)
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()
        self._runs: List[str] = []
        self._rows: List[int] = []

    # -- write side ---------------------------------------------------------
    def append_run(self, *cols: np.ndarray, tag: Optional[str] = None) -> int:
        """Append one immutable run.  `tag` overrides the default sequence
        name — the multi-process mode uses `{sender}_{seq}` tags so that
        runs written concurrently by different workers never collide and
        `attach()` recovers them in sender order (lexicographic)."""
        arr = stack_columns(cols, self.columns, self.dtype)
        name = tag if tag is not None else auto_run_tag(len(self._runs))
        path = os.path.join(self.dir, f"run_{name}.npy")
        np.save(path, arr)
        self.ledger.write(arr.nbytes)
        self.ledger.wrote_rows(arr.shape[0])
        self.gauge.track(arr.shape[0])
        self._runs.append(path)
        self._rows.append(int(arr.shape[0]))
        return len(self._runs) - 1

    @classmethod
    def attach(
        cls,
        workdir: str,
        name: str,
        ledger: IOLedger,
        columns: Sequence[str] = ("src", "dst"),
        dtype=np.int64,
        gauge: Optional[MemoryGauge] = None,
    ) -> "BlockStore":
        """Open a store directory written by another process: run files are
        recovered in lexicographic (== append/tag) order.  The filesystem IS
        the manifest — this is the barrier-free handoff the partitioned mode
        uses between phases."""
        store = cls(workdir, name, ledger, columns=columns, dtype=dtype, gauge=gauge)
        names = sorted(f for f in os.listdir(store.dir) if f.startswith("run_") and f.endswith(".npy"))
        store._runs = [os.path.join(store.dir, f) for f in names]
        store._rows = [int(np.load(p, mmap_mode="r").shape[0]) for p in store._runs]
        return store

    # -- read side ------------------------------------------------------------
    @property
    def ncols(self) -> int:
        return len(self.columns)

    @property
    def num_runs(self) -> int:
        return len(self._runs)

    def run_rows(self, i: int) -> int:
        return self._rows[i]

    def total_rows(self) -> int:
        return sum(self._rows)

    def read_run(self, i: int, sequential: bool = True) -> Tuple[np.ndarray, ...]:
        """Load one WHOLE run resident (mmap_mode=None) — ledger-charged and
        gauge-tracked like any other materialization.  Only for consumers
        that genuinely need the full run at once (per-run stable sorts:
        sort_runs, partition_runs); block-sized consumers must stream
        through iter_blocks instead of paying a whole-run buffer."""
        arr = np.load(self._runs[i], mmap_mode=None)
        self.ledger.read(arr.nbytes, sequential)
        self.gauge.track(arr.shape[0])
        return tuple(arr[:, c] for c in range(arr.shape[1]))

    def open_run(self, i: int) -> np.ndarray:
        """Memmap a run WITHOUT charging the ledger — callers that stream
        blocks out of it charge per block (merge_runs)."""
        return np.load(self._runs[i], mmap_mode="r")

    def iter_runs(self) -> Iterator[Tuple[np.ndarray, ...]]:
        for i in range(self.num_runs):
            yield self.read_run(i)

    def iter_blocks(self, block_rows: int,
                    sequential: bool = True) -> Iterator[Tuple[np.ndarray, ...]]:
        """Stream the whole store in buffers of <= block_rows (run order).
        `sequential` classifies the reads in the ledger — a consumer that
        probes the stream non-contiguously (see MonotoneLookup) can account
        its loads honestly instead of defaulting everything to sequential."""
        for i in range(self.num_runs):
            mm = self.open_run(i)
            for lo in range(0, mm.shape[0], block_rows):
                blk = np.asarray(mm[lo : lo + block_rows])
                self.ledger.read(blk.nbytes, sequential)
                self.gauge.track(blk.shape[0])
                yield tuple(blk[:, c] for c in range(blk.shape[1]))

    def missing_runs(self) -> List[str]:
        """Run files this store's manifest names but the filesystem lacks —
        nonempty after checkpoint GC reclaimed them (drivers check this
        before rerunning a non-checkpointable phase against old outputs)."""
        return [p for p in self._runs if not os.path.exists(p)]

    # -- lifecycle --------------------------------------------------------------
    def destroy(self):
        shutil.rmtree(self.dir, ignore_errors=True)
        self._runs, self._rows = [], []

    # -- checkpoint manifests ----------------------------------------------------
    def manifest(self) -> Dict:
        """Workdir-relative description of this store (no absolute paths, so
        a checkpointed workdir can be moved/re-mounted and still resume)."""
        return {
            "name": self.name,
            "columns": list(self.columns),
            "dtype": self.dtype.str,
            "runs": [os.path.basename(p) for p in self._runs],
            "rows": list(self._rows),
        }

    @classmethod
    def from_manifest(
        cls, m: Dict, workdir: str, ledger: IOLedger,
        gauge: Optional[MemoryGauge] = None,
    ) -> "BlockStore":
        store = cls.__new__(cls)
        BlockStore.__init__(
            store,
            workdir,
            m["name"],
            ledger,
            columns=m["columns"],
            dtype=np.dtype(m["dtype"]),
            gauge=gauge,
        )
        store._runs = [os.path.join(store.dir, r) for r in m["runs"]]
        store._rows = list(m["rows"])
        return store

    def save_manifest(self, path: str):
        with open(path, "w") as f:
            json.dump(self.manifest(), f)


def _keys_of(key: KeySpec, cols: Tuple[np.ndarray, ...]) -> np.ndarray:
    """Resolve a key spec: column index, or callable over the columns.

    Callable keys are *recomputed* from record values instead of stored —
    that is what lets the external shuffle sort by hash(value, salt) without
    a key column on disk (the paper's counter-based determinism, applied to
    the sort key itself)."""
    if callable(key):
        return np.asarray(key(*cols))
    return np.asarray(cols[key])


def sort_runs(store: BlockStore, out: BlockStore, key: KeySpec = 0,
              overlap: bool = False) -> BlockStore:
    """External-sort pass 1: each run sorted in RAM by `key`, rewritten.

    Runs are writer-bounded (<= chunk rows), so resident memory is one run
    — with `overlap`, run i+1 is prefetched and run i-1's sorted output
    written behind while run i sorts, so resident memory is <= 2 runs and
    wall time tends to max(read, sort, write) instead of their sum.  Output
    is byte-identical either way: the single FIFO writer preserves append
    order, and sorting is per-run."""
    row_bytes = store.ncols * store.dtype.itemsize
    prefetch = overlap and store.num_runs > 0 and (
        max(store.run_rows(i) for i in range(store.num_runs)) * row_bytes
        >= _ASYNC_IO_MIN_BYTES)
    runs: Iterator[Tuple[np.ndarray, ...]] = store.iter_runs()
    if prefetch:
        runs = PrefetchReader(runs, ledger=store.ledger)
    tracer = get_tracer()
    t_wall, p0 = time.time(), time.perf_counter()
    try:
        with write_behind([out], store.ledger, store.gauge,
                          enabled=overlap) as sinks:
            for cols in runs:
                if prefetch:
                    store.gauge.track(2 * cols[0].shape[0])
                order = np.argsort(_keys_of(key, cols), kind="stable")
                sinks[0].append_run(*(c[order] for c in cols))
    finally:
        if isinstance(runs, PrefetchReader):
            runs.close()
        if tracer.enabled:
            tracer.event(f"sort:{store.name}", "io", t_wall,
                         time.perf_counter() - p0,
                         args={"runs": store.num_runs})
    return out


# ---------------------------------------------------------------------------
# Asynchronous I/O layer (io_overlap): double-buffered prefetch + write-behind
# ---------------------------------------------------------------------------

_DONE = object()  # PrefetchReader's end-of-stream sentinel


class PrefetchReader:
    """Double-buffered background reader — the paper's dedicated I/O thread
    (read half): disk transfers overlap compute instead of alternating with
    it, so a pass costs max(read, compute) instead of read + compute.

    Wraps any block iterator so the NEXT item is produced on an I/O thread
    while the consumer works on the current one.  Exactly ONE item is ever
    in flight (the consumer's current block + one prefetched block = the
    depth-2 double buffer), so resident memory is at most 2x the serial
    bound, never more — callers report the doubled aggregate to their gauge.

    Stall accounting (`ledger`): consumer time blocked on the pending item
    is charged to `read_wait_s`; producer time hidden behind compute to
    `overlap_s`.  Exceptions raised by the wrapped iterator ON THE I/O
    THREAD are captured by the future and rethrown HERE, at the consuming
    call site (`__next__`), so error propagation, checkpoint/resume and
    mid-phase-kill semantics are identical to the serial path.

    `executor` shares one single-worker executor across several readers —
    a k-way merge's cursors all refill through ONE I/O thread (the paper's
    one-I/O-thread-per-node model), each keeping one outstanding refill.
    Without it the reader owns a private single-worker executor.  Exhaust
    the iterator or call close(); abandoning a reader mid-stream without
    close() leaks its in-flight future until the executor drains it.
    """

    def __init__(self, it: Iterator, ledger: Optional[IOLedger] = None,
                 executor: Optional[ThreadPoolExecutor] = None):
        self._it = iter(it)
        self._ledger = ledger
        self._own = executor is None
        self._ex = executor if executor is not None else ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="io_prefetch")
        self._fut = self._ex.submit(self._pull)

    def _pull(self):
        t0 = time.perf_counter()
        item = next(self._it, _DONE)
        return item, time.perf_counter() - t0

    def __iter__(self):
        return self

    def __next__(self):
        if self._fut is None:
            raise StopIteration
        t0 = time.perf_counter()
        item, produce_s = self._fut.result()  # I/O-thread errors rethrow here
        wait_s = time.perf_counter() - t0
        if self._ledger is not None:
            self._ledger.stall(read_wait_s=wait_s,
                               overlap_s=max(0.0, produce_s - wait_s))
        if wait_s > STALL_MIN_S:
            # A span only for stalls worth seeing on a timeline; sub-ms
            # waits stay counter-only (the ledger above never misses them).
            tracer = get_tracer()
            if tracer.enabled:
                tracer.event("read_stall", "stall", time.time() - wait_s,
                             wait_s)
        if item is _DONE:
            self._fut = None
            if self._own:
                self._ex.shutdown(wait=False)
            raise StopIteration
        self._fut = self._ex.submit(self._pull)
        return item

    def close(self) -> None:
        """Stop prefetching (early-exit consumers): cancel or drain the
        in-flight pull, swallowing its result/error — the stream is being
        abandoned, there is no consuming call site left to rethrow at."""
        fut, self._fut = self._fut, None
        if fut is not None and not fut.cancel():
            try:
                fut.result()
            except BaseException:
                pass
        if self._own:
            self._ex.shutdown(wait=True)


class WriteBehindWriter:
    """Write-behind sink multiplexer — the paper's dedicated I/O thread
    (write half): `append_run` emission and Transport channel sends complete
    off-thread with AT MOST ONE chunk in flight, so emitters pay
    max(compute, write) per chunk instead of compute + write.

    Wraps an ordered list of run sinks (BlockStores, or Transport channels —
    anything with BlockStore's `append_run(*cols, tag=)` signature); `sink(d)`
    returns a proxy whose `append_run` enqueues (d, cols, tag) on a bounded
    queue (maxsize=1) drained by ONE writer thread.  A single FIFO queue and
    a single thread preserve the exact serial append order across ALL sinks
    — and therefore run tags and receivers' lexicographic recovery order —
    which is why write-behind can never change result bytes.  In-flight
    residency is <= 1 queued + 1 being-written chunk; the doubled aggregate
    is reported to `gauge` per enqueue.  Enqueued column arrays must not be
    mutated afterwards (every call site emits fresh arrays).

    Producer time blocked on the full queue is charged to `write_wait_s`;
    writer-thread time hidden behind compute to `overlap_s` (on close).
    Errors raised by a sink ON THE WRITER THREAD are captured and rethrown
    at the producer's next append_run/flush/close — the consuming call
    site — and once one append fails no later chunk is written (fail-stop,
    so a checkpointed phase can never be marked complete past a lost write).
    Call flush()/close() (or use the context manager / `write_behind`)
    before relying on the sinks' contents.
    """

    def __init__(self, sinks: Sequence, ledger: Optional[IOLedger] = None,
                 gauge: Optional[MemoryGauge] = None):
        self._sinks = list(sinks)
        self._ledger = ledger
        self._gauge = gauge
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._write_s = 0.0   # writer-thread time (accumulated there)
        self._wait_s = 0.0    # producer time blocked on the queue
        self._thread: Optional[threading.Thread] = threading.Thread(
            target=self._drain, name="io_writebehind", daemon=True)
        self._thread.start()

    def _drain(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                d, cols, tag = item
                if self._err is None:
                    t0 = time.perf_counter()
                    try:
                        self._sinks[d].append_run(*cols, tag=tag)
                    except BaseException as e:  # rethrown at the producer
                        self._err = e
                    self._write_s += time.perf_counter() - t0
            finally:
                self._q.task_done()

    def sink(self, d: int) -> "_WriteBehindSink":
        """The async proxy for `sinks[d]` (same append_run signature)."""
        return _WriteBehindSink(self, d)

    def _put(self, d: int, cols: Tuple[np.ndarray, ...],
             tag: Optional[str]) -> None:
        if self._err is not None:
            self.abort()
            raise self._err
        if sum(int(np.asarray(c).nbytes) for c in cols) < _ASYNC_IO_MIN_BYTES:
            # Tiny chunk: the queue wake + GIL ping-pong costs more than
            # the write itself.  Drain anything in flight first (FIFO
            # order, hence bit-identity, is preserved), then append inline
            # on the producer — errors surface here, the consuming site.
            self._q.join()
            if self._err is not None:
                self.abort()
                raise self._err
            self._sinks[d].append_run(*cols, tag=tag)
            return
        if self._gauge is not None and cols:
            # current chunk + one in flight: the <= 2x residency bound.
            self._gauge.track(2 * int(np.asarray(cols[0]).shape[0]))
        t0 = time.perf_counter()
        self._q.put((d, cols, tag))
        self._wait_s += time.perf_counter() - t0

    def flush(self) -> None:
        """Barrier: every enqueued chunk is durably appended on return;
        rethrows any writer-thread error at this (consuming) call site."""
        self._q.join()
        if self._err is not None:
            self.abort()
            raise self._err

    def close(self) -> None:
        """flush() + stop the writer thread + charge the stall counters."""
        if self._thread is None:
            return
        self._q.put(None)
        self._thread.join()
        self._thread = None
        if self._ledger is not None:
            self._ledger.stall(write_wait_s=self._wait_s,
                               overlap_s=max(0.0, self._write_s - self._wait_s))
        if self._wait_s > STALL_MIN_S:
            tracer = get_tracer()
            if tracer.enabled:
                # One aggregate span per writer lifetime (per-put spans
                # would swamp the buffer); anchored so it ENDS at close.
                tracer.event("write_stall", "stall",
                             time.time() - self._wait_s, self._wait_s)
        if self._err is not None:
            raise self._err

    def abort(self) -> None:
        """Stop the writer WITHOUT rethrowing (error-path teardown: the
        producer is already unwinding and must not be masked)."""
        if self._thread is None:
            return
        self._q.put(None)
        self._thread.join()
        self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        else:
            self.abort()


class _WriteBehindSink:
    """Per-destination proxy view: the same `append_run(*cols, tag=)` shape
    call sites already use, so partition/merge emit loops are overlap-
    agnostic.  Returns None (run indices are writer-thread state; no current
    emitter consumes append_run's return value)."""

    __slots__ = ("_w", "_d")

    def __init__(self, w: WriteBehindWriter, d: int):
        self._w, self._d = w, d

    def append_run(self, *cols: np.ndarray, tag: Optional[str] = None) -> None:
        self._w._put(self._d, cols, tag)


@contextlib.contextmanager
def write_behind(sinks: Sequence, ledger: Optional[IOLedger],
                 gauge: Optional[MemoryGauge], enabled: bool = True):
    """Scoped write-behind over `sinks`: yields proxy sinks (or the
    originals when disabled — one code path for overlap on/off), and on
    clean exit flushes the writer, rethrowing any I/O-thread error inside
    the caller's scope.  On an exception the writer is torn down without
    masking the original error."""
    if not enabled:
        yield list(sinks)
        return
    wb = WriteBehindWriter(sinks, ledger=ledger, gauge=gauge)
    try:
        yield [wb.sink(d) for d in range(len(sinks))]
    except BaseException:
        wb.abort()
        raise
    else:
        wb.close()


# Routing one buffer through an I/O thread costs tens of µs of
# queue/future handoff plus GIL ping-pong with the consumer.  Async I/O
# only pays once a buffer's transfer time dwarfs that, so transfers below
# this byte floor (fine-grained budgets, huge fan-ins, toy scales) run
# synchronously even under io_overlap: merge cursors refill inline
# (_cursor_plan), sort_runs skips run prefetch, and WriteBehindWriter
# appends tiny chunks on the producer (after draining anything in flight,
# so FIFO order — and therefore bit-identity — is preserved).  Timing-only
# either way; output bytes never depend on the floor, and the
# halved-budget block size only applies when a second block is actually
# in flight.
_ASYNC_IO_MIN_BYTES = 1 << 16


def _cursor_plan(gauge: MemoryGauge, fan: int, max_run: int, row_bytes: int,
                 block_rows: int, overlap: bool) -> Tuple[int, bool]:
    """(refill block rows, prefetch on?) for one merge's cursors.  Explicit
    block_rows is respected unchanged; otherwise the gauge budget splits
    across the fan-in (MemoryGauge.cursor_rows), halved only when prefetch
    actually engages — which it does only above _PREFETCH_MIN_BYTES."""
    brows = (block_rows if block_rows > 0
             else gauge.cursor_rows(fan, max_run, overlap=False))
    prefetch = overlap and brows * row_bytes >= _ASYNC_IO_MIN_BYTES
    if prefetch and block_rows <= 0:
        brows = gauge.cursor_rows(fan, max_run, overlap=True)
    return brows, prefetch


@contextlib.contextmanager
def _merge_io(overlap: bool):
    """The shared I/O thread of ONE merge (None when overlap is off): a
    single-worker executor serves every cursor's refills — the paper's
    dedicated-I/O-thread-per-node model — with one outstanding prefetch per
    cursor, so in-flight blocks never exceed one extra block per cursor
    (the <= 2x residency bound the gauge records)."""
    if not overlap:
        yield None
        return
    ex = ThreadPoolExecutor(max_workers=1, thread_name_prefix="io_merge")
    try:
        yield ex
    finally:
        ex.shutdown(wait=True, cancel_futures=True)


def _segment_blocks(store: BlockStore, runs: Sequence[int],
                    block_rows: int) -> Iterator[np.ndarray]:
    """Raw block producer of one sorted segment: run files streamed back to
    back in <= block_rows slices.  At most ONE memmap is held open at a time
    (the previous run's reference is dropped as soon as it drains — the
    open-file bound of the bounded-fan-in merge).  Ledger charges happen
    here, i.e. on the I/O thread when prefetched (IOLedger is locked)."""
    for ri in runs:
        mm = store.open_run(ri)
        for off in range(0, mm.shape[0], block_rows):
            blk = np.asarray(mm[off : off + block_rows])
            store.ledger.read(blk.nbytes)
            yield blk
        mm = None


class _MergeCursor:
    """Block-buffered read cursor over one sorted *segment*: an ordered list
    of run files of a single store that together form one globally sorted
    sequence — a plain run, or a cascade intermediate store's runs back to
    back (merge_runs helper).  Refills come from _segment_blocks, optionally
    prefetched on the merge's shared I/O thread (`prefetch`, io_overlap):
    the NEXT block reads from disk while the heap drains the current one.
    """

    __slots__ = ("store", "key", "block_rows", "runs", "_blocks",
                 "block_keys", "block_cols", "_rel", "_done")

    def __init__(self, store: BlockStore, runs: Sequence[int], key: KeySpec,
                 block_rows: int,
                 prefetch: Optional[ThreadPoolExecutor] = None):
        self.store = store
        self.key = key
        self.block_rows = max(1, int(block_rows))
        self.runs = [r for r in runs if store.run_rows(r) > 0]
        blocks = _segment_blocks(store, self.runs, self.block_rows)
        self._blocks: Iterator[np.ndarray] = (
            blocks if prefetch is None
            else PrefetchReader(blocks, ledger=store.ledger, executor=prefetch))
        self.block_keys: Optional[np.ndarray] = None
        self.block_cols: Optional[Tuple[np.ndarray, ...]] = None
        self._rel = 0
        self._done = False
        self._advance()

    def _advance(self):
        """Consume the next block (keys are computed HERE, on the consumer
        thread — the I/O thread only moves bytes).  A prefetch-thread read
        error rethrows out of next(), i.e. at this consuming call site."""
        blk = next(self._blocks, None)
        if blk is None:
            self._done = True
            self.block_keys = self.block_cols = None
            return
        self.block_cols = tuple(blk[:, c] for c in range(blk.shape[1]))
        self.block_keys = _keys_of(self.key, self.block_cols)
        self._rel = 0
        return

    def head_key(self) -> int:
        if self._rel >= self.block_keys.shape[0]:
            self._advance()
        # Python int: unbounded, so uint64 hash keys >= 2^63 survive the heap.
        return int(self.block_keys[self._rel])

    def take_below(self, bound: Optional[int],
                   inclusive: bool) -> Optional[Tuple[np.ndarray, ...]]:
        """Pop the maximal prefix of the current block with key <= bound
        (inclusive=True) or key < bound (False — this cursor ranks AFTER the
        bound's cursor, so keys equal to the bound are not yet its turn: the
        strict-stability rule that makes equal-key order independent of merge
        topology).  `bound=None` means "no bound at all" (the final-drain
        sentinel — a max-int bound would under-drain key dtypes with values
        above it, e.g. callable uint64 hash keys >= 2^63).  Returns None
        when the block head already reaches bound."""
        if self._done:
            return None
        if self._rel >= self.block_keys.shape[0]:
            self._advance()
            if self._done:
                return None
        if bound is None:
            end = self.block_keys.shape[0]
        else:
            end = int(np.searchsorted(self.block_keys[self._rel :], bound,
                                      side="right" if inclusive else "left")
                      ) + self._rel
        if end == self._rel:
            return None
        out = tuple(c[self._rel : end] for c in self.block_cols)
        self._rel = end
        return out

    @property
    def exhausted(self) -> bool:
        if self._done:
            return True
        if self._rel < self.block_keys.shape[0]:
            return False
        self._advance()
        return self._done


def _merge_cursors(cursors: List[_MergeCursor], ncols: int,
                   flush_rows: int) -> Iterator[Tuple[np.ndarray, ...]]:
    """STABLE heap merge of sorted segment cursors, ~flush_rows blocks out.

    The winning cursor drains up to the next heap head (key, index) in
    LEXICOGRAPHIC order — keys equal to the bound belong to this cursor only
    if its index ranks first — so equal keys are emitted strictly in cursor
    order.  That stability is what makes the cascaded merge bit-identical to
    the flat one: equal-key order depends only on run order, never on merge
    topology or block sizes.  With an empty heap the bound is None (no
    bound), NOT a max int — see take_below.  Output is flushed inside the
    drain loop so even a final cursor spanning a huge cascade segment never
    accumulates more than ~flush_rows resident rows.
    """
    heap = [(c.head_key(), i) for i, c in enumerate(cursors) if not c.exhausted]
    heapq.heapify(heap)
    out_parts: List[Tuple[np.ndarray, ...]] = []
    out_rows = 0
    while heap:
        _, ci = heapq.heappop(heap)
        cur = cursors[ci]
        bound, inclusive = (heap[0][0], ci < heap[0][1]) if heap else (None, True)
        while True:
            part = cur.take_below(bound, inclusive)
            if part is None:
                break
            out_parts.append(part)
            out_rows += part[0].shape[0]
            if out_rows >= flush_rows:
                yield tuple(np.concatenate([p[c] for p in out_parts])
                            for c in range(ncols))
                out_parts, out_rows = [], 0
            if cur.exhausted:
                break
        if not cur.exhausted:
            heapq.heappush(heap, (cur.head_key(), ci))
    if out_parts:
        yield tuple(np.concatenate([p[c] for p in out_parts]) for c in range(ncols))


def merge_segments(
    segments: Sequence[Tuple[BlockStore, Sequence[int]]], key: KeySpec = 0,
    block_rows: int = 0, overlap: bool = False,
) -> Iterator[Tuple[np.ndarray, ...]]:
    """STABLE streaming merge over pre-built sorted segments.

    A segment is (store, ordered run indices) whose runs form ONE globally
    sorted sequence back to back — a single plain run, or a whole cascade
    intermediate store.  This is merge_runs' inner merge exposed for callers
    that build the segment list themselves: the pooled cascade
    (phases.cascade_merge_bucket) runs each *group* of segments as its own
    pool task, so intermediate merge levels parallelize across workers/hosts
    instead of running serially inside one consumer kernel.  Equal keys
    drain in segment order (see _merge_cursors), so any consecutive grouping
    of segments is bit-identical to the flat merge — the same stability
    contract merge_runs' inline cascade relies on.

    `overlap` refills every cursor through ONE shared I/O thread
    (_merge_io + PrefetchReader) while the heap drains current blocks —
    timing-only, bit-identical output, <= 2x cursor-buffer residency
    (recorded in the gauge; block sizes shrink under a gauge budget so the
    doubled set still fits — MemoryGauge.cursor_rows).
    """
    segs = [(s, [r for r in runs if s.run_rows(r) > 0]) for s, runs in segments]
    segs = [(s, runs) for s, runs in segs if runs]
    if not segs:
        return
    max_run = max(s.run_rows(r) for s, runs in segs for r in runs)
    flush_rows = max(block_rows, max_run)
    fan = len(segs)
    lead = segs[0][0]
    brows, prefetch = _cursor_plan(
        lead.gauge, fan, max_run, lead.ncols * lead.dtype.itemsize,
        block_rows, overlap)
    lead.gauge.track(brows * fan * (2 if prefetch else 1))
    tracer = get_tracer()
    t_wall, p0 = time.time(), time.perf_counter()
    try:
        with _merge_io(prefetch) as ex:
            cursors = [_MergeCursor(s, runs, key, brows, prefetch=ex)
                       for s, runs in segs]
            yield from _merge_cursors(cursors, lead.ncols, flush_rows)
    finally:
        # Generator span: covers first next() to close — what the consumer
        # actually spent inside this merge.  "io" is a leaf category (not
        # under the nesting law): interleaved generators close out of LIFO.
        if tracer.enabled:
            tracer.event(f"merge_seg:{lead.name}", "io", t_wall,
                         time.perf_counter() - p0, args={"segments": fan})


CASCADE_MARKER = "__cas_l"  # substring naming cascade intermediate store dirs


def clean_cascade_stores(workdir: str) -> None:
    """Remove leftover cascade intermediate stores from a crashed merge.
    merge_runs wipes (fresh=True) and destroys its own intermediates; ones
    that survive a crash are dead weight that must never be mistaken for
    phase outputs, so resume paths (PhaseOrchestrator) sweep them first."""
    if not os.path.isdir(workdir):
        return
    for d in os.listdir(workdir):
        if CASCADE_MARKER in d and os.path.isdir(os.path.join(workdir, d)):
            shutil.rmtree(os.path.join(workdir, d), ignore_errors=True)


def merge_runs(
    store: BlockStore, key: KeySpec = 0, block_rows: int = 0,
    max_fanin: int = 0, overlap: bool = False,
) -> Iterator[Tuple[np.ndarray, ...]]:
    """External-sort pass 2: streaming k-way merge of sorted runs, with a
    bounded-fan-in cascade (the STXXL-style log-depth multiway merge).

    Flat path (num_runs <= max_fanin, or max_fanin=0): resident memory is
    fan-in x block_rows rows (cursor buffers) + one output block — never the
    whole store.  block_rows defaults to an even split of the largest run
    across the cursors, so total buffer memory stays ~one run at any fan-in.

    Cascade path (max_fanin >= 2 and num_runs > max_fanin): groups of
    <= max_fanin segments are merged into intermediate stores (sibling dirs
    named `{store.name}__cas_l{level}_g{group}`, ledger- and gauge-accounted
    like any other store), recursing until <= max_fanin segments remain for
    one final streaming merge.  Open run files and heap size are then
    bounded by max_fanin REGARDLESS of store size — per-cursor blocks stay
    max_run/max_fanin instead of shrinking to max_run/num_runs — at the cost
    of O(log_max_fanin(num_runs)) extra sequential read+write passes over
    the data.  A consumed cascade level is destroyed as soon as the next
    level is built (and on generator close), so scratch disk is bounded by
    ~2x the store; output is bit-identical to the flat merge because the
    merge is STABLE (equal keys emit in run order — see _merge_cursors) and
    groups are consecutive runs, so cascading never reorders anything.

    `overlap` runs every level's cursor refills on a shared I/O thread and
    the intermediate stores' appends through a write-behind thread (see
    merge_segments / WriteBehindWriter): each cascade pass costs
    ~max(read, merge, write) instead of their sum.  Timing-only — the
    single FIFO writer preserves run order and the merge is stable, so
    output is bit-identical to the serial path at every fan-in.

    Yields tuples of column arrays in globally sorted order; merge_runs over
    sort_runs output is therefore a stable external sort of the store.
    """
    if max_fanin == 1 or max_fanin < 0:
        raise ValueError(f"max_fanin must be 0 (flat) or >= 2, got {max_fanin}")
    nruns = store.num_runs
    if nruns == 0:
        return
    max_run = max(store.run_rows(i) for i in range(nruns))
    flush_rows = max(block_rows, max_run)
    workdir = os.path.dirname(store.dir)
    # A segment = (store, ordered run indices) forming one sorted sequence.
    segments: List[Tuple[BlockStore, List[int]]] = [
        (store, [i]) for i in range(nruns)]
    scratch: List[BlockStore] = []

    row_bytes = store.ncols * store.dtype.itemsize

    def cursors_of(segs, ex):
        fan = len(segs)
        brows, pf = _cursor_plan(store.gauge, fan, max_run, row_bytes,
                                 block_rows, overlap)
        store.gauge.track(brows * fan * (2 if pf else 1))
        return [_MergeCursor(s, runs, key, brows, prefetch=ex if pf else None)
                for s, runs in segs]

    tracer = get_tracer()
    t_wall, p0 = time.time(), time.perf_counter()
    try:
        level = 0
        while max_fanin >= 2 and len(segments) > max_fanin:
            nxt: List[Tuple[BlockStore, List[int]]] = []
            for g, lo in enumerate(range(0, len(segments), max_fanin)):
                grp = segments[lo : lo + max_fanin]
                out = BlockStore(
                    workdir, f"{store.name}{CASCADE_MARKER}{level}_g{g:04d}",
                    store.ledger, columns=store.columns, dtype=store.dtype,
                    gauge=store.gauge, fresh=True)
                scratch.append(out)
                with _merge_io(overlap) as ex, \
                        write_behind([out], store.ledger, store.gauge,
                                     enabled=overlap) as sinks:
                    for cols in _merge_cursors(cursors_of(grp, ex),
                                               store.ncols, flush_rows):
                        sinks[0].append_run(*cols)
                # This group's input segments are consumed; reclaim the ones
                # that are cascade intermediates (never the caller's store).
                for s, _ in grp:
                    if s is not store:
                        s.destroy()
                nxt.append((out, list(range(out.num_runs))))
            segments = nxt
            level += 1
        with _merge_io(overlap) as ex:
            yield from _merge_cursors(cursors_of(segments, ex), store.ncols,
                                      flush_rows)
    finally:
        for s in scratch:
            s.destroy()
        if tracer.enabled:
            tracer.event(f"merge:{store.name}", "io", t_wall,
                         time.perf_counter() - p0,
                         args={"runs": nruns, "levels": level})


def partition_runs(
    store: BlockStore,
    outs: Sequence,
    part_of: Callable[..., np.ndarray],
    tag_prefix: Optional[str] = None,
    transform: Optional[Callable[..., Tuple[np.ndarray, ...]]] = None,
    overlap: bool = False,
) -> Sequence:
    """Bounded-memory bucket partition (paper Alg. 8's bucket exchange).

    Streams `store` one run at a time; each run is stable-sorted by its
    destination bucket and the per-bucket slices appended to `outs[d]` —
    all access sequential, resident memory one run.  `outs` are run sinks
    with BlockStore's `append_run(*cols, tag=)` signature: destination
    stores on a shared filesystem, or transport channels
    (core/transport.py) that frame each emitted run to the destination
    bucket's host — the emit path is transport-agnostic.  `tag_prefix`
    names the written runs `{tag_prefix}_{seq}` so concurrent senders into
    a shared destination inbox never collide (multi-process mode), and so
    receivers recover sender order lexicographically on either backend.
    `transform` rewrites each run's columns before partitioning (same
    column count; `part_of` sees the TRANSFORMED values) — the inline-map
    hook of the recompute relabel: u -> perm(u) applied during the very
    scan that ships each edge to owner(perm(src)).

    `overlap` prefetches the next input run on an I/O thread while the
    current one is transformed/sorted/sliced, and completes every
    append_run — including Transport channel SENDS — through one
    write-behind thread with at most one chunk in flight.  The single FIFO
    writer preserves the exact serial append order across all destinations
    (and therefore the `{tag_prefix}_{seq}` tags), so the exchange bytes
    are bit-identical to the serial path; residency is <= 2 runs in flight
    (tracked in the gauge).
    """
    nparts = len(outs)
    seq = [0] * nparts
    runs: Iterator[Tuple[np.ndarray, ...]] = store.iter_runs()
    if overlap:
        runs = PrefetchReader(runs, ledger=store.ledger)
    tracer = get_tracer()
    t_wall, p0 = time.time(), time.perf_counter()
    try:
        with write_behind(outs, store.ledger, store.gauge,
                          enabled=overlap) as sinks:
            for cols in runs:
                if overlap:
                    store.gauge.track(2 * cols[0].shape[0])
                if transform is not None:
                    cols = tuple(transform(*cols))
                dest = np.asarray(part_of(*cols))
                if dest.size and (int(dest.min()) < 0 or int(dest.max()) >= nparts):
                    bad = dest[(dest < 0) | (dest >= nparts)]
                    raise ValueError(
                        f"partition_runs: part_of produced bucket {int(bad[0])} outside "
                        f"[0, {nparts}) for {bad.size} record(s) of store "
                        f"'{store.name}' — a bad owner function would silently "
                        "shrink the record stream")
                order = np.argsort(dest, kind="stable")
                cols = tuple(c[order] for c in cols)
                dest = dest[order]
                starts = np.searchsorted(dest, np.arange(nparts))
                ends = np.searchsorted(dest, np.arange(nparts), side="right")
                for d in range(nparts):
                    if ends[d] > starts[d]:
                        tag = None if tag_prefix is None else f"{tag_prefix}_{seq[d]:05d}"
                        sinks[d].append_run(*(c[starts[d] : ends[d]] for c in cols),
                                            tag=tag)
                        seq[d] += 1
    finally:
        if isinstance(runs, PrefetchReader):
            runs.close()
        if tracer.enabled:
            tracer.event(f"partition:{store.name}", "io", t_wall,
                         time.perf_counter() - p0, args={"parts": nparts})
    return outs


class NpyColumnStore:
    """Read-only, single-column BlockStore look-alike over one flat .npy
    vector (e.g. a bucket's CSR offv file), streamed in ledger-charged,
    gauge-tracked blocks.

    Exists so MonotoneLookup can sort-merge-join against plain array files
    with the SAME I/O accounting as real stores — before this adapter, flat
    .npy tables could only be memmapped directly, and those block loads never
    landed in the IOLedger (breaking the Fig.-2-style sequential-vs-random
    bookkeeping for any phase that joined against them).
    """

    def __init__(self, path: str, ledger: IOLedger,
                 gauge: Optional[MemoryGauge] = None):
        self.path = path
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()

    def iter_blocks(self, block_rows: int,
                    sequential: bool = True) -> Iterator[Tuple[np.ndarray]]:
        mm = np.load(self.path, mmap_mode="r")
        for lo in range(0, mm.shape[0], block_rows):
            blk = np.asarray(mm[lo : lo + block_rows], np.int64)
            self.ledger.read(blk.nbytes, sequential)
            self.gauge.track(blk.shape[0])
            yield (blk,)


class MonotoneLookup:
    """Streaming table lookup for sort-merge-joins: `lookup(keys)` returns
    table[keys - base] for a globally NONDECREASING key stream, reading the
    table (a sequence of single-column stores laid out back to back) strictly
    forward, one bounded block at a time.

    This is the paper's Alg. 6-7 join half: both the probe stream (sorted
    edges) and the build stream (pv blocks) advance monotonically, so the
    join is two synchronized sequential scans — no random I/O, resident
    memory one block.  Block loads are charged to the stores' ledger through
    iter_blocks; the output buffer of every `lookup` call is reported to
    `gauge` so the join's working set is auditable too.
    """

    def __init__(self, stores: Sequence[BlockStore], block_rows: int, base: int = 0,
                 gauge: Optional[MemoryGauge] = None):
        def blocks():
            for s in stores:
                for (vals,) in s.iter_blocks(block_rows):
                    yield vals

        self._blocks = blocks()
        self._g0 = base
        self._vals = np.zeros(0, np.int64)
        self._gauge = gauge

    def lookup(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys)
        if keys.size and np.any(keys[1:] < keys[:-1]):
            i = int(np.argmax(keys[1:] < keys[:-1]))
            raise ValueError(
                f"MonotoneLookup probe stream regressed within a call: "
                f"keys[{i + 1}]={int(keys[i + 1])} < keys[{i}]={int(keys[i])}")
        out = np.empty(keys.shape[0], np.int64)
        if self._gauge is not None:
            self._gauge.track(out.shape[0])
        i = 0
        while i < keys.shape[0]:
            if keys[i] < self._g0:
                # A regressed probe would index _vals with a NEGATIVE offset,
                # wrapping to the wrong table entry instead of erroring.
                raise ValueError(
                    f"MonotoneLookup probe stream regressed: key "
                    f"{int(keys[i])} is below the already-consumed table "
                    f"prefix ending at {self._g0}")
            g1 = self._g0 + self._vals.shape[0]
            if keys[i] >= g1:
                try:
                    nxt = next(self._blocks)
                except StopIteration:
                    raise IndexError(
                        f"key {int(keys[i])} beyond end of lookup table at {g1}"
                    ) from None
                self._g0 = g1
                self._vals = nxt
                continue
            hi = int(np.searchsorted(keys, g1, side="left"))
            out[i:hi] = self._vals[keys[i:hi] - self._g0]
            i = hi
        return out
