"""Redistribute relabeled edges to their owners (paper Alg. 8-9, §III-B5).

An edge is owned by the shard whose range partition contains its (relabeled)
source.  The paper's implementation is the 1:1 scatter-gather: bucket edges
into per-destination packets, ship packets when full, collector appends.
Here that is exactly one `capacity_all_to_all` call.

Because the sources have been relabeled through a *uniform* permutation, the
per-destination counts concentrate tightly around m_local/nb (this is why the
paper relabels *before* redistributing!) — a modest capacity factor absorbs
the binomial fluctuation plus residual high-degree-vertex skew (the paper's
§IV-C weak-scaling observation).  Overflow is counted and surfaced.

Two variants, mirroring the paper:
  redistribute            unordered (paper's implemented version, §III-B5)
  redistribute_sorted     §III-B7: senders pre-sort by new source; the
                          stable bucketing preserves sortedness per packet;
                          the receiver k-way-merges the nb sorted runs =>
                          its edges arrive globally sorted by source and the
                          CSR build degenerates to the trivial Alg. 1.
                          (The paper proposes but does NOT implement this
                          variant; we implement both and benchmark the gap.)

Disk-tier twin's I/O overlap (cfg.io_overlap): the external redistribute
(phases.redistribute_bucket, external.StreamingGenerator.redistribute)
streams its partition scan through a prefetch thread and ships owner runs
write-behind through the Transport (blockstore.PrefetchReader /
WriteBehindWriter); this module's all_to_all is device-side and has no
disk I/O to overlap.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.collectives import capacity_all_to_all, merge_sorted_runs, shard_map
from .types import GraphConfig


class OwnedEdges(NamedTuple):
    """Per-shard owned edge set, fixed capacity with validity mask.

    src/dst: [nb_shards, capacity] on each shard (global: [nb*nb, cap]);
    rows are per-sender packets.  Whether the flattened per-shard view is
    globally sorted by src is a property of which redistribute variant
    produced it (§III-B7 => sorted), not a runtime flag — jit traces bools.
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    valid: jnp.ndarray
    dropped: jnp.ndarray


def _default_capacity(cfg: GraphConfig, nb: int) -> int:
    return int(cfg.capacity_factor * cfg.edges_per_shard / max(nb, 1)) + 8


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis", "capacity"))
def redistribute(
    cfg: GraphConfig,
    mesh: Mesh,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    axis: str = "shards",
    capacity: int = 0,
) -> OwnedEdges:
    """Unordered redistribute (paper Alg. 8-9)."""
    nb = mesh.shape[axis]
    B = cfg.bucket_size
    cap = capacity or _default_capacity(cfg, nb)

    def per_shard(src_l, dst_l):
        pair = jnp.stack([src_l, dst_l], axis=-1)          # [N, 2]
        ex = capacity_all_to_all(pair, src_l // B, axis=axis, capacity=cap)
        return ex.data[..., 0], ex.data[..., 1], ex.valid, ex.dropped

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P()),
    )
    s, d, v, drop = fn(src, dst)
    return OwnedEdges(s, d, v, drop)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis", "capacity"))
def redistribute_sorted(
    cfg: GraphConfig,
    mesh: Mesh,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    axis: str = "shards",
    capacity: int = 0,
) -> OwnedEdges:
    """Sorted-merge redistribute (paper §III-B7, proposed-not-implemented).

    Sort locally by (new) src; stable bucketing keeps each packet sorted;
    receiver merges its nb sorted runs (invalid slots are key-maxed so they
    sink to the end).  Output flattened arrays are globally sorted by src.
    """
    nb = mesh.shape[axis]
    B = cfg.bucket_size
    cap = capacity or _default_capacity(cfg, nb)

    def per_shard(src_l, dst_l):
        order = jnp.argsort(src_l)                         # send-side sort
        src_s, dst_s = src_l[order], dst_l[order]
        pair = jnp.stack([src_s, dst_s], axis=-1)
        ex = capacity_all_to_all(pair, src_s // B, axis=axis, capacity=cap)
        rs, rd, rv = ex.data[..., 0], ex.data[..., 1], ex.valid
        # receive-side k-way sorted merge; sentinel-key the empty slots.
        sentinel = jnp.asarray(cfg.n, rs.dtype)
        keys = jnp.where(rv, rs, sentinel)
        payload = jnp.stack([rd, rv.astype(rd.dtype)], axis=-1)
        mkeys, mpay = merge_sorted_runs(keys, payload)
        mvalid = mpay[..., 1].astype(jnp.bool_)
        msrc = jnp.where(mvalid, mkeys, 0)
        mdst = mpay[..., 0]
        # keep the [nb, cap] layout (flattened view is sorted)
        return (
            msrc.reshape(nb, cap),
            mdst.reshape(nb, cap),
            mvalid.reshape(nb, cap),
            ex.dropped,
        )

    fn = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P()),
    )
    s, d, v, drop = fn(src, dst)
    return OwnedEdges(s, d, v, drop)
