"""Distributed random shuffle -> permutation vector pv (paper Alg. 2-4).

The paper's shuffle: each node holds one range-partition of [0:n) in `sbuf`;
for log_nb(n) rounds it (i) shuffles sbuf locally, (ii) 1:1 scatter-gathers
equal slices to every other node, (iii) swaps buffers.  The result, read in
shard order, is a permutation vector pv with pv[i] = new label of vertex i.

TPU adaptation:
  * local shuffle  = argsort of counter-hash keys (Fisher-Yates equivalent:
    sorting by i.i.d. keys is a uniform permutation of the buffer);
  * 1:1 slice exchange = `lax.all_to_all` over the shard axis (the paper's
    Alg. 2/3 send/recv loops are literally the definition of all_to_all);
  * the round loop is a `lax.fori_loop`, so the whole shuffle is one compiled
    program regardless of n.

Two variants:
  distributed_shuffle       paper-faithful multi-round shuffle-exchange
  shuffle_argsort           beyond-paper exact one-shot shuffle (global sort
                            by random keys) — what you'd do when the whole
                            key vector fits aggregate HBM.

Both return pv as a global array of shape (n,) sharded over the mesh axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.collectives import shard_map
from .rmat import mix32
from .types import GraphConfig


def _local_shuffle(buf: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    """Uniform local permutation: sort by i.i.d. counter-hash keys.

    Keys depend on the *values* (unique across the machine — buf always holds
    a subset of a permutation of [0:n)) and a per-round salt, so the schedule
    is deterministic, reproducible, and needs no RNG state.
    """
    keys = mix32(buf.astype(jnp.uint32) ^ salt)
    return buf[jnp.argsort(keys)]


def _shuffle_rounds_body(nb: int, axis: str, seed: int):
    def body(r, sbuf):
        salt = mix32(jnp.uint32(seed) + jnp.uint32(r) * jnp.uint32(0x9E3779B9))
        sbuf = _local_shuffle(sbuf, salt)
        if nb > 1:
            blk = sbuf.shape[0] // nb
            pieces = sbuf.reshape(nb, blk)
            # Alg. 2/3: slice j of my buffer -> node j; my slice stays (line 6).
            pieces = lax.all_to_all(pieces, axis, split_axis=0, concat_axis=0, tiled=False)
            sbuf = pieces.reshape(-1)
        return sbuf

    return body


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def distributed_shuffle(cfg: GraphConfig, mesh: Mesh, axis: str = "shards") -> jnp.ndarray:
    """Paper-faithful shuffle (Alg. 4).  Returns pv of shape (n,), sharded."""
    nb = mesh.shape[axis]
    assert nb == cfg.nb, f"mesh axis size {nb} != cfg.nb {cfg.nb}"
    B = cfg.bucket_size
    assert B % max(nb, 1) == 0, "bucket size must split into nb exchange slices"
    rounds = cfg.rounds

    def per_shard(_):
        bid = lax.axis_index(axis)
        # sbuf initialized to this shard's range partition of [0:n)  (RP(n, nb))
        sbuf = bid * B + jnp.arange(B, dtype=cfg.vertex_dtype)
        sbuf = lax.fori_loop(0, rounds, _shuffle_rounds_body(nb, axis, cfg.seed), sbuf)
        return sbuf

    shard_fn = shard_map(
        per_shard, mesh=mesh, in_specs=(P(axis),), out_specs=P(axis)
    )
    dummy = jnp.zeros((nb,), jnp.int32)  # carries the axis, no data
    return shard_fn(dummy)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def shuffle_argsort(cfg: GraphConfig, mesh: Mesh, axis: str = "shards") -> jnp.ndarray:
    """Beyond-paper exact shuffle: pv = argsort(counter-hash keys of [0:n)).

    One global (distributed) sort instead of log_nb(n) shuffle-exchange
    rounds.  XLA partitions the sort across the mesh; this is the fast path
    when aggregate HBM holds the key vector — i.e. the regime where the
    paper's memory wall doesn't bind.
    """
    n = cfg.n
    sharding = NamedSharding(mesh, P(axis))
    ids = jnp.arange(n, dtype=cfg.vertex_dtype)
    ids = lax.with_sharding_constraint(ids, sharding)
    keys = mix32(ids.astype(jnp.uint32) + jnp.uint32(cfg.seed))
    # sort (keys, ids) pairs by key: ids land in uniformly-random order.
    # mix32 is bijective => no duplicate keys => exact uniform permutation.
    _, pv = lax.sort([keys, ids], dimension=0, num_keys=1)
    return lax.with_sharding_constraint(pv, sharding)


def pv_is_permutation(pv: jnp.ndarray) -> jnp.ndarray:
    """Check pv is a bijection on [0:n) (validation hook)."""
    n = pv.shape[0]
    hits = jnp.zeros((n,), jnp.int32).at[pv].add(1)
    return jnp.all(hits == 1)
