"""Distributed random shuffle -> permutation vector pv (paper Alg. 2-4).

The paper's shuffle: each node holds one range-partition of [0:n) in `sbuf`;
for log_nb(n) rounds it (i) shuffles sbuf locally, (ii) 1:1 scatter-gathers
equal slices to every other node, (iii) swaps buffers.  The result, read in
shard order, is a permutation vector pv with pv[i] = new label of vertex i.

TPU adaptation:
  * local shuffle  = argsort of counter-hash keys (Fisher-Yates equivalent:
    sorting by i.i.d. keys is a uniform permutation of the buffer);
  * 1:1 slice exchange = `lax.all_to_all` over the shard axis (the paper's
    Alg. 2/3 send/recv loops are literally the definition of all_to_all);
  * the round loop is a `lax.fori_loop`, so the whole shuffle is one compiled
    program regardless of n.

Three variants:
  distributed_shuffle       paper-faithful multi-round shuffle-exchange
  shuffle_argsort           beyond-paper exact one-shot shuffle (global sort
                            by random keys) — what you'd do when the whole
                            key vector fits aggregate HBM.
  shuffle_recompute         the communication-free family (Funke et al.):
                            pv[i] = keyed_perm(i), a Feistel bijection over
                            mix32 — ZERO collectives, every shard evaluates
                            its own slice, and any host can recompute any
                            entry (the disk tier never materializes pv at
                            all).  jnp twin of hostgen.keyed_perm_np,
                            bit-exact (tested).

All return pv as a global array of shape (n,) sharded over the mesh axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.collectives import shard_map
from .hostgen import (
    FEISTEL_ROUNDS,
    feistel_round_key_np,
    graph_perm_key,
    perm_domain_bits,
)
from .rmat import mix32
from .types import GraphConfig


def _local_shuffle(buf: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    """Uniform local permutation: sort by i.i.d. counter-hash keys.

    Keys depend on the *values* (unique across the machine — buf always holds
    a subset of a permutation of [0:n)) and a per-round salt, so the schedule
    is deterministic, reproducible, and needs no RNG state.
    """
    keys = mix32(buf.astype(jnp.uint32) ^ salt)
    return buf[jnp.argsort(keys)]


def _shuffle_rounds_body(nb: int, axis: str, seed: int):
    def body(r, sbuf):
        salt = mix32(jnp.uint32(seed) + jnp.uint32(r) * jnp.uint32(0x9E3779B9))
        sbuf = _local_shuffle(sbuf, salt)
        if nb > 1:
            blk = sbuf.shape[0] // nb
            pieces = sbuf.reshape(nb, blk)
            # Alg. 2/3: slice j of my buffer -> node j; my slice stays (line 6).
            pieces = lax.all_to_all(pieces, axis, split_axis=0, concat_axis=0, tiled=False)
            sbuf = pieces.reshape(-1)
        return sbuf

    return body


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def distributed_shuffle(cfg: GraphConfig, mesh: Mesh, axis: str = "shards") -> jnp.ndarray:
    """Paper-faithful shuffle (Alg. 4).  Returns pv of shape (n,), sharded."""
    nb = mesh.shape[axis]
    assert nb == cfg.nb, f"mesh axis size {nb} != cfg.nb {cfg.nb}"
    B = cfg.bucket_size
    assert B % max(nb, 1) == 0, "bucket size must split into nb exchange slices"
    rounds = cfg.rounds

    def per_shard(_):
        bid = lax.axis_index(axis)
        # sbuf initialized to this shard's range partition of [0:n)  (RP(n, nb))
        sbuf = bid * B + jnp.arange(B, dtype=cfg.vertex_dtype)
        sbuf = lax.fori_loop(0, rounds, _shuffle_rounds_body(nb, axis, cfg.seed), sbuf)
        return sbuf

    shard_fn = shard_map(
        per_shard, mesh=mesh, in_specs=(P(axis),), out_specs=P(axis)
    )
    dummy = jnp.zeros((nb,), jnp.int32)  # carries the axis, no data
    return shard_fn(dummy)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def shuffle_argsort(cfg: GraphConfig, mesh: Mesh, axis: str = "shards") -> jnp.ndarray:
    """Beyond-paper exact shuffle: pv = argsort(counter-hash keys of [0:n)).

    One global (distributed) sort instead of log_nb(n) shuffle-exchange
    rounds.  XLA partitions the sort across the mesh; this is the fast path
    when aggregate HBM holds the key vector — i.e. the regime where the
    paper's memory wall doesn't bind.
    """
    n = cfg.n
    sharding = NamedSharding(mesh, P(axis))
    ids = jnp.arange(n, dtype=cfg.vertex_dtype)
    ids = lax.with_sharding_constraint(ids, sharding)
    keys = mix32(ids.astype(jnp.uint32) + jnp.uint32(cfg.seed))
    # sort (keys, ids) pairs by key: ids land in uniformly-random order.
    # mix32 is bijective => no duplicate keys => exact uniform permutation.
    _, pv = lax.sort([keys, ids], dimension=0, num_keys=1)
    return lax.with_sharding_constraint(pv, sharding)


# ---------------------------------------------------------------------------
# Keyed invertible permutation family — jnp twin of hostgen's Feistel.
# Container is uint32 (jax x64 stays disabled), so nbits <= 32; the numpy
# source of truth covers nbits <= 62 with its uint64 container.  For the
# overlap the two agree bit for bit (tested), as does the Pallas kernel
# (kernels/rmat.feistel_perm_pallas).
# ---------------------------------------------------------------------------


def feistel_perm(x: jnp.ndarray, key: int, nbits: int,
                 rounds: int = FEISTEL_ROUNDS) -> jnp.ndarray:
    """Keyed bijection on [0, 2**nbits), nbits <= 32.  Returns uint32.

    Identical round structure to hostgen.feistel_perm_np: F = mix32(R ^
    rk_i) with rk_i = mix32(key + (i+1)*GOLDEN) folded in Python ints, the
    halves swap, and the new R is masked to the old L's width.  The round
    loop is a static unroll (rounds is a compile-time constant)."""
    if rounds < 2 or rounds % 2:
        raise ValueError(f"feistel rounds must be even and >= 2, got {rounds}")
    if not 1 <= nbits <= 32:
        raise ValueError(
            f"jnp feistel container is uint32: need 1 <= nbits <= 32, got "
            f"{nbits} (use hostgen.feistel_perm_np for wider domains)")
    lo_bits = nbits // 2
    x = jnp.asarray(x).astype(jnp.uint32)
    L = x >> lo_bits
    R = x & jnp.uint32((1 << lo_bits) - 1)
    wL, wR = nbits - lo_bits, lo_bits
    for i in range(rounds):
        rk = jnp.uint32(int(feistel_round_key_np(key, i)))
        F = mix32(R ^ rk)
        L, R, wL, wR = R, (L ^ F) & jnp.uint32((1 << wL) - 1), wR, wL
    return (L << lo_bits) | R


def keyed_perm(x: jnp.ndarray, key: int, n: int,
               rounds: int = FEISTEL_ROUNDS) -> jnp.ndarray:
    """Keyed bijection on [0, n) via cycle-walking (twin of
    hostgen.keyed_perm_np).  For power-of-two n the while_loop body never
    runs; otherwise out-of-range lanes are re-permuted until in range
    (termination: the Feistel orbit of any x < n returns to x).  Returns
    the input's dtype."""
    nbits = perm_domain_bits(n)
    dtype = jnp.asarray(x).dtype
    y = feistel_perm(x, key, nbits, rounds)
    bound = jnp.uint32(n)

    def walk(y):
        return jnp.where(y >= bound, feistel_perm(y, key, nbits, rounds), y)

    if n != (1 << nbits):  # non-power-of-two domain: cycle-walk
        y = lax.while_loop(lambda y: jnp.any(y >= bound), walk, y)
    return y.astype(dtype)


def graph_perm(seed: int, x: jnp.ndarray, n: int,
               rounds: int = FEISTEL_ROUNDS) -> jnp.ndarray:
    """Device twin of hostgen.graph_perm_np (same key derivation)."""
    return keyed_perm(x, graph_perm_key(seed), n, rounds)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def shuffle_recompute(cfg: GraphConfig, mesh: Mesh, axis: str = "shards") -> jnp.ndarray:
    """Communication-free pv: every shard evaluates keyed_perm over its own
    range partition — no shuffle rounds, no all_to_all, no materialized
    state beyond the output itself.  Requires cfg.scale <= 31 (vertex ids
    must fit the uint32 Feistel container)."""
    sharding = NamedSharding(mesh, P(axis))
    ids = lax.with_sharding_constraint(
        jnp.arange(cfg.n, dtype=cfg.vertex_dtype), sharding)
    pv = graph_perm(cfg.seed, ids, cfg.n, rounds=cfg.feistel_rounds)
    return lax.with_sharding_constraint(pv, sharding)


def pv_is_permutation(pv: jnp.ndarray) -> jnp.ndarray:
    """Check pv is a bijection on [0:n) (validation hook)."""
    n = pv.shape[0]
    hits = jnp.zeros((n,), jnp.int32).at[pv].add(1)
    return jnp.all(hits == 1)
