"""Hash-based relabel baseline (the Graph500 'hashing based' kernel, §I).

The reference Graph500 kernel avoids the permutation vector entirely: a
perfect hash (MRG-family) maps old id -> new id in O(1) from main memory.
The paper's whole point is that this is the *memory-bound* design: it needs
the full graph resident, so scale-34 demands ~8 TB of DRAM.

We implement the baseline faithfully-in-spirit with a **Feistel network on
`scale` bits**: provably a bijection on [0, 2**scale) for any scale, collision
free, high-quality mixing, O(1) per lookup, vectorizes perfectly — the same
properties the MRG hash is chosen for.  Benchmarks compare it against the
paper's shuffle+relabel pipeline (the paper's own micro-comparison: hashing
2^30 ints = 1.34 s vs chunk-sorting them = 5.134 s on their machine).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .rmat import mix32
from .types import GraphConfig

_ROUNDS = 4


def _feistel_even(v: jnp.ndarray, bits: int, seed: int) -> jnp.ndarray:
    """Balanced Feistel on an even number of bits: provably a bijection on
    [0, 2**bits) regardless of the round function."""
    half = bits // 2
    mask = jnp.uint32((1 << half) - 1)
    L = (v >> half) & mask
    R = v & mask
    for r in range(_ROUNDS):
        k = jnp.uint32(seed) ^ jnp.uint32((r * 0x9E3779B9) & 0xFFFFFFFF)
        L, R = R, L ^ (mix32(R + k) & mask)
    return (L << half) | R


def feistel_permute(v: jnp.ndarray, scale: int, seed: int) -> jnp.ndarray:
    """Bijective map on [0, 2**scale) via Feistel + cycle walking.

    Odd `scale` is handled by running the network on scale+1 bits and
    *cycle walking*: re-encrypt any output that falls outside [0, 2**scale)
    until it lands inside.  Cycle walking preserves the bijection exactly
    (standard format-preserving-encryption argument), and terminates because
    the permutation's cycles are finite.  Tests verify bijectivity for
    scales 4..20, odd and even.
    """
    v = v.astype(jnp.uint32)
    bits = scale + (scale & 1)
    n = jnp.uint32(1) << scale
    x = _feistel_even(v, bits, seed)
    if bits == scale:
        return x

    def cond(x):
        return jnp.any(x >= n)

    def body(x):
        return jnp.where(x >= n, _feistel_even(x, bits, seed), x)

    return jax.lax.while_loop(cond, body, x)


@partial(jax.jit, static_argnames=("cfg",))
def hash_relabel(cfg: GraphConfig, src: jnp.ndarray, dst: jnp.ndarray):
    """The baseline kernel's relabel: new = H(old), no pv, no communication.

    This is what the paper's pipeline replaces when memory is scarce.
    """
    ns = feistel_permute(src, cfg.scale, cfg.seed).astype(src.dtype)
    nd = feistel_permute(dst, cfg.scale, cfg.seed).astype(dst.dtype)
    return ns, nd


def hash_permutation_vector(cfg: GraphConfig) -> jnp.ndarray:
    """Materialize H as a pv (for cross-validating against relabel paths)."""
    ids = jnp.arange(cfg.n, dtype=jnp.uint32)
    return feistel_permute(ids, cfg.scale, cfg.seed).astype(cfg.vertex_dtype)
