"""The paper's primary contribution: external-memory distributed graph
generation — shuffle, R-MAT, relabel, redistribute, CSR — as shard_map
collectives + chunk-streamed host storage."""

from .types import GraphConfig, owner_of, quadrant_thresholds  # noqa: F401
from .rmat import rmat_edge_block, mix32, counter_uniform_u32  # noqa: F401
from .blockstore import (  # noqa: F401
    BlockStore, IOLedger, MemoryGauge, MonotoneLookup,
    clean_cascade_stores, merge_runs, partition_runs, sort_runs,
)
from .phases import PhaseOrchestrator, PartitionedGenerator, plain_config  # noqa: F401
from .corpus import ShardedWalks  # noqa: F401
from .cluster import (  # noqa: F401
    ClusterController, ClusterGenerator, ClusterSpec, CommandTemplateBackend,
    HostRunner, HostSpec, LocalExecBackend,
)
from .transport import (  # noqa: F401
    ExchangeServer, FilesystemTransport, SocketTransport, Transport,
    TransportError, TransportStats, make_transport, sweep_partial_frames,
)
from .external import StreamingGenerator, RunStore, external_merge, external_sort_runs  # noqa: F401
from .hostgen import mix32_np, rmat_edges_np, rmat_edges_np_cfg  # noqa: F401
from .shuffle import distributed_shuffle, shuffle_argsort, pv_is_permutation  # noqa: F401
from .relabel import relabel_ring, relabel_alltoall  # noqa: F401
from .redistribute import redistribute, redistribute_sorted, OwnedEdges  # noqa: F401
from .csr import build_csr_scatter, build_csr_sorted, CSRShards, csr_neighbors  # noqa: F401
from .hashing import feistel_permute, hash_relabel, hash_permutation_vector  # noqa: F401
from .pipeline import generate, generate_edges, generate_baseline_hash, GraphResult  # noqa: F401
