"""Run-wide tracing + unified metrics: spans, Perfetto export, one schema.

The paper's whole argument is an I/O-cost ledger — which pass, which phase,
how many bytes, how much overlap — but until this module the telemetry was
fragmented: IOLedger (disk), TransportStats (wire), MemoryGauge (residency),
stall counters (async I/O), and ad-hoc controller dicts, none of which could
answer "where did the wall time of this 2-host run go?".  Two pieces close
that gap:

  Tracer            a per-process, append-only span log.  Every
                    instrumented site (PhaseOrchestrator.run_phase, the
                    phase kernels via phases._traced_kernel, the blockstore
                    sort/merge/partition primitives, Transport sends and
                    MIGRATE streams, controller barriers) emits one JSON
                    line per span into `<workdir>/trace/trace_{pid}.jsonl`.
                    Emission is off the hot path: spans buffer in a bounded
                    in-memory deque and a background thread flushes them;
                    when the buffer saturates, spans are DROPPED and
                    counted, never blocked on.  With tracing disabled
                    (GraphConfig.trace=False, the default) every site costs
                    one attribute check — the NullTracer — and no file is
                    ever created, so traced and untraced runs are
                    bit-identical in everything but the trace files.

  MetricsRegistry   one snapshot schema (`unified_snapshot`) over every
                    counter family: {"schema", "io" (IOLedger), "stalls"
                    (read_wait_s/write_wait_s/overlap_s), "wire"
                    (TransportStats), "memory" (MemoryGauge)}.  The SAME
                    shape flows into BENCH_*.json (benchmarks/run.py), the
                    controller's `status` admin RPC (per host), and any
                    future serve-tier histogram — so trajectory diffs,
                    live fleet views, and trace args never disagree about
                    what a byte counter is called.

Hosts ship their trace files to the controller (a "trace" control op riding
the exchange frame format — see core/cluster.py), where they land in
`<ctrl>/trace/host{h}.jsonl`; `merge_traces` + `to_perfetto` turn any pile
of trace files into one run-wide Chrome/Perfetto trace-event JSON
(`python -m repro.launch.cluster trace`).

Clock discipline: spans carry WALL-clock `ts` (time.time(), comparable
across processes and hosts within NTP skew) and a perf_counter-measured
`dur`, so per-phase durations are monotonic-accurate even when the wall
clock steps.  The span NESTING law (a child span closes before its parent,
per (host, pid, tid) lane) holds for the call-structured categories
"phase" and "kernel" only; "io"/"wire"/"stall" spans are leaf complete
events that generator interleaving may close out of LIFO order, so
`validate_timeline` exempts them.

`python -m repro.core.trace lint` asserts every kernel registered in
phases._KERNELS (the universe phase_task_plan draws from) carries the
instrumentation wrapper — the CI guard against a new kernel silently
missing from timelines.
"""

from __future__ import annotations

import contextlib
import dataclasses
import glob
import json
import os
import socket
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence

SCHEMA_VERSION = 1

# Subdirectory of a workdir holding that process tree's trace files.
TRACE_DIR = "trace"

# Stall windows shorter than this emit NO span (the counter in IOLedger
# still accumulates them): per-block waits of a healthy overlapped pass are
# microseconds, and a span per block would swamp the buffer with noise.
STALL_MIN_S = 1e-3

# Categories that are strictly call-structured (emitted by `with` blocks /
# function wrappers on one thread) and therefore subject to the nesting law.
NESTED_CATS = ("phase", "kernel")

# Tolerance for the nesting/ordering checks: perf_counter durations are
# subtracted from wall timestamps taken a few ns apart, so parent/child
# endpoints can disagree by scheduler-tick noise.
_EPS_S = 5e-3


def _now() -> float:
    return time.time()


class NullTracer:
    """The disabled tracer: every instrumented site costs one `.enabled`
    check (or a no-op context manager), and nothing touches the disk."""

    enabled = False
    dropped = 0
    path = None

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "span", **args):
        yield

    def event(self, name: str, cat: str, t0: float, dur: float,
              args: Optional[Dict] = None) -> None:
        pass

    def instant(self, name: str, cat: str = "instant", **args) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


_NULL = NullTracer()


class Tracer:
    """Span emitter for ONE process: bounded buffer, background flush.

    `host`/`job` label every span (None omits the field); `path` is the
    per-process trace file — per-PID because pool workers and host daemons
    share workdirs, and an append-only file with one writer needs no
    locking.  Buffer overflow DROPS spans (counted in `dropped`, recorded
    as a final meta line on close) instead of blocking the traced code —
    tracing must never become the bottleneck it is measuring."""

    enabled = True

    def __init__(self, trace_dir: str, host=None, job: Optional[str] = None,
                 max_buffer: int = 8192, flush_interval: float = 0.5):
        os.makedirs(trace_dir, exist_ok=True)
        self.path = os.path.join(trace_dir, f"trace_{os.getpid()}.jsonl")
        self.host = host
        self.job = job
        self.dropped = 0
        self._max = int(max_buffer)
        self._buf: deque = deque()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._flush_loop, args=(float(flush_interval),),
            name="trace-flush", daemon=True)
        self._thread.start()

    # -- emission ------------------------------------------------------------
    def _emit(self, rec: Dict) -> None:
        if self.host is not None:
            rec["host"] = self.host
        if self.job is not None:
            rec["job"] = self.job
        rec["pid"] = os.getpid()
        rec["tid"] = threading.get_ident()
        with self._lock:
            if len(self._buf) >= self._max:
                self.dropped += 1
                return
            self._buf.append(rec)

    def event(self, name: str, cat: str, t0: float, dur: float,
              args: Optional[Dict] = None) -> None:
        """One COMPLETE span from pre-measured (wall t0, duration)."""
        rec = {"name": name, "cat": cat, "ph": "X",
               "ts": float(t0), "dur": float(dur)}
        if args:
            rec["args"] = args
        self._emit(rec)

    def instant(self, name: str, cat: str = "instant", **args) -> None:
        rec = {"name": name, "cat": cat, "ph": "i", "ts": _now()}
        if args:
            rec["args"] = args
        self._emit(rec)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "span", **args):
        t0 = _now()
        p0 = time.perf_counter()
        try:
            yield
        finally:
            self.event(name, cat, t0, time.perf_counter() - p0,
                       args=args or None)

    # -- flushing ------------------------------------------------------------
    def _drain(self) -> List[Dict]:
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
        return out

    def flush(self) -> None:
        recs = self._drain()
        if not recs:
            return
        lines = "".join(json.dumps(r, separators=(",", ":")) + "\n"
                        for r in recs)
        with open(self.path, "a") as f:
            f.write(lines)

    def _flush_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            try:
                self.flush()
            except OSError:
                pass   # disk-full etc. must never kill the traced process

    def close(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        try:
            # Drain BEFORE appending the meta record: on a full buffer the
            # meta line would otherwise be the one span _emit drops.
            self.flush()
            if self.dropped:
                self._emit({"name": "trace_dropped", "cat": "meta",
                            "ph": "i", "ts": _now(),
                            "args": {"dropped": self.dropped}})
                self.flush()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Process-global tracer installation
# ---------------------------------------------------------------------------

_TRACER = _NULL
_INSTALL_LOCK = threading.Lock()


def get_tracer():
    """The process tracer — _NULL (enabled=False) until installed."""
    return _TRACER


def install_tracer(workdir: str, host=None, job: Optional[str] = None,
                   **kw) -> Tracer:
    """Install the process-global Tracer writing under
    `<workdir>/trace/`.  Idempotent: a second install keeps the first
    tracer (one process, one trace file) and returns it."""
    global _TRACER
    with _INSTALL_LOCK:
        if isinstance(_TRACER, Tracer):
            return _TRACER
        _TRACER = Tracer(os.path.join(workdir, TRACE_DIR),
                         host=host, job=job, **kw)
        return _TRACER


def maybe_install_tracer(workdir: str, enabled: bool = True, host=None,
                         job: Optional[str] = None):
    """install_tracer gated on a config flag — the one-liner every driver
    and worker entry point calls: no-op (and no directory) when disabled."""
    if not enabled:
        return _TRACER
    return install_tracer(workdir, host=host, job=job)


def uninstall_tracer() -> None:
    """Close and reset to the NullTracer (tests; production processes just
    exit and the daemon flush thread dies with them after a final flush on
    close paths that call it)."""
    global _TRACER
    with _INSTALL_LOCK:
        tr, _TRACER = _TRACER, _NULL
    tr.close()


# ---------------------------------------------------------------------------
# Merge + validation + Perfetto export
# ---------------------------------------------------------------------------


def trace_files(dirs: Iterable[str]) -> List[str]:
    """Every trace file under the given directories: per-process
    `trace_{pid}.jsonl` files plus controller-side shipped `host{h}.jsonl`
    files, in deterministic (sorted) order."""
    out: List[str] = []
    for d in dirs:
        out += glob.glob(os.path.join(d, "trace_*.jsonl"))
        out += glob.glob(os.path.join(d, "host*.jsonl"))
    return sorted(set(out))


def merge_traces(sources: Iterable[str]) -> List[Dict]:
    """Merge trace FILES and/or trace DIRECTORIES into one run-wide
    timeline, sorted by (ts, -dur, name) so parents precede children and
    the result is a pure function of the input contents (not of file
    order).  Torn trailing lines (a process killed mid-flush) and corrupt
    lines are skipped — a trace must be readable after any crash the
    checkpoint machinery survives."""
    paths: List[str] = []
    for s in sources:
        if os.path.isdir(s):
            paths += trace_files([s])
        elif os.path.exists(s):
            paths.append(s)
    events: List[Dict] = []
    for p in sorted(set(paths)):
        try:
            with open(p) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue   # torn/corrupt line: skip, keep the rest
                    if isinstance(rec, dict) and "ts" in rec:
                        events.append(rec)
        except OSError:
            continue
    events.sort(key=lambda r: (float(r.get("ts", 0.0)),
                               -float(r.get("dur", 0.0)),
                               str(r.get("name", ""))))
    return events


def _lane(rec: Dict):
    return (rec.get("host"), rec.get("pid"), rec.get("tid"))


def validate_timeline(events: Sequence[Dict]) -> List[str]:
    """Well-formedness of a merged timeline; returns problem strings
    (empty = valid).  Checks: every complete span has a non-negative
    duration, and per (host, pid, tid) lane the call-structured categories
    (NESTED_CATS) obey the nesting law — a child span lies within its
    parent (±_EPS_S for cross-clock subtraction noise).  Leaf categories
    (io/wire/stall/ctrl) are exempt: generator-driven I/O spans legally
    close out of LIFO order when merges interleave."""
    problems: List[str] = []
    lanes: Dict[tuple, List[Dict]] = {}
    for rec in events:
        if rec.get("ph") == "X":
            dur = float(rec.get("dur", 0.0))
            if dur < 0.0:
                problems.append(
                    f"negative duration {dur} on span {rec.get('name')!r}")
            if rec.get("cat") in NESTED_CATS:
                lanes.setdefault(_lane(rec), []).append(rec)
    for lane, recs in lanes.items():
        recs = sorted(recs, key=lambda r: (float(r["ts"]), -float(r["dur"])))
        stack: List[Dict] = []
        for rec in recs:
            t0 = float(rec["ts"])
            t1 = t0 + float(rec["dur"])
            while stack and t0 >= (float(stack[-1]["ts"])
                                   + float(stack[-1]["dur"]) - _EPS_S):
                stack.pop()
            if stack:
                p1 = float(stack[-1]["ts"]) + float(stack[-1]["dur"])
                if t1 > p1 + _EPS_S:
                    problems.append(
                        f"span {rec.get('name')!r} overflows its parent "
                        f"{stack[-1].get('name')!r} in lane {lane} "
                        f"({t1 - p1:.6f}s past the parent end)")
            stack.append(rec)
    return problems


def to_perfetto(events: Sequence[Dict]) -> Dict:
    """Chrome/Perfetto trace-event JSON: complete ("X") and instant ("i")
    events with µs timestamps rebased to the earliest span, one Perfetto
    pid per (host, pid) so a 2-host run renders as parallel process
    tracks."""
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    base = min(float(r["ts"]) for r in events)
    procs: Dict[tuple, int] = {}
    out: List[Dict] = []
    for rec in events:
        pkey = (rec.get("host"), rec.get("pid"))
        pid = procs.get(pkey)
        if pid is None:
            pid = procs[pkey] = len(procs) + 1
            host = "?" if pkey[0] is None else pkey[0]
            out.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0,
                        "args": {"name": f"host {host} / pid {pkey[1]}"}})
        ev = {"name": str(rec.get("name", "?")),
              "cat": str(rec.get("cat", "span")),
              "ph": rec.get("ph", "X"),
              "ts": int(round((float(rec["ts"]) - base) * 1e6)),
              "pid": pid,
              "tid": int(rec.get("tid") or 0) % (1 << 31)}
        if rec.get("ph") == "X":
            ev["dur"] = max(0, int(round(float(rec.get("dur", 0.0)) * 1e6)))
        args = dict(rec.get("args") or {})
        if rec.get("job"):
            args["job"] = rec["job"]
        if args:
            ev["args"] = args
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_perfetto(events: Sequence[Dict], path: str) -> str:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(to_perfetto(events), f)
    os.replace(tmp, path)
    return path


def phase_durations(events: Sequence[Dict]) -> Dict[str, float]:
    """Total seconds per phase-span name — the "where did the wall time
    go" summary the acceptance gate sums against run wall time."""
    out: Dict[str, float] = {}
    for rec in events:
        if rec.get("ph") == "X" and rec.get("cat") == "phase":
            name = str(rec.get("name", "?"))
            out[name] = out.get(name, 0.0) + float(rec.get("dur", 0.0))
    return out


# ---------------------------------------------------------------------------
# Unified metrics schema + registry
# ---------------------------------------------------------------------------

_STALL_KEYS = ("read_wait_s", "write_wait_s", "overlap_s")


def unified_snapshot(ledger=None, stats=None, gauge=None,
                     extra: Optional[Dict] = None) -> Dict:
    """THE telemetry snapshot schema: every surface that reports counters
    (BENCH_*.json, the `status` admin RPC, trace span args, future serve
    latency histograms) emits this shape, so consumers parse one schema.

      {"schema": 1,
       "io":     flat IOLedger counters (stall seconds split out),
       "stalls": {"read_wait_s", "write_wait_s", "overlap_s"},
       "wire":   TransportStats fields,
       "memory": {"peak_rows", "budget_rows"},
       "extra":  caller-specific leaves (queue depths, heartbeat ages)}

    Sections for absent inputs are omitted, never null.  `ledger`/`stats`
    duck-type (as_dict() / dataclass / plain dict) so reports that crossed
    the wire as dicts snapshot identically to live objects."""
    snap: Dict = {"schema": SCHEMA_VERSION}
    if ledger is not None:
        d = dict(ledger.as_dict() if hasattr(ledger, "as_dict") else ledger)
        snap["stalls"] = {k: float(d.pop(k, 0.0)) for k in _STALL_KEYS}
        snap["io"] = d
    if stats is not None:
        snap["wire"] = dict(dataclasses.asdict(stats)
                            if dataclasses.is_dataclass(stats) else stats)
    if gauge is not None:
        snap["memory"] = {
            "peak_rows": int(getattr(gauge, "peak_rows", gauge if
                                     isinstance(gauge, int) else 0)),
            "budget_rows": int(getattr(gauge, "budget_rows", 0))}
    if extra:
        snap["extra"] = dict(extra)
    return snap


class MetricsRegistry:
    """Named unified_snapshot slots + a combiner.  `update(name, snap)`
    replaces the named slot (snapshots are cumulative, so latest wins);
    `combined()` folds every slot into one snapshot — numeric counters
    sum, memory peaks take the max.  Thread-safe: phase threads, the
    controller's server threads, and the bench harness all touch it."""

    def __init__(self):
        self._lock = threading.Lock()
        self._snaps: Dict[str, Dict] = {}

    def update(self, name: str, snap: Dict) -> None:
        with self._lock:
            self._snaps[name] = snap

    def get(self, name: str) -> Optional[Dict]:
        with self._lock:
            return self._snaps.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._snaps)

    def clear(self) -> None:
        with self._lock:
            self._snaps.clear()

    def combined(self) -> Dict:
        with self._lock:
            snaps = list(self._snaps.items())
        out: Dict = {"schema": SCHEMA_VERSION}
        if snaps:
            out["sources"] = sorted(n for n, _ in snaps)
        for _, snap in snaps:
            for sec in ("io", "stalls", "wire", "extra"):
                d = snap.get(sec)
                if not isinstance(d, dict):
                    continue
                acc = out.setdefault(sec, {})
                for k, v in d.items():
                    if isinstance(v, (int, float)):
                        acc[k] = acc.get(k, 0) + v
            mem = snap.get("memory")
            if isinstance(mem, dict):
                acc = out.setdefault("memory", {})
                for k, v in mem.items():
                    if isinstance(v, (int, float)):
                        acc[k] = max(acc.get(k, 0), v)
        return out


# The process-wide registry: PhaseOrchestrator folds its cumulative
# ledger/wire counters in per phase; benchmarks/run.py snapshots + clears
# it per bench; the cluster controller keeps its own per-host instances.
GLOBAL = MetricsRegistry()


# ---------------------------------------------------------------------------
# Run metadata (BENCH attribution across machines)
# ---------------------------------------------------------------------------


def run_metadata(config_digest: Optional[str] = None) -> Dict[str, str]:
    """Provenance stamp for BENCH_summary.json: which commit, which box,
    when, which jax.  All values are STRINGS so benchmarks/diff.py's
    numeric-leaf walk never tracks them as a perf trajectory."""
    meta = {
        "schema": str(SCHEMA_VERSION),
        "hostname": socket.gethostname(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "python": sys.version.split()[0],
    }
    try:
        meta["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5.0, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        meta["git_sha"] = "unknown"
    try:
        import jax
        meta["jax"] = str(jax.__version__)
    except Exception:   # pragma: no cover - jax is baked into the image
        meta["jax"] = "unavailable"
    if config_digest:
        meta["config_digest"] = str(config_digest)
    return meta


# ---------------------------------------------------------------------------
# Lint: every registered kernel carries the instrumentation wrapper
# ---------------------------------------------------------------------------


def lint_kernel_coverage() -> List[str]:
    """Problems (empty = pass): every kernel in phases._KERNELS must carry
    the `traced_kernel` wrapper attribute, and every kernel a
    phase_task_plan can dispatch must be a registered (hence instrumented)
    kernel.  Run by CI as `python -m repro.core.trace lint`."""
    from .phases import PlainCfg, _KERNELS, phase_task_plan
    problems: List[str] = []
    for name, fn in _KERNELS.items():
        if getattr(fn, "traced_kernel", None) != name:
            problems.append(f"kernel {name!r} is not wrapped with "
                            "phases._traced_kernel (no span instrumentation)")
    base = PlainCfg(scale=8, edge_factor=2, seed=1, a=0.57, b=0.19, c=0.19,
                    d=0.05, nb=2, chunk_edges=1024, rounds=2)
    walks = [(8, 2, 0, "w0.npy"), (8, 2, 1, "w1.npy")]
    plans = [
        phase_task_plan(base, walks=walks),
        phase_task_plan(dataclasses.replace(base, perm_family="feistel"),
                        csr_variant="scatter"),
        phase_task_plan(
            dataclasses.replace(base, shuffle_variant="recompute",
                                perm_family="feistel"),
            walks=walks, fuse_gen_relabel=True, fuse_walks=True),
    ]
    for plan in plans:
        for p in plan:
            k = p["kernel"]
            if k not in _KERNELS:
                problems.append(f"phase {p['phase']!r} dispatches unknown "
                                f"kernel {k!r}")
    return problems


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv[:1] == ["lint"]:
        problems = lint_kernel_coverage()
        for p in problems:
            print(f"TRACE-LINT: {p}")
        if problems:
            return 1
        from .phases import _KERNELS
        print(f"trace lint ok: {len(_KERNELS)} kernels instrumented")
        return 0
    print("usage: python -m repro.core.trace lint", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
