"""Phase orchestration for the disk tier + the partitioned multi-process mode.

Three things live here, none of which touches jax directly (worker
processes still pay the package-level jax import once at startup — Python
runs repro/core/__init__ when unpickling the kernel reference — but no jit
tracing or device state is involved in any kernel):

  PhaseOrchestrator    declares the pipeline as named, resumable,
                       individually-measurable phases.  Each phase records a
                       per-phase I/O-ledger delta (the paper's Fig. 2/4 are
                       per-phase measurements — the orchestrator is what
                       makes the host tier measurable the same way) and,
                       with checkpointing on, persists a JSON manifest of its
                       output stores so a crashed/killed run resumes at the
                       first incomplete phase.

  bucket-level kernels the unit of distribution: every pipeline phase is a
                       function of (config, workdir, bucket_id) operating on
                       BlockStores addressed *by naming convention* —
                       `pv_r{round}_b{bucket}`, `edges_b{bucket}`, … — and
                       exchanging runs through a pluggable Transport
                       (core/transport.py) that plays the role of the
                       paper's MPI interconnect: the shared filesystem
                       (`{sender}_{seq}` run tags) or framed TCP to per-
                       bucket ExchangeServers.  A phase is the same code
                       whether one process runs all buckets
                       (StreamingGenerator) or nb workers run one each
                       (PartitionedGenerator), and whichever backend carries
                       the exchange — outputs are bit-identical.

  PartitionedGenerator the single-host stand-in for the paper's 64-node
                       cluster: nb `concurrent.futures` workers, each owning
                       the vertex range [i*B, (i+1)*B), with a barrier after
                       every phase (the paper's bulk-synchronous MPI
                       structure).  Workers account I/O into private ledgers
                       that the parent merges (receiver-side ExchangeServer
                       accounting folds in at the same barriers), so the
                       aggregate ledger is comparable with the sequential
                       driver's.  The execution strategy is a hook
                       (`_submit`): core/cluster.py's ClusterGenerator
                       subclasses it to dispatch the same kernels to
                       HostRunner daemons on N machines — the paper's actual
                       deployment shape.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os
import time
from concurrent.futures import ProcessPoolExecutor
from multiprocessing import get_context
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .blockstore import (
    BlockStore,
    IOLedger,
    MemoryGauge,
    MonotoneLookup,
    NpyColumnStore,
    clean_cascade_stores,
    clean_store,
    merge_runs,
    merge_segments,
    partition_runs,
    sort_runs,
    write_behind,
)
from .corpus import (
    ShardedWalks,
    manifest_name as corpus_manifest_name,
    shard_name as corpus_shard_name,
    write_manifest,
)
from .trace import (
    GLOBAL as GLOBAL_METRICS,
    get_tracer,
    maybe_install_tracer,
    unified_snapshot,
)
from .transport import (
    ExchangeServer,
    Transport,
    TransportStats,
    make_transport,
    sweep_partial_frames,
)
from .hostgen import (
    graph_perm_np,
    rmat_edges_np_cfg,
    round_salt,
    shuffle_keys,
    walk_rand_np,
    walk_start_np,
)

# ---------------------------------------------------------------------------
# Worker-safe config (GraphConfig carries a jnp dtype; workers get this mirror)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlainCfg:
    """Picklable, numpy-only mirror of GraphConfig for phase kernels."""

    scale: int
    edge_factor: int
    seed: int
    a: float
    b: float
    c: float
    d: float
    nb: int
    chunk_edges: int
    rounds: int
    merge_block_rows: int = 0
    merge_fanin: int = 64
    # Overlap disk I/O with compute (blockstore PrefetchReader /
    # WriteBehindWriter) in every external kernel.  Timing-only — outputs
    # are bit-identical on vs. off — so result_config_key normalizes it
    # out; REPRO_IO_OVERLAP=0/false/off forces it off regardless of the
    # GraphConfig (the CI serial shard).
    io_overlap: bool = True
    # Emit timing spans (core/trace.py) from every instrumented layer into
    # per-process trace files under `<workdir>/trace/`.  Timing-only —
    # outputs are bit-identical on vs. off — so result_config_key
    # normalizes it out; REPRO_TRACE=1/0 overrides the GraphConfig.
    trace: bool = False
    # Exchange transport: "fs" (shared-filesystem {sender}_{seq} runs) or
    # "socket" (framed TCP to the ExchangeServer at peer_addrs[bucket]).
    transport: str = "fs"
    peer_addrs: Optional[Tuple[str, ...]] = None
    # Dispatch the CSR sort's cascade merge levels through the worker pool /
    # cluster (phase-level group merges) instead of cascading inline within
    # one consumer kernel.  Output is bit-identical either way (the merge is
    # stable and groups are consecutive), but the PHASE NAMES differ, so
    # this field is deliberately NOT normalized out of result_config_key: a
    # checkpoint taken in one mode must not be resumed in the other (its GC
    # may have freed the other mode's phase inputs).
    pooled_cascade: bool = False
    # Disk-tier shuffle variant: "device" | "external" | "recompute".  The
    # recompute variant (Funke et al.) materializes NO pv stores and fuses
    # relabel + redistribute into one hash-evaluating scan — a different
    # phase schedule AND different CSR sort key, so (like pooled_cascade)
    # it stays in result_config_key.
    shuffle_variant: str = "external"
    # Permutation family: "shuffle" (the materialized shuffle-exchange
    # permutation) or "feistel" (the keyed invertible family —
    # hostgen.graph_perm_np; recomputable on any host, forced by
    # shuffle_variant="recompute", also legal under "external" where the
    # same pv flows through the store machinery for parity testing).
    perm_family: str = "shuffle"
    # Feistel depth (perm_family="feistel"); even, >= 2.
    feistel_rounds: int = 4
    # Per-job exchange namespace (the multi-tenant job queue): when set,
    # every socket frame carries it as a subdir, so concurrent jobs share
    # one ExchangeServer per host without their same-named inboxes ever
    # colliding (`<host workdir>/<namespace>/<store>`).  Pure routing —
    # never affects result bytes — so result_config_key normalizes it out
    # exactly like transport/peer_addrs.
    exchange_namespace: Optional[str] = None
    # Shard-map version the routes in peer_addrs were computed under (the
    # controller's directory ShardMap; core/shardmap.py).  Stamped into
    # every socket frame as `mapv` so receivers can refuse stale routes
    # after a rebalance barrier.  Like peer_addrs this is pure routing —
    # the map changes where bytes live, never what they are — so
    # result_config_key normalizes it out.
    shard_map_version: int = 0

    @property
    def n(self) -> int:
        return 1 << self.scale

    @property
    def m(self) -> int:
        return self.n * self.edge_factor

    @property
    def bucket_size(self) -> int:
        return self.n // self.nb

    @property
    def edges_per_bucket(self) -> int:
        return self.m // self.nb


def _resolve_io_overlap(cfg) -> bool:
    """cfg.io_overlap, unless REPRO_IO_OVERLAP is set in the environment —
    the override keeps one CI tier-1 shard on the strictly serial path
    without threading a config change through every fixture."""
    env = os.environ.get("REPRO_IO_OVERLAP")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no", "")
    return bool(getattr(cfg, "io_overlap", True))


def _resolve_trace(cfg) -> bool:
    """cfg.trace, unless REPRO_TRACE is set — the override turns tracing on
    for a whole CI job / ad-hoc run without threading a config change
    through every fixture (mirror of _resolve_io_overlap)."""
    env = os.environ.get("REPRO_TRACE")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "off", "no", "")
    return bool(getattr(cfg, "trace", False))


def plain_config(cfg) -> PlainCfg:
    """Accepts GraphConfig (or anything duck-typed like it)."""
    shuffle_variant = str(getattr(cfg, "shuffle_variant", "external"))
    perm_family = str(getattr(cfg, "perm_family", "shuffle"))
    if shuffle_variant == "recompute" and perm_family == "shuffle":
        # recompute REQUIRES a recomputable permutation; auto-select it so
        # cfg.with_(shuffle_variant="recompute") alone does the right thing.
        perm_family = "feistel"
    p = PlainCfg(
        scale=int(cfg.scale), edge_factor=int(cfg.edge_factor), seed=int(cfg.seed),
        a=float(cfg.a), b=float(cfg.b), c=float(cfg.c), d=float(cfg.d),
        nb=int(cfg.nb), chunk_edges=int(cfg.chunk_edges), rounds=int(cfg.rounds),
        merge_block_rows=int(getattr(cfg, "merge_block_rows", 0)),
        merge_fanin=int(getattr(cfg, "merge_fanin", 64)),
        io_overlap=_resolve_io_overlap(cfg),
        trace=_resolve_trace(cfg),
        # "filesystem" is accepted as an alias and canonicalized, so every
        # downstream comparison can test == "fs" alone.
        transport={"filesystem": "fs"}.get(
            str(getattr(cfg, "transport", "fs")),
            str(getattr(cfg, "transport", "fs"))),
        peer_addrs=(None if getattr(cfg, "peer_addrs", None) is None
                    else tuple(str(a) for a in cfg.peer_addrs)),
        pooled_cascade=bool(getattr(cfg, "pooled_cascade", False)),
        shuffle_variant=shuffle_variant,
        perm_family=perm_family,
        feistel_rounds=int(getattr(cfg, "feistel_rounds", 4)),
        exchange_namespace=(None
                            if getattr(cfg, "exchange_namespace", None) is None
                            else str(cfg.exchange_namespace)),
        shard_map_version=int(getattr(cfg, "shard_map_version", 0)),
    )
    if p.n % p.nb != 0:
        raise ValueError(f"nb={p.nb} must divide n={p.n}")
    if p.shuffle_variant not in ("device", "external", "recompute"):
        raise ValueError(
            f"shuffle_variant must be 'device', 'external' or 'recompute', "
            f"got {p.shuffle_variant!r}")
    if p.perm_family not in ("shuffle", "feistel"):
        raise ValueError(
            f"perm_family must be 'shuffle' or 'feistel', got "
            f"{p.perm_family!r}")
    if p.perm_family == "feistel":
        if p.shuffle_variant == "device":
            raise ValueError(
                "perm_family='feistel' is the disk tier's recomputable "
                "family; use shuffle_variant 'recompute' or 'external' "
                "(the device twin is shuffle.shuffle_recompute)")
        if p.scale > 31:
            raise ValueError(
                f"perm_family='feistel' needs scale <= 31 (ids in the "
                f"uint32 container; (src, dst) sort keys in int64), got "
                f"scale={p.scale}")
        if p.feistel_rounds < 2 or p.feistel_rounds % 2:
            raise ValueError(
                f"feistel_rounds must be even and >= 2, got "
                f"{p.feistel_rounds}")
    if p.merge_fanin == 1 or p.merge_fanin < 0:
        raise ValueError(
            f"merge_fanin must be 0 (flat) or >= 2, got {p.merge_fanin}")
    if p.transport not in ("fs", "socket"):
        raise ValueError(
            f"transport must be 'fs' or 'socket', got {p.transport!r}")
    if p.peer_addrs is not None and len(p.peer_addrs) != p.nb:
        raise ValueError(
            f"peer_addrs must hold one address per bucket: "
            f"got {len(p.peer_addrs)} for nb={p.nb}")
    return p


def result_config_key(pcfg: PlainCfg) -> PlainCfg:
    """The subset of a config that determines the RESULT bytes.  Transport
    choice and peer addresses move data differently but produce bit-identical
    stores, and socket ports are ephemeral — keying checkpoints on them would
    spuriously invalidate (or worse, a changed port would block resuming a
    crashed run).  Normalize them out.  The same normalization is what lets
    a run resume across CLUSTER shapes: host count, exec backend, and
    rendezvous addresses never reach PlainCfg at all, and the fields that do
    (transport, peer_addrs) are erased here — so a 2-host socket run and a
    single-host fs run of the same graph share one checkpoint key.

    `pooled_cascade` stays IN the key on purpose: its bytes are identical
    but its phase schedule is not, and a cross-mode resume could replay a
    phase whose inputs the other mode's checkpoint GC already freed."""
    return dataclasses.replace(pcfg, transport="fs", peer_addrs=None,
                               exchange_namespace=None, shard_map_version=0,
                               io_overlap=True, trace=False)


def validate_external_shape(p: PlainCfg) -> PlainCfg:
    """Shape requirements specific to the nb-way external shuffle/exchange
    (the device-spill path only needs nb | n).  Same constraints the device
    shuffle asserts inside jit; here they must fail before any store is
    written.  The feistel family never runs the positional slice exchange
    (its pv is computed, not shuffled), so it is exempt from the nb**2 <= n
    slice constraint."""
    if p.perm_family != "feistel" and p.bucket_size % p.nb != 0:
        raise ValueError(
            f"bucket size B={p.bucket_size} must split into nb={p.nb} "
            f"exchange slices (need nb**2 <= n)")
    if p.m % p.nb != 0:
        raise ValueError(f"nb={p.nb} must divide m={p.m}")
    return p


# ---------------------------------------------------------------------------
# Store naming convention (the "wire format" between phases)
# ---------------------------------------------------------------------------


def pv_store_name(r: int, i: int) -> str:
    return f"pv_r{r}_b{i:03d}"


def edges_store_name(i: int, pass_ix: Optional[int] = None) -> str:
    return f"edges_b{i:03d}" if pass_ix is None else f"edges_p{pass_ix}_b{i:03d}"


def relabel_inbox_name(pass_ix: int, j: int) -> str:
    return f"rl{pass_ix}_b{j:03d}"


def owned_store_name(j: int) -> str:
    return f"owned_b{j:03d}"


def sorted_owned_store_name(j: int) -> str:
    """Output of the pooled csr_sort phase (run-sorted, not yet merged)."""
    return owned_store_name(j) + "_sorted"


# Pooled-cascade intermediate stores are CHECKPOINTED phase outputs, unlike
# merge_runs' kernel-private `__cas_l` scratch — a distinct marker keeps
# clean_cascade_stores (the resume sweep) from reclaiming them.
POOLED_CASCADE_MARKER = "__pcas_l"


def pooled_cascade_store_name(base: str, level: int, g: int) -> str:
    return f"{base}{POOLED_CASCADE_MARKER}{level}_g{g:04d}"


def csr_offv_path(workdir: str, i: int) -> str:
    return os.path.join(workdir, f"csr_offv_{i:03d}.npy")


def csr_adjv_path(workdir: str, i: int) -> str:
    return os.path.join(workdir, f"csr_adjv_{i:03d}.npy")


def wfront_store_name(t: int, j: int, ns: str = "") -> str:
    """Walker frontier inbox of bucket j at walk step t (multi-writer).
    `ns` is WalkCfg.ns — the per-config prefix that keeps several walk
    configs' stores apart when they advance through one fused CSR scan."""
    return f"{ns}wfront_s{t:04d}_b{j:03d}"


def whist_store_name(s: int, j: int, ns: str = "") -> str:
    """History rows (wid, step=s, vertex) emitted by bucket j (single-writer:
    written fresh by the kernel that advances step s, so a crashed attempt's
    partial rows can never leak into a rerun)."""
    return f"{ns}whist_s{s:04d}_b{j:03d}"


def whist_inbox_name(j: int, ns: str = "") -> str:
    """Walker-block inbox of the history collect phase (multi-writer)."""
    return f"{ns}whout_b{j:03d}"


def load_bucket_csr(offv_path: str, adjv_path: str, ledger: IOLedger,
                    gauge: Optional[MemoryGauge] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Open one bucket's CSR result files: offsets resident (charged to the
    ledger — loading them back is I/O too), adjacency as a memmap (charged
    by whoever streams it)."""
    offv = np.load(offv_path)
    ledger.read(offv.nbytes)
    if gauge is not None:
        gauge.track(offv.shape[0])
    return offv, np.load(adjv_path, mmap_mode="r")


def attach_pv_buckets(pcfg: PlainCfg, workdir: str, ledger: IOLedger,
                      gauge: Optional[MemoryGauge] = None) -> List[BlockStore]:
    """Re-open the final-round pv bucket stores (they ARE the permutation)."""
    return [
        BlockStore.attach(workdir, pv_store_name(pcfg.rounds, i), ledger,
                          columns=("v",), gauge=gauge)
        for i in range(pcfg.nb)
    ]


class _SrcDstKey:
    """Composite (src, dst) merge key src * n + dst — picklable (module-level
    class, not a closure) so pool workers can receive it inside a KeySpec.
    Fits int64 because perm_family='feistel' enforces scale <= 31."""

    def __init__(self, n: int):
        self.n = n

    def __call__(self, s: np.ndarray, d: np.ndarray) -> np.ndarray:
        return s * np.int64(self.n) + d


def csr_merge_key(pcfg: PlainCfg):
    """Sort/merge KeySpec of the CSR build.  The shuffle family sorts by src
    only (column 0): redistribute arrival order is deterministic and the
    stable sort makes within-row adjacency encounter order — the historical
    contract.  The feistel family sorts by (src, dst): recompute and
    external deliver the same owned-edge MULTISET in different arrival
    orders, so only a total key makes their CSR files bit-identical."""
    if pcfg.perm_family == "feistel":
        return _SrcDstKey(pcfg.n)
    return 0


def resolve_merge_key(pcfg: PlainCfg, key):
    """Decode a wire-safe cascade key spec: an int column index, or the
    string "csr" for csr_merge_key (cluster task args travel as JSON, so a
    callable KeySpec cannot ride in them — the sentinel is resolved
    in-kernel from the config instead)."""
    if key == "csr":
        return csr_merge_key(pcfg)
    return int(key)


# ---------------------------------------------------------------------------
# Bucket-level phase kernels (shared by sequential + partitioned drivers)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _exchange(pcfg: PlainCfg, workdir: str, ledger: IOLedger,
              gauge: Optional[MemoryGauge], transport: Optional[Transport]):
    """The transport a kernel exchanges through: the caller's if provided
    (inline drivers reuse one), else one built from the config (worker
    processes — transports hold sockets and are not picklable, so workers
    reconstruct from PlainCfg and tear down with the kernel).  Flushed on
    clean exit either way; only owned transports are closed."""
    if transport is not None:
        yield transport
        transport.flush()
        return
    tr = make_transport(pcfg, workdir, ledger, gauge)
    try:
        yield tr
        tr.flush()
    finally:
        tr.close()


def init_pv_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                   ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                   transport: Optional[Transport] = None):
    """Round-0 shuffle buffer: bucket i holds its range partition of [0:n)
    (the paper's RP(n, nb)), written as chunk-bounded runs.  Local-only
    (no exchange): `transport` is accepted for the uniform kernel signature
    and unused."""
    B, chunk = pcfg.bucket_size, pcfg.chunk_edges
    store = BlockStore(workdir, pv_store_name(0, i), ledger, columns=("v",), gauge=gauge,
                       fresh=True)
    for lo in range(i * B, (i + 1) * B, chunk):
        hi = min(lo + chunk, (i + 1) * B)
        store.append_run(np.arange(lo, hi, dtype=np.int64))


def shuffle_bucket_round(pcfg: PlainCfg, workdir: str, i: int, r: int, *,
                         ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                         transport: Optional[Transport] = None):
    """One round of the external shuffle for bucket i (paper Alg. 2-4 on disk).

    (i)  local shuffle = external sort of the bucket by the counter-hash key
         mix32(value ^ salt_r) — sorting distinct values by a bijective hash
         is a uniform permutation, and exactly reproduces the device
         shuffle's argsort because the keys are unique;
    (ii) bucket exchange = the sorted stream is cut into nb equal positional
         slices, slice j shipped to next-round bucket j through the transport
         with a `{sender}_{seq}` run tag, so receivers recover sender order
         lexicographically — the disk twin of `lax.all_to_all`, over either
         the shared filesystem or framed TCP.

    Every access is a sequential scan: the shuffle phase does zero random I/O.
    """
    nb, B = pcfg.nb, pcfg.bucket_size
    blk = B // nb
    salt = round_salt(pcfg.seed, r)

    def key(v):
        return shuffle_keys(v, salt)

    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        src = tr.drain_inbox(pv_store_name(r, i), columns=("v",))
        tmp = BlockStore(workdir, pv_store_name(r, i) + "_sorted", ledger, columns=("v",),
                         gauge=gauge, fresh=True)
        sort_runs(src, tmp, key=key, overlap=pcfg.io_overlap)
        outs = tr.channels(lambda j: pv_store_name(r + 1, j), nb, columns=("v",))
        seq = [0] * nb
        pos = 0
        with write_behind(outs, ledger, gauge,
                          enabled=pcfg.io_overlap) as sinks:
            for (v,) in merge_runs(tmp, key=key,
                                   block_rows=pcfg.merge_block_rows,
                                   max_fanin=pcfg.merge_fanin,
                                   overlap=pcfg.io_overlap):
                o = 0
                while o < v.size:
                    j = pos // blk
                    take = min(v.size - o, (j + 1) * blk - pos)
                    sinks[j].append_run(v[o : o + take],
                                        tag=f"{i:03d}_{seq[j]:05d}")
                    seq[j] += 1
                    o += take
                    pos += take
        tmp.destroy()
        src.destroy()


def generate_bucket_edges(pcfg: PlainCfg, workdir: str, i: int, *,
                          ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                          transport: Optional[Transport] = None):
    """Paper Alg. 5: bucket i generates its bin of edges [i*eps, (i+1)*eps).
    Counter-based RNG => the stream is independent of nb and of which
    process generates it (regeneration-friendly).  Local-only (no exchange):
    `transport` is accepted for the uniform kernel signature and unused."""
    eps, chunk = pcfg.edges_per_bucket, pcfg.chunk_edges
    store = BlockStore(workdir, edges_store_name(i), ledger, gauge=gauge, fresh=True)
    start = i * eps
    for lo in range(start, start + eps, chunk):
        cnt = min(chunk, start + eps - lo)
        s, d = rmat_edges_np_cfg(pcfg, lo, cnt)
        store.append_run(s, d)


def materialize_pv_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                          ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                          transport: Optional[Transport] = None):
    """perm_family='feistel' under shuffle_variant='external': write bucket
    i's pv chunk pv[i*B:(i+1)*B] = graph_perm(ids) directly — ONE local phase
    replaces init + log_nb(n) shuffle-exchange rounds, because a recomputable
    permutation needs no shuffling to exist.  (Under 'recompute' even this
    store is skipped; this kernel serves the parity path that runs the
    feistel family through the full store machinery.)  Local-only:
    `transport` is accepted for the uniform kernel signature and unused."""
    B, chunk = pcfg.bucket_size, pcfg.chunk_edges
    store = BlockStore(workdir, pv_store_name(pcfg.rounds, i), ledger,
                       columns=("v",), gauge=gauge, fresh=True)
    for lo in range(i * B, (i + 1) * B, chunk):
        ids = np.arange(lo, min(lo + chunk, (i + 1) * B), dtype=np.int64)
        ledger.hashes(ids.size)
        store.append_run(graph_perm_np(pcfg.seed, ids, pcfg.n,
                                       rounds=pcfg.feistel_rounds))


def relabel_recompute_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                             ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                             transport: Optional[Transport] = None):
    """The communication-free relabel (Funke et al.): ONE streaming scan of
    bucket i's raw edges applies u -> perm(u) to both endpoints (pure hash
    evaluations charged to ledger.hash_evals — no pv store, no scatter/join
    exchange, no external sort) and partitions each run straight to
    owner(perm(src))'s owned inbox.  The external pipeline's two relabel
    passes AND the redistribute phase collapse into this kernel: the only
    bytes on the wire are the one edge exchange every variant must pay to
    place edges with their owners."""
    B = pcfg.bucket_size

    def relabel(s, d):
        ledger.hashes(s.size + d.size)
        return (graph_perm_np(pcfg.seed, s, pcfg.n, rounds=pcfg.feistel_rounds),
                graph_perm_np(pcfg.seed, d, pcfg.n, rounds=pcfg.feistel_rounds))

    store = BlockStore.attach(workdir, edges_store_name(i), ledger, gauge=gauge)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(owned_store_name, pcfg.nb)
        partition_runs(store, outs, lambda a, b: a // B,
                       tag_prefix=f"{i:03d}", transform=relabel,
                       overlap=pcfg.io_overlap)


class _RegenRuns:
    """A virtual, read-only BlockStore over bucket i's RAW edge stream that
    REGENERATES each run from the counter-based RNG instead of reading disk
    — run boundaries exactly match what generate_bucket_edges would have
    appended, so any consumer (partition_runs) sees a bit-identical store.
    Exists for gen_relabel_recompute_bucket: a task with no local inputs at
    all is freely migratable between hosts, which is what makes it stealable
    under the job-queue scheduler."""

    def __init__(self, pcfg: PlainCfg, i: int, ledger: IOLedger,
                 gauge: Optional[MemoryGauge]):
        self.pcfg, self.i = pcfg, i
        self.ledger = ledger
        self.gauge = gauge if gauge is not None else MemoryGauge()
        self.name = edges_store_name(i)

    def iter_runs(self):
        pcfg = self.pcfg
        eps, chunk = pcfg.edges_per_bucket, pcfg.chunk_edges
        start = self.i * eps
        for lo in range(start, start + eps, chunk):
            cnt = min(chunk, start + eps - lo)
            s, d = rmat_edges_np_cfg(pcfg, lo, cnt)
            self.gauge.track(s.size)
            yield s, d


def gen_relabel_recompute_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                                 ledger: IOLedger,
                                 gauge: Optional[MemoryGauge] = None,
                                 transport: Optional[Transport] = None):
    """Fused generate+relabel for shuffle_variant='recompute' (Funke et
    al. taken to its conclusion): regenerate bucket i's raw edges chunk by
    chunk from the counter-based RNG and pipe them straight through the
    hash-evaluating relabel into owner(perm(src))'s inbox — the raw-edge
    store is never written.  Wire bytes and inbox contents are bit-identical
    to generate_bucket_edges + relabel_recompute_bucket because _RegenRuns
    reproduces the exact run boundaries; what changes is the task's
    footprint: zero local reads, zero local writes, so the scheduler may
    hand it to ANY host (stealable) without migrating data."""
    if pcfg.shuffle_variant != "recompute":
        raise ValueError("gen_relabel_recompute_bucket requires "
                         f"shuffle_variant='recompute', got "
                         f"{pcfg.shuffle_variant!r}")
    B = pcfg.bucket_size

    def relabel(s, d):
        ledger.hashes(s.size + d.size)
        return (graph_perm_np(pcfg.seed, s, pcfg.n, rounds=pcfg.feistel_rounds),
                graph_perm_np(pcfg.seed, d, pcfg.n, rounds=pcfg.feistel_rounds))

    src = _RegenRuns(pcfg, i, ledger, gauge)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(owned_store_name, pcfg.nb)
        partition_runs(src, outs, lambda a, b: a // B,
                       tag_prefix=f"{i:03d}", transform=relabel,
                       overlap=pcfg.io_overlap)


def relabel_scatter_bucket(pcfg: PlainCfg, workdir: str, i: int, pass_ix: int, *,
                           ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                           transport: Optional[Transport] = None):
    """Relabel pass `pass_ix`, scatter half (paper Alg. 6): ship each record
    through the transport to the owner of its key field (column 1) so the
    owner can join it against its pv bucket.  Bucket partition = sequential
    scan + stable chunk sort."""
    B = pcfg.bucket_size
    in_name = edges_store_name(i) if pass_ix == 0 else edges_store_name(i, pass_ix - 1)
    store = BlockStore.attach(workdir, in_name, ledger, gauge=gauge)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(lambda j: relabel_inbox_name(pass_ix, j), pcfg.nb)
        partition_runs(store, outs, lambda a, b: b // B, tag_prefix=f"{i:03d}",
                       overlap=pcfg.io_overlap)


def relabel_apply_bucket(pcfg: PlainCfg, workdir: str, i: int, pass_ix: int, *,
                         ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                         transport: Optional[Transport] = None):
    """Relabel pass `pass_ix`, join half (paper Alg. 7): external-sort my
    inbox by the key field, stream pv blocks past it (sort-merge-join), emit
    (pv[key], other) — the column swap makes pass 1 relabel dst and pass 2
    relabel src with identical code."""
    B, chunk = pcfg.bucket_size, pcfg.chunk_edges
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        inbox = tr.drain_inbox(relabel_inbox_name(pass_ix, i))   # post-barrier
    tmp = BlockStore(workdir, relabel_inbox_name(pass_ix, i) + "_sorted", ledger,
                     gauge=gauge, fresh=True)
    sort_runs(inbox, tmp, key=1, overlap=pcfg.io_overlap)
    pv = BlockStore.attach(workdir, pv_store_name(pcfg.rounds, i), ledger,
                           columns=("v",), gauge=gauge)
    lookup = MonotoneLookup([pv], block_rows=chunk, base=i * B, gauge=gauge)
    out = BlockStore(workdir, edges_store_name(i, pass_ix), ledger, gauge=gauge, fresh=True)
    with write_behind([out], ledger, gauge, enabled=pcfg.io_overlap) as sinks:
        for a, b in merge_runs(tmp, key=1, block_rows=pcfg.merge_block_rows,
                               max_fanin=pcfg.merge_fanin,
                               overlap=pcfg.io_overlap):
            sinks[0].append_run(lookup.lookup(b), a)
    tmp.destroy()
    inbox.destroy()


def relabel_sort_bucket(pcfg: PlainCfg, workdir: str, i: int, pass_ix: int, *,
                        ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                        transport: Optional[Transport] = None) -> int:
    """Pooled-cascade relabel join, phase 1 of 3 (the csr_sort twin): sort
    pass 1 over the relabel inbox, each run sorted by the key field.
    Returns the run count for the driver's cascade plan; the inbox is freed
    by the PHASE's `frees` (after the checkpoint write), never in-kernel."""
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        inbox = tr.drain_inbox(relabel_inbox_name(pass_ix, i))
    out = BlockStore(workdir, relabel_inbox_name(pass_ix, i) + "_sorted",
                     ledger, gauge=gauge, fresh=True)
    sort_runs(inbox, out, key=1, overlap=pcfg.io_overlap)
    return out.num_runs


def relabel_join_bucket(pcfg: PlainCfg, workdir: str, i: int, pass_ix: int,
                        src_name: str, presorted: bool, *,
                        ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                        transport: Optional[Transport] = None):
    """Pooled-cascade relabel join, final phase: the sort-merge-join of
    relabel_apply_bucket, fed from `src_name` (the cascade's last level when
    `presorted`, else a flat bounded merge of the sorted runs)."""
    B, chunk = pcfg.bucket_size, pcfg.chunk_edges
    src = BlockStore.attach(workdir, src_name, ledger, gauge=gauge)
    if presorted:
        stream = merge_segments([(src, list(range(src.num_runs)))], key=1,
                                block_rows=pcfg.merge_block_rows,
                                overlap=pcfg.io_overlap)
    else:
        stream = merge_runs(src, key=1, block_rows=pcfg.merge_block_rows,
                            max_fanin=pcfg.merge_fanin,
                            overlap=pcfg.io_overlap)
    pv = BlockStore.attach(workdir, pv_store_name(pcfg.rounds, i), ledger,
                           columns=("v",), gauge=gauge)
    lookup = MonotoneLookup([pv], block_rows=chunk, base=i * B, gauge=gauge)
    out = BlockStore(workdir, edges_store_name(i, pass_ix), ledger, gauge=gauge,
                     fresh=True)
    with write_behind([out], ledger, gauge, enabled=pcfg.io_overlap) as sinks:
        for a, b in stream:
            sinks[0].append_run(lookup.lookup(b), a)


def redistribute_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                        ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                        transport: Optional[Transport] = None):
    """Paper Alg. 8-9: ship each relabeled edge to owner(new_src) through
    the transport."""
    B = pcfg.bucket_size
    store = BlockStore.attach(workdir, edges_store_name(i, 1), ledger, gauge=gauge)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(owned_store_name, pcfg.nb)
        partition_runs(store, outs, lambda a, b: a // B, tag_prefix=f"{i:03d}",
                       overlap=pcfg.io_overlap)


def csr_bucket_sorted(pcfg: PlainCfg, workdir: str, i: int, *,
                      ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                      in_name: Optional[str] = None,
                      transport: Optional[Transport] = None) -> Tuple[str, str]:
    """§III-B7: external sort owned edges by src, then one sequential pass
    emits degrees + adjacency.  adjv streams straight into a memmap — the
    adjacency never materializes in RAM.  `in_name` overrides the input
    store (the sequential driver's owner stores are named differently)."""
    B, base = pcfg.bucket_size, i * pcfg.bucket_size
    if in_name is None:
        in_name = owned_store_name(i)
    key = csr_merge_key(pcfg)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        owned = tr.drain_inbox(in_name)   # redistribute's multi-writer inbox
    tmp = BlockStore(workdir, in_name + "_sorted", ledger, gauge=gauge, fresh=True)
    sort_runs(owned, tmp, key=key, overlap=pcfg.io_overlap)
    degv = np.zeros(B, np.int64)
    if gauge is not None:
        gauge.track(B)
    adjv_path = csr_adjv_path(workdir, i)
    total = tmp.total_rows()
    adjv = np.lib.format.open_memmap(adjv_path, mode="w+", dtype=np.int64, shape=(total,))
    pos = 0
    for s, d in merge_runs(tmp, key=key, block_rows=pcfg.merge_block_rows,
                           max_fanin=pcfg.merge_fanin,
                           overlap=pcfg.io_overlap):
        np.add.at(degv, s - base, 1)
        adjv[pos : pos + d.size] = d
        ledger.write(d.nbytes)
        pos += d.size
    adjv.flush()
    del adjv
    offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
    offv_path = csr_offv_path(workdir, i)
    np.save(offv_path, offv)
    ledger.write(offv.nbytes)
    tmp.destroy()
    return offv_path, adjv_path


def _emit_csr(pcfg: PlainCfg, workdir: str, i: int, stream, total: int, *,
              ledger: IOLedger, gauge: Optional[MemoryGauge]) -> Tuple[str, str]:
    """Shared CSR emit tail: one pass over a src-sorted (s, d) stream writes
    degrees + adjacency; adjv streams straight into a memmap (§III-B7)."""
    B, base = pcfg.bucket_size, i * pcfg.bucket_size
    degv = np.zeros(B, np.int64)
    if gauge is not None:
        gauge.track(B)
    adjv_path = csr_adjv_path(workdir, i)
    adjv = np.lib.format.open_memmap(adjv_path, mode="w+", dtype=np.int64,
                                     shape=(total,))
    pos = 0
    for s, d in stream:
        np.add.at(degv, s - base, 1)
        adjv[pos : pos + d.size] = d
        ledger.write(d.nbytes)
        pos += d.size
    adjv.flush()
    del adjv
    offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
    offv_path = csr_offv_path(workdir, i)
    np.save(offv_path, offv)
    ledger.write(offv.nbytes)
    return offv_path, adjv_path


def csr_sort_bucket(pcfg: PlainCfg, workdir: str, i: int, *,
                    ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                    transport: Optional[Transport] = None) -> int:
    """Pooled-cascade CSR, phase 1 of 3: external-sort pass 1 over the owned
    inbox (each run sorted by src, rewritten).  Returns the run count — the
    driver plans the cascade levels from it, and the count rides the phase
    manifest so a resumed run plans identically."""
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        owned = tr.drain_inbox(owned_store_name(i))
    out = BlockStore(workdir, sorted_owned_store_name(i), ledger, gauge=gauge,
                     fresh=True)
    sort_runs(owned, out, key=csr_merge_key(pcfg), overlap=pcfg.io_overlap)
    return out.num_runs


def cascade_merge_bucket(pcfg: PlainCfg, workdir: str, i: int, base: str,
                         level: int, g: int, lo: int, hi: int, key=0, *,
                         ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                         transport: Optional[Transport] = None):
    """One GROUP of one cascade level, as a pool task (PR 3's "intermediate
    levels are embarrassingly parallel" upside): merge consecutive sorted
    segments [lo, hi) of `base`'s level-1 into the level-`level` group store.
    At level 0 a segment is one run of the `base` store; above that it is a
    whole previous-level group store (its runs back to back).  Stability +
    consecutive grouping keep the result bit-identical to merge_runs' inline
    cascade — and to the flat merge.  `key` is a wire-safe spec (an int
    column, or "csr" for the config-dependent CSR key) so the same task
    tuple serializes to JSON for cluster dispatch."""
    key = resolve_merge_key(pcfg, key)
    if level == 0:
        src = BlockStore.attach(workdir, base, ledger, gauge=gauge)
        segments = [(src, [k]) for k in range(lo, hi)]
    else:
        segments = []
        for k in range(lo, hi):
            s = BlockStore.attach(
                workdir, pooled_cascade_store_name(base, level - 1, k),
                ledger, gauge=gauge)
            segments.append((s, list(range(s.num_runs))))
    out = BlockStore(workdir, pooled_cascade_store_name(base, level, g),
                     ledger, gauge=gauge, fresh=True)
    with write_behind([out], ledger, gauge, enabled=pcfg.io_overlap) as sinks:
        for cols in merge_segments(segments, key=key,
                                   block_rows=pcfg.merge_block_rows,
                                   overlap=pcfg.io_overlap):
            sinks[0].append_run(*cols)


def csr_emit_bucket(pcfg: PlainCfg, workdir: str, i: int, src_name: str,
                    presorted: bool, *,
                    ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                    transport: Optional[Transport] = None) -> Tuple[str, str]:
    """Pooled-cascade CSR, final phase: emit offv/adjv from `src_name`.
    `presorted` means the store is one globally sorted segment (the cascade's
    last level) and is streamed; otherwise its runs are merged flat."""
    key = csr_merge_key(pcfg)
    src = BlockStore.attach(workdir, src_name, ledger, gauge=gauge)
    if presorted:
        stream = merge_segments([(src, list(range(src.num_runs)))], key=key,
                                block_rows=pcfg.merge_block_rows,
                                overlap=pcfg.io_overlap)
    else:
        stream = merge_runs(src, key=key, block_rows=pcfg.merge_block_rows,
                            max_fanin=pcfg.merge_fanin,
                            overlap=pcfg.io_overlap)
    return _emit_csr(pcfg, workdir, i, stream, src.total_rows(),
                     ledger=ledger, gauge=gauge)


def csr_bucket_scatter(pcfg: PlainCfg, workdir: str, i: int, *,
                       ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                       in_name: Optional[str] = None,
                       transport: Optional[Transport] = None) -> Tuple[str, str]:
    """Paper Alg. 10-11 under real process parallelism: unordered scan of the
    owned edges with a bounded associative map, flushed into a memmap'd adjv
    — every flush is a RANDOM write burst (the Fig. 2 blowup, now measurable
    per worker).  Emits the same csr_offv/csr_adjv files as the sorted
    variant; within-row adjacency is encounter order, which equals the
    sorted variant's stable order, so the FILES are bit-identical — only the
    I/O ledger (random vs sequential writes) differs."""
    if pcfg.perm_family == "feistel":
        # Under the feistel family the sorted variant orders adjacency by
        # (src, dst) — encounter order no longer matches it, so the
        # files-bit-identical contract between the CSR variants would break.
        raise ValueError(
            "csr 'scatter' emits adjacency in encounter order, which "
            "perm_family='feistel' does not preserve; use csr_variant="
            "'sorted'")
    B, base = pcfg.bucket_size, i * pcfg.bucket_size
    if in_name is None:
        in_name = owned_store_name(i)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        owned = tr.drain_inbox(in_name)
    flush_at = max(16, pcfg.chunk_edges // 256)  # the paper's mmc analogue
    degv = np.zeros(B, np.int64)
    if gauge is not None:
        gauge.track(B)
    # Degree pass streams block-sized buffers, not whole runs: iter_runs
    # would load each run file entirely (read_run's documented whole-run
    # contract), spiking residency to the largest run instead of one chunk.
    for s, _ in owned.iter_blocks(pcfg.chunk_edges):
        np.add.at(degv, s - base, 1)
    offv = np.concatenate([[0], np.cumsum(degv)]).astype(np.int64)
    adjv_path = csr_adjv_path(workdir, i)
    adjv = np.lib.format.open_memmap(adjv_path, mode="w+", dtype=np.int64,
                                     shape=(int(offv[-1]),))
    cursor = np.zeros(B, np.int64)
    held_map: Dict[int, list] = {}
    held = 0

    def _flush():
        for v, lst in held_map.items():  # random write per vertex
            o = offv[v] + cursor[v]
            adjv[o : o + len(lst)] = lst
            cursor[v] += len(lst)
            ledger.write(8 * len(lst), sequential=False)

    for s, d in owned.iter_blocks(pcfg.chunk_edges):
        for sv, dv in zip((s - base).tolist(), d.tolist()):
            held_map.setdefault(sv, []).append(dv)
            held += 1
            if held >= flush_at:
                _flush()
                held_map, held = {}, 0
    _flush()
    adjv.flush()
    del adjv
    offv_path = csr_offv_path(workdir, i)
    np.save(offv_path, offv)
    ledger.write(offv.nbytes)
    return offv_path, adjv_path


# Checkpoint helpers shared by every driver-level phase whose manifest is
# just a completion mark (the filesystem is the real manifest).
_MARK = lambda _res: {"done": True}   # noqa: E731
_SKIP = lambda _m: None               # noqa: E731


def drive_shuffle(pcfg: PlainCfg, workdir: str, map_kernel,
                  orchestrator: Optional["PhaseOrchestrator"] = None,
                  transport: Optional[Transport] = None) -> None:
    """The shuffle round loop, shared by all drivers.  `map_kernel(name,
    argss)` runs one bucket kernel for every args tuple and acts as the
    barrier.  Receiver stores are multi-writer, so each round's outputs are
    cleaned BEFORE the senders run — a correctness invariant for BOTH
    transports (attach() would merge in stale runs from a previous attempt;
    a partial socket frame would linger as a `.part` stray).  The driver's
    `transport` carries the clean to whichever host owns each inbox.

    With `orchestrator` set (cluster mode), every clean and every round
    barrier is its OWN checkpointed phase.  The split matters for per-host
    resume: when a phase reruns because one host died mid-barrier, hosts
    that already completed it skip their kernels — so the clean must NOT
    rerun (it would delete the completed hosts' already-delivered runs),
    while the dead host's reruns are safe on the dirty inbox because run
    tags and contents are deterministic (idempotent overwrite)."""
    def step(name, fn):
        if orchestrator is None:
            return fn()
        return orchestrator.run_phase(name, fn, save=_MARK, load=_SKIP)

    if pcfg.perm_family == "feistel":
        # A recomputable permutation needs no shuffling to exist: one local
        # phase writes every pv bucket directly (zero exchange rounds, zero
        # wire bytes).  Kept under the "shuffle_init" phase name so ledger
        # reports line up across families.
        step("shuffle_init",
             lambda: map_kernel("pv_feistel", [(i,) for i in range(pcfg.nb)]))
        return

    with _exchange(pcfg, workdir, IOLedger(), None, transport) as tr:
        step("shuffle_init",
             lambda: map_kernel("init_pv", [(i,) for i in range(pcfg.nb)]))
        for r in range(pcfg.rounds):
            step(f"shuffle_clean_r{r}",
                 lambda r=r: tr.clean_inboxes(
                     [pv_store_name(r + 1, j) for j in range(pcfg.nb)]))
            step(f"shuffle_round_r{r}",
                 lambda r=r: map_kernel("shuffle_round",
                                        [(i, r) for i in range(pcfg.nb)]))


def pooled_cascade_levels(pcfg: PlainCfg, orch: "PhaseOrchestrator",
                          map_kernel, counts: Dict[int, int], base_of,
                          phase_prefix: str, key=0) -> Dict[int, Tuple[str, bool]]:
    """Dispatch a bounded-fan-in merge cascade's LEVELS through the worker
    pool / cluster — the shared core of the pooled CSR sort, the pooled
    relabel join, and the pooled walk hops (PR 3's "intermediate levels are
    embarrassingly parallel" upside, generalized).  `counts[i]` is the
    sorted-run count of `base_of(i)`; each level is one checkpointed barrier
    (`{phase_prefix}_cascade_l{level}`) whose tasks are that level's
    (bucket, group) merges, keyed by the wire-safe `key` spec.  Returns
    {i: (src_name, presorted)} for the consumer phase: the final cascade
    store (presorted) or the untouched base when it never cascaded.
    Stability + consecutive grouping keep the result bit-identical to the
    inline cascade and to the flat merge."""
    fanin = pcfg.merge_fanin
    seg = dict(counts)
    last_level: Dict[int, Optional[int]] = {i: None for i in seg}
    level = 0
    while fanin >= 2 and any(c > 1 for c in seg.values()):
        tasks, frees, plan = [], [], {}
        for i in sorted(seg):
            c = seg[i]
            if c <= 1:
                continue
            base = base_of(i)
            ng = -(-c // fanin)
            for g in range(ng):
                tasks.append((i, base, level, g, g * fanin,
                              min((g + 1) * fanin, c), key))
            plan[i] = ng
            # This level is the last consumer of its input segments.
            if level == 0:
                frees.append(base)
            else:
                frees += [pooled_cascade_store_name(base, level - 1, k)
                          for k in range(c)]
        orch.run_phase(
            f"{phase_prefix}_cascade_l{level}",
            lambda tasks=tasks: map_kernel("cascade_merge", tasks),
            save=_MARK, load=_SKIP, frees=frees)
        for i, ng in plan.items():
            seg[i] = ng
            last_level[i] = level
        level += 1
    out: Dict[int, Tuple[str, bool]] = {}
    for i in sorted(seg):
        if last_level[i] is None:
            # Never cascaded: <= 1 sorted run (stream) — or fanin == 0
            # (flat), where the consumer merges the runs inline.
            out[i] = (base_of(i), seg[i] <= 1)
        else:
            out[i] = (pooled_cascade_store_name(base_of(i), last_level[i], 0),
                      True)
    return out


# ---------------------------------------------------------------------------
# Out-of-core random walks (the redistribute phase re-run once per hop)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WalkCfg:
    """Picklable walk-corpus parameters (the walk twin of PlainCfg).

    Walk semantics are the data/walks.py contract: counter RNG keyed by
    (seed, walker_id, step), sink vertices teleport to rand % n, histories
    are int64.  `out_name` names the corpus: per-bucket shard files
    `{stem}_b{j}.npy` (each holding its walker block's rows of the logical
    [num_walkers, length + 1] corpus) plus the `{stem}_manifest.json` that
    ties them together (core/corpus.py)."""

    num_walkers: int
    length: int
    seed: int = 0
    out_name: str = "walks.npy"
    # Store-name prefix isolating this config's frontier/history stores when
    # several walk configs advance through ONE fused CSR scan per hop
    # (walk_hop_fused / drive_walks_fused — the job queue's batched-seeds
    # upside); "" is the classic un-prefixed single-config layout.
    ns: str = ""


def walker_block(wcfg: WalkCfg, nb: int, j: int) -> Tuple[int, int]:
    """Walker-id range [w0, w1) whose history bucket j collects (blocks of
    ceil(W/nb) ids; owner(w) = w // block)."""
    wpb = -(-wcfg.num_walkers // nb)
    return min(j * wpb, wcfg.num_walkers), min((j + 1) * wpb, wcfg.num_walkers)


def _gather_adjv(adjv_mm: np.ndarray, idx: np.ndarray, chunk: int,
                 ledger: IOLedger, gauge: MemoryGauge) -> np.ndarray:
    """adjv[idx] for idx sorted by CSR row (the frontier's sort order), read
    as a strictly-forward scan of <=chunk-row blocks.  Within one row walkers
    land at random offsets, but rows are nondecreasing, so every block load
    moves forward — sequential I/O, bounded memory, and all of it ledgered."""
    order = np.argsort(idx, kind="stable")
    si = idx[order]
    out = np.empty(idx.shape[0], np.int64)
    i = 0
    while i < si.size:
        lo = int(si[i])
        hi_ix = int(np.searchsorted(si, lo + chunk, side="left"))
        hi = int(si[hi_ix - 1]) + 1
        blk = np.asarray(adjv_mm[lo:hi], np.int64)
        ledger.read(blk.nbytes)
        gauge.track(blk.shape[0])
        out[order[i:hi_ix]] = blk[si[i:hi_ix] - lo]
        i = hi_ix
    return out


def walk_init_bucket(pcfg: PlainCfg, workdir: str, j: int, wcfg: WalkCfg, *,
                     ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                     transport: Optional[Transport] = None):
    """Launch bucket j's walker block: deterministic start vertices, step-0
    history rows, and the step-0 frontier exchange (partition_runs to the
    owner bucket of each start — paper Alg. 8 with walkers for edges)."""
    gauge = gauge if gauge is not None else MemoryGauge()
    B, chunk = pcfg.bucket_size, pcfg.chunk_edges
    w0, w1 = walker_block(wcfg, pcfg.nb, j)
    hist = BlockStore(workdir, whist_store_name(0, j, wcfg.ns), ledger,
                      columns=("wid", "step", "v"), gauge=gauge, fresh=True)
    adv = BlockStore(workdir, f"{wcfg.ns}wadv_init_b{j:03d}", ledger,
                     columns=("pos", "wid"), gauge=gauge, fresh=True)
    for lo in range(w0, w1, chunk):
        hi = min(lo + chunk, w1)
        wid = np.arange(lo, hi, dtype=np.int64)
        pos = walk_start_np(wcfg.seed, wid.astype(np.uint32), pcfg.n)
        hist.append_run(wid, np.zeros(wid.size, np.int64), pos)
        adv.append_run(pos, wid)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(lambda d: wfront_store_name(0, d, wcfg.ns), pcfg.nb,
                           columns=("pos", "wid"))
        partition_runs(adv, outs, lambda p, w: p // B, tag_prefix=f"{j:03d}",
                       overlap=pcfg.io_overlap)
    adv.destroy()


def walk_hop_bucket(pcfg: PlainCfg, workdir: str, j: int, t: int, wcfg: WalkCfg, *,
                    ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                    transport: Optional[Transport] = None):
    """Advance every walker currently owned by bucket j one hop (step t+1).

    The paper's discipline applied to traversal: (i) external-sort the
    frontier inbox by current vertex, (ii) sort-merge-join it against the
    bucket's CSR — offv probed through two MonotoneLookups (row starts and
    row ends both advance monotonically), adjv gathered as a forward scan —
    and (iii) partition the advanced walkers through the transport to their
    new owner's step-t+1 inbox.  Every access is a bounded sequential block;
    no random CSR I/O.
    """
    gauge = gauge if gauge is not None else MemoryGauge()
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        front = tr.drain_inbox(wfront_store_name(t, j, wcfg.ns),
                               columns=("pos", "wid"))
        tmp = BlockStore(workdir, wfront_store_name(t, j, wcfg.ns) + "_sorted",
                         ledger, columns=("pos", "wid"), gauge=gauge, fresh=True)
        sort_runs(front, tmp, key=0, overlap=pcfg.io_overlap)
        stream = merge_runs(tmp, key=0, block_rows=pcfg.merge_block_rows,
                            max_fanin=pcfg.merge_fanin,
                            overlap=pcfg.io_overlap)
        _walk_advance(pcfg, workdir, j, t, wcfg, stream, tr,
                      ledger=ledger, gauge=gauge)
        tmp.destroy()


class _HopEmitter:
    """One walk config's sinks for one hop of bucket j: the step-t+1 history
    store and (unless this is the last hop) the advance store that gets
    partitioned to the next frontier.  `emit` consumes merged (pos, wid)
    chunks in nondecreasing pos order against CALLER-OWNED CSR cursors —
    which is what lets walk_hop_fused_bucket advance several configs through
    ONE shared scan of offv/adjv (one emitter per config, one cursor set)."""

    def __init__(self, pcfg: PlainCfg, workdir: str, j: int, t: int,
                 wcfg: WalkCfg, ledger: IOLedger, gauge: MemoryGauge):
        self.pcfg, self.wcfg, self.j, self.t = pcfg, wcfg, j, t
        self.base = j * pcfg.bucket_size
        self.ledger, self.gauge = ledger, gauge
        self.hist = BlockStore(workdir, whist_store_name(t + 1, j, wcfg.ns),
                               ledger, columns=("wid", "step", "v"),
                               gauge=gauge, fresh=True)
        self.adv = None
        if t + 1 < wcfg.length:
            self.adv = BlockStore(workdir,
                                  f"{wcfg.ns}wadv_s{t:04d}_b{j:03d}", ledger,
                                  columns=("pos", "wid"), gauge=gauge,
                                  fresh=True)

    def emit(self, pos: np.ndarray, wid: np.ndarray,
             lk_lo: MonotoneLookup, lk_hi: MonotoneLookup,
             adjv_mm: np.ndarray) -> None:
        pcfg, wcfg, t = self.pcfg, self.wcfg, self.t
        row = pos - self.base
        start = lk_lo.lookup(row)
        end = lk_hi.lookup(row + 1)
        deg = end - start
        r = walk_rand_np(wcfg.seed, wid.astype(np.uint32),
                         t + 1).astype(np.int64)
        sink = deg == 0
        idx = start + np.where(sink, 0, r % np.maximum(deg, 1))
        nxt = np.where(sink, r % pcfg.n, 0).astype(np.int64)
        live = ~sink
        if live.any():
            nxt[live] = _gather_adjv(adjv_mm, idx[live], pcfg.chunk_edges,
                                     self.ledger, self.gauge)
        self.hist.append_run(wid, np.full(wid.size, t + 1, np.int64), nxt)
        if self.adv is not None:
            self.adv.append_run(nxt, wid)

    def finish(self, tr: Transport) -> None:
        if self.adv is None:
            return
        pcfg, t, ns = self.pcfg, self.t, self.wcfg.ns
        outs = tr.channels(lambda d: wfront_store_name(t + 1, d, ns),
                           pcfg.nb, columns=("pos", "wid"))
        partition_runs(self.adv, outs,
                       lambda p, w: p // pcfg.bucket_size,
                       tag_prefix=f"{self.j:03d}",
                       overlap=pcfg.io_overlap)
        self.adv.destroy()


def _csr_cursors(pcfg: PlainCfg, workdir: str, j: int, ledger: IOLedger,
                 gauge: MemoryGauge):
    """Bucket j's hop-join read state: two offv cursors + the adjv memmap.
    Two independent offv cursors, one per row end: a single interleaved
    probe stream (row, row+1, row', row'+1, ...) is NOT monotone when
    consecutive walkers share a vertex (5,6,5,6), so the 2x offv scan is
    the price of keeping each stream strictly nondecreasing."""
    offv_file = csr_offv_path(workdir, j)
    chunk = pcfg.chunk_edges
    lk_lo = MonotoneLookup([NpyColumnStore(offv_file, ledger, gauge)],
                           block_rows=chunk, gauge=gauge)
    lk_hi = MonotoneLookup([NpyColumnStore(offv_file, ledger, gauge)],
                           block_rows=chunk, gauge=gauge)
    adjv_mm = np.load(csr_adjv_path(workdir, j), mmap_mode="r")
    return lk_lo, lk_hi, adjv_mm


def _walk_advance(pcfg: PlainCfg, workdir: str, j: int, t: int, wcfg: WalkCfg,
                  stream, tr: Transport, *,
                  ledger: IOLedger, gauge: MemoryGauge):
    """The hop's join+advance tail, shared by walk_hop_bucket (inline sort)
    and walk_hop_join_bucket (pooled cascade): sort-merge-join the
    vertex-sorted frontier `stream` against bucket j's CSR, emit step-t+1
    history rows, and partition the advanced walkers to their new owners."""
    lk_lo, lk_hi, adjv_mm = _csr_cursors(pcfg, workdir, j, ledger, gauge)
    em = _HopEmitter(pcfg, workdir, j, t, wcfg, ledger, gauge)
    for pos, wid in stream:
        em.emit(pos, wid, lk_lo, lk_hi, adjv_mm)
    em.finish(tr)


def walk_hop_fused_bucket(pcfg: PlainCfg, workdir: str, j: int, t: int,
                          wcfgs: Sequence[WalkCfg], *,
                          ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                          transport: Optional[Transport] = None):
    """Advance SEVERAL independent walk configs (different seeds/widths,
    same length, distinct ns prefixes) one hop through bucket j with ONE
    scan of the bucket's CSR — the PR 2 upside: hop phases for different
    corpora are independent, so their sorted frontiers k-way merge at chunk
    granularity into a single globally nondecreasing pos stream that shares
    one pair of offv MonotoneLookup cursors and one adjv memmap.

    Per config the outputs (history rows, next frontier frames) are
    bit-identical to running walk_hop_bucket alone: each config keeps its
    own _HopEmitter (own RNG stream, own ns-prefixed stores), and the merge
    only decides the interleaving — which the corpus gather erases by
    sorting on the unique wid*(L+1)+step key."""
    gauge = gauge if gauge is not None else MemoryGauge()
    wcfgs = list(wcfgs)
    if len({w.ns for w in wcfgs}) != len(wcfgs):
        raise ValueError("walk_hop_fused_bucket: walk configs must carry "
                         "distinct ns prefixes")
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        tmps, heads = [], []
        for w in wcfgs:
            front = tr.drain_inbox(wfront_store_name(t, j, w.ns),
                                   columns=("pos", "wid"))
            tmp = BlockStore(workdir,
                             wfront_store_name(t, j, w.ns) + "_sorted",
                             ledger, columns=("pos", "wid"), gauge=gauge,
                             fresh=True)
            sort_runs(front, tmp, key=0, overlap=pcfg.io_overlap)
            tmps.append(tmp)
            stream = merge_runs(tmp, key=0, block_rows=pcfg.merge_block_rows,
                                max_fanin=pcfg.merge_fanin,
                                overlap=pcfg.io_overlap)
            # head = [stream, pos_chunk, wid_chunk, offset] or None (drained)
            try:
                pos, wid = next(stream)
                heads.append([stream, pos, wid, 0])
            except StopIteration:
                heads.append(None)
        lk_lo, lk_hi, adjv_mm = _csr_cursors(pcfg, workdir, j, ledger, gauge)
        ems = [_HopEmitter(pcfg, workdir, j, t, w, ledger, gauge)
               for w in wcfgs]
        while True:
            live = [s for s, h in enumerate(heads) if h is not None]
            if not live:
                break
            # Chunk-level k-way merge: pick the stream whose head value is
            # minimal (ties to the lowest stream id), then emit its longest
            # head-chunk prefix that stays below every OTHER live head —
            # `<= other` when we win the tie (other id higher), `< other`
            # when the other would (id lower).  The chosen head's first
            # value always qualifies, so every round makes progress, and
            # the concatenated emits are globally nondecreasing in pos —
            # exactly the monotonicity the shared cursors need.
            s_star = min(live,
                         key=lambda s: (int(heads[s][1][heads[s][3]]), s))
            stream, pos, wid, off = heads[s_star]
            cut = None
            for o in live:
                if o == s_star:
                    continue
                bound = int(heads[o][1][heads[o][3]]) + (1 if o > s_star else 0)
                cut = bound if cut is None else min(cut, bound)
            hi = pos.size if cut is None else int(
                np.searchsorted(pos[off:], cut, side="left")) + off
            ems[s_star].emit(pos[off:hi], wid[off:hi], lk_lo, lk_hi, adjv_mm)
            if hi < pos.size:
                heads[s_star][3] = hi
            else:
                try:
                    npos, nwid = next(stream)
                    heads[s_star] = [stream, npos, nwid, 0]
                except StopIteration:
                    heads[s_star] = None
        for em, tmp in zip(ems, tmps):
            em.finish(tr)
            tmp.destroy()


def walk_hop_sort_bucket(pcfg: PlainCfg, workdir: str, j: int, t: int,
                         wcfg: WalkCfg, *,
                         ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                         transport: Optional[Transport] = None) -> int:
    """Pooled-cascade walk hop, phase 1 of 3: sort pass over bucket j's
    step-t frontier inbox.  Returns the run count for the cascade plan."""
    gauge = gauge if gauge is not None else MemoryGauge()
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        front = tr.drain_inbox(wfront_store_name(t, j, wcfg.ns),
                               columns=("pos", "wid"))
    out = BlockStore(workdir, wfront_store_name(t, j, wcfg.ns) + "_sorted",
                     ledger, columns=("pos", "wid"), gauge=gauge, fresh=True)
    sort_runs(front, out, key=0, overlap=pcfg.io_overlap)
    return out.num_runs


def walk_hop_join_bucket(pcfg: PlainCfg, workdir: str, j: int, t: int,
                         src_name: str, presorted: bool, wcfg: WalkCfg, *,
                         ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                         transport: Optional[Transport] = None):
    """Pooled-cascade walk hop, final phase: advance from `src_name` (the
    cascade's last level when `presorted`, else a flat bounded merge).
    `wcfg` stays the LAST positional arg — the cluster wire protocol
    extracts and re-appends WalkCfg there."""
    gauge = gauge if gauge is not None else MemoryGauge()
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        src = BlockStore.attach(workdir, src_name, ledger,
                                columns=("pos", "wid"), gauge=gauge)
        if presorted:
            stream = merge_segments([(src, list(range(src.num_runs)))], key=0,
                                    block_rows=pcfg.merge_block_rows,
                                    overlap=pcfg.io_overlap)
        else:
            stream = merge_runs(src, key=0, block_rows=pcfg.merge_block_rows,
                                max_fanin=pcfg.merge_fanin,
                                overlap=pcfg.io_overlap)
        _walk_advance(pcfg, workdir, j, t, wcfg, stream, tr,
                      ledger=ledger, gauge=gauge)


def walk_hist_scatter_bucket(pcfg: PlainCfg, workdir: str, j: int, wcfg: WalkCfg, *,
                             ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                             transport: Optional[Transport] = None):
    """Collect phase, scatter half: ship every history row bucket j emitted
    through the transport to the walker-block owner of its walker id."""
    gauge = gauge if gauge is not None else MemoryGauge()
    wpb = -(-wcfg.num_walkers // pcfg.nb)
    with _exchange(pcfg, workdir, ledger, gauge, transport) as tr:
        outs = tr.channels(lambda d: whist_inbox_name(d, wcfg.ns), pcfg.nb,
                           columns=("wid", "step", "v"))
        for s in range(wcfg.length + 1):
            src = BlockStore.attach(workdir, whist_store_name(s, j, wcfg.ns),
                                    ledger, columns=("wid", "step", "v"),
                                    gauge=gauge)
            partition_runs(src, outs, lambda w, st, v: w // wpb,
                           tag_prefix=f"{j:03d}_{s:04d}",
                           overlap=pcfg.io_overlap)


def walk_hist_gather_bucket(pcfg: PlainCfg, workdir: str, j: int, wcfg: WalkCfg, *,
                            ledger: IOLedger, gauge: Optional[MemoryGauge] = None,
                            transport: Optional[Transport] = None) -> str:
    """Collect phase, join half — SHARDED: external-sort bucket j's inbox by
    the flat key wid*(L+1)+step; the merged stream covers exactly the walker
    block's cells once each, so writing it out is one sequential pass over
    bucket j's OWN corpus shard (`{out}_b{j}.npy`, rows [w0, w1) of the
    corpus).  No workdir ever holds the full corpus — on a cluster each
    host keeps only its buckets' shards, and the driver's manifest
    (core/corpus.py) is the only global artifact."""
    gauge = gauge if gauge is not None else MemoryGauge()
    L = wcfg.length
    w0, w1 = walker_block(wcfg, pcfg.nb, j)
    shard_path = os.path.join(workdir, corpus_shard_name(wcfg.out_name, j))

    def key(w, s, v):
        return w * (L + 1) + s

    with _exchange(pcfg, workdir, ledger, gauge, transport) as _tr:
        inbox = _tr.drain_inbox(whist_inbox_name(j, wcfg.ns),
                                columns=("wid", "step", "v"))
    if w1 == w0:
        # Degenerate walker block (W < nb): an empty, valid shard.
        np.save(shard_path, np.zeros((0, L + 1), np.int64))
        return shard_path
    tmp = BlockStore(workdir, whist_inbox_name(j, wcfg.ns) + "_sorted", ledger,
                     columns=("wid", "step", "v"), gauge=gauge, fresh=True)
    sort_runs(inbox, tmp, key=key, overlap=pcfg.io_overlap)
    out = np.lib.format.open_memmap(shard_path, mode="w+", dtype=np.int64,
                                    shape=(w1 - w0, L + 1))
    flat = out.reshape(-1)
    base = w0 * (L + 1)
    for w, s, v in merge_runs(tmp, key=key, block_rows=pcfg.merge_block_rows,
                              max_fanin=pcfg.merge_fanin,
                              overlap=pcfg.io_overlap):
        flat[w * (L + 1) + s - base] = v
        ledger.write(v.nbytes)
    out.flush()
    del out
    tmp.destroy()
    return shard_path


def drive_walks(pcfg: PlainCfg, workdir: str, wcfg: WalkCfg, map_kernel,
                orchestrator: "PhaseOrchestrator",
                transport: Optional[Transport] = None,
                shard_dir_of=None, shard_host_of=None,
                fine_phases: bool = False) -> str:
    """The walk phase loop, shared by the inline driver (data/walks.py's
    external_walks), PartitionedGenerator.walk_corpus, and the cluster
    runtime.  `map_kernel` is the barrier, exactly as in drive_shuffle.
    Requires the csr_sorted phase outputs (csr_offv_*/csr_adjv_* bucket
    files) in each bucket owner's `workdir`.  Returns the path of the corpus
    MANIFEST (core/corpus.py); the corpus itself stays as per-bucket shard
    files written by the gather kernels — `shard_dir_of(j)` /
    `shard_host_of(j)` tell the manifest where bucket j's shard landed
    (default: this driver's workdir / host 0).

    Resume discipline: each phase pre-cleans its own multi-writer outputs
    through the driver's `transport` (stale runs AND partial frames from a
    crashed attempt, on whichever host owns the inbox) and the PREVIOUS
    phase's consumed frontier — inputs are never destroyed by the phase that
    reads them, so a phase can always be rerun after a mid-phase crash.
    With `fine_phases` (cluster mode) every clean is ITS OWN checkpointed
    phase, for the reason drive_shuffle documents: a rerun with per-host
    task skipping must not re-clean inboxes completed hosts already filled.
    walk_gc reclaims everything once the corpus shards are on disk.
    """
    nb, L = pcfg.nb, wcfg.length
    orch = orchestrator
    mark, skip = _MARK, _SKIP
    shard_dir_of = shard_dir_of if shard_dir_of is not None else (
        lambda j: workdir)
    shard_host_of = shard_host_of if shard_host_of is not None else (
        lambda j: 0)

    def phase(name, clean_fn, map_fn):
        """One barrier with its pre-senders clean: a single phase normally,
        split into `{name}_clean` + `{name}` under fine_phases."""
        if fine_phases:
            orch.run_phase(f"{name}_clean", clean_fn, save=mark, load=skip)
            orch.run_phase(name, map_fn, save=mark, load=skip)
        else:
            orch.run_phase(name, lambda: (clean_fn(), map_fn()),
                           save=mark, load=skip)

    with _exchange(pcfg, workdir, IOLedger(), None, transport) as tr:
        phase("walk_init",
              lambda: tr.clean_inboxes(
                  [wfront_store_name(0, d, wcfg.ns) for d in range(nb)]),
              lambda: map_kernel("walk_init", [(j, wcfg) for j in range(nb)]))
        for t in range(L):
            def _clean(t=t):
                if t > 0:
                    # Reclaim the PREVIOUS hop's consumed frontier (GC, not
                    # correctness: hop t-1 drained it already).
                    tr.clean_inboxes(
                        [wfront_store_name(t - 1, d, wcfg.ns)
                         for d in range(nb)])
                tr.clean_inboxes(
                    [wfront_store_name(t + 1, d, wcfg.ns) for d in range(nb)])

            if not pcfg.pooled_cascade:
                phase(f"walk_hop_{t:04d}", _clean,
                      lambda t=t: map_kernel("walk_hop",
                                             [(j, t, wcfg) for j in range(nb)]))
                continue
            # Pooled-cascade hop: sort barrier, cascade levels as (bucket,
            # group) pool tasks, then the join+advance barrier — the walk
            # twin of the pooled CSR sort.  Every step is its own
            # checkpointed phase (the clean separately, for the per-host
            # resume reason drive_shuffle documents).
            orch.run_phase(f"walk_hop_{t:04d}_clean", _clean,
                           save=mark, load=skip)
            counts = orch.run_phase(
                f"walk_sort_{t:04d}",
                lambda t=t: [int(c) for c in map_kernel(
                    "walk_hop_sort", [(j, t, wcfg) for j in range(nb)])],
                save=lambda r: {"counts": list(r)},
                load=lambda m: [int(c) for c in m["counts"]])
            srcs = pooled_cascade_levels(
                pcfg, orch, map_kernel, {j: counts[j] for j in range(nb)},
                lambda j, t=t: wfront_store_name(t, j, wcfg.ns) + "_sorted",
                f"walk_{t:04d}", key=0)
            orch.run_phase(
                f"walk_hop_{t:04d}",
                lambda t=t, srcs=srcs: map_kernel(
                    "walk_hop_join",
                    [(j, t, srcs[j][0], srcs[j][1], wcfg) for j in range(nb)]),
                save=mark, load=skip,
                frees=[srcs[j][0] for j in range(nb)])

        def _collect():
            map_kernel("walk_hist_scatter", [(j, wcfg) for j in range(nb)])
            map_kernel("walk_hist_gather", [(j, wcfg) for j in range(nb)])

        phase("walk_collect",
              lambda: tr.clean_inboxes([whist_inbox_name(d, wcfg.ns)
                                        for d in range(nb)]),
              _collect)

        manifest_path = os.path.join(workdir,
                                     corpus_manifest_name(wcfg.out_name))

        def _manifest():
            shards = []
            for j in range(nb):
                w0, w1 = walker_block(wcfg, nb, j)
                shards.append({
                    "bucket": j, "w0": w0, "w1": w1,
                    "host": shard_host_of(j),
                    "path": os.path.join(shard_dir_of(j),
                                         corpus_shard_name(wcfg.out_name, j)),
                })
            write_manifest(manifest_path, wcfg.num_walkers, L, shards)

        orch.run_phase("walk_manifest", _manifest, save=mark, load=skip)

        def _gc():
            # keep_all is the same debugging escape hatch _apply_frees
            # honors: the walk intermediates (frontiers, history stores)
            # stay on disk for inspection.
            if orch.keep_all:
                return
            names = []
            for d in range(nb):
                for t in range(L + 1):
                    names.append(wfront_store_name(t, d, wcfg.ns))
                    names.append(whist_store_name(t, d, wcfg.ns))
                names.append(whist_inbox_name(d, wcfg.ns))
            tr.clean_inboxes(names)

        orch.run_phase("walk_gc", _gc, save=mark, load=skip)
    return manifest_path


def drive_walks_fused(pcfg: PlainCfg, workdir: str, wcfgs: Sequence[WalkCfg],
                      map_kernel, orchestrator: "PhaseOrchestrator",
                      transport: Optional[Transport] = None,
                      shard_dir_of=None, shard_host_of=None,
                      fine_phases: bool = False) -> List[str]:
    """drive_walks for SEVERAL independent corpora at once: init/collect
    barriers batch all configs, and each hop is one walk_hop_fused barrier
    whose bucket tasks merge every config's frontier through a single CSR
    scan (the PR 2 carried upside — k corpora pay one offv/adjv pass per
    hop instead of k).  Configs must share `length` (hops are lockstep) and
    carry distinct, NONEMPTY ns prefixes plus distinct out_names; hops use
    the inline-sort variant (pooled_cascade does not apply here).  Returns
    the manifest path per config, in input order; each corpus is
    bit-identical to its own drive_walks run."""
    nb = pcfg.nb
    wcfgs = list(wcfgs)
    if not wcfgs:
        raise ValueError("drive_walks_fused: no walk configs")
    L = wcfgs[0].length
    if any(w.length != L for w in wcfgs):
        raise ValueError("drive_walks_fused: configs must share length "
                         f"(got {[w.length for w in wcfgs]})")
    if any(not w.ns for w in wcfgs) or len({w.ns for w in wcfgs}) != len(wcfgs):
        raise ValueError("drive_walks_fused: configs need distinct nonempty "
                         "ns prefixes")
    if len({w.out_name for w in wcfgs}) != len(wcfgs):
        raise ValueError("drive_walks_fused: configs need distinct out_names")
    orch = orchestrator
    mark, skip = _MARK, _SKIP
    shard_dir_of = shard_dir_of if shard_dir_of is not None else (
        lambda j: workdir)
    shard_host_of = shard_host_of if shard_host_of is not None else (
        lambda j: 0)

    def phase(name, clean_fn, map_fn):
        if fine_phases:
            orch.run_phase(f"{name}_clean", clean_fn, save=mark, load=skip)
            orch.run_phase(name, map_fn, save=mark, load=skip)
        else:
            orch.run_phase(name, lambda: (clean_fn(), map_fn()),
                           save=mark, load=skip)

    with _exchange(pcfg, workdir, IOLedger(), None, transport) as tr:
        phase("walk_init",
              lambda: tr.clean_inboxes(
                  [wfront_store_name(0, d, w.ns)
                   for w in wcfgs for d in range(nb)]),
              lambda: map_kernel("walk_init",
                                 [(j, w) for w in wcfgs for j in range(nb)]))
        for t in range(L):
            def _clean(t=t):
                if t > 0:
                    tr.clean_inboxes(
                        [wfront_store_name(t - 1, d, w.ns)
                         for w in wcfgs for d in range(nb)])
                tr.clean_inboxes(
                    [wfront_store_name(t + 1, d, w.ns)
                     for w in wcfgs for d in range(nb)])

            phase(f"walk_hop_{t:04d}", _clean,
                  lambda t=t: map_kernel(
                      "walk_hop_fused",
                      [(j, t, wcfgs) for j in range(nb)]))

        def _collect():
            map_kernel("walk_hist_scatter",
                       [(j, w) for w in wcfgs for j in range(nb)])
            map_kernel("walk_hist_gather",
                       [(j, w) for w in wcfgs for j in range(nb)])

        phase("walk_collect",
              lambda: tr.clean_inboxes(
                  [whist_inbox_name(d, w.ns)
                   for w in wcfgs for d in range(nb)]),
              _collect)

        paths = [os.path.join(workdir, corpus_manifest_name(w.out_name))
                 for w in wcfgs]

        def _manifests():
            for w, path in zip(wcfgs, paths):
                shards = []
                for j in range(nb):
                    w0, w1 = walker_block(w, nb, j)
                    shards.append({
                        "bucket": j, "w0": w0, "w1": w1,
                        "host": shard_host_of(j),
                        "path": os.path.join(
                            shard_dir_of(j),
                            corpus_shard_name(w.out_name, j)),
                    })
                write_manifest(path, w.num_walkers, L, shards)

        orch.run_phase("walk_manifest", _manifests, save=mark, load=skip)

        def _gc():
            if orch.keep_all:
                return
            names = []
            for w in wcfgs:
                for d in range(nb):
                    for t in range(L + 1):
                        names.append(wfront_store_name(t, d, w.ns))
                        names.append(whist_store_name(t, d, w.ns))
                    names.append(whist_inbox_name(d, w.ns))
            tr.clean_inboxes(names)

        orch.run_phase("walk_gc", _gc, save=mark, load=skip)
    return paths


# ---------------------------------------------------------------------------
# PhaseOrchestrator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PhaseRecord:
    name: str
    status: str                      # "done" | "resumed"
    seconds: float
    ledger_delta: Dict[str, int]


class PhaseOrchestrator:
    """Runs named phases with per-phase ledger deltas and checkpoint/resume.

    With `checkpoint=True`, each completed phase's `save()` payload (e.g.
    BlockStore manifests) is persisted to `<workdir>/phases.json`; a new
    orchestrator over the same workdir replays completed phases through
    `load()` instead of recomputing them — intermediate stores are reused
    in place, so resume does (almost) no I/O.
    """

    def __init__(self, workdir: str, ledger: IOLedger, checkpoint: bool = False,
                 config_key: Optional[str] = None, state_name: str = "phases.json",
                 keep_all: bool = False, sweep: bool = True,
                 cleaner: Optional[Callable[[Sequence[str]], None]] = None,
                 stats: Optional[TransportStats] = None):
        # `state_name` separates checkpoint namespaces sharing one workdir
        # (the walk pipeline resumes independently of the generation pipeline
        # whose CSR it reads — see drive_walks).
        # `sweep=False` skips the stray-file sweeps below — for callers that
        # already swept at a moment when no exchange could be mid-frame (the
        # cluster HostRunner sweeps before its ExchangeServer starts
        # accepting; sweeping here would race a live receive's `.part`).
        # `cleaner` overrides how freed stores are removed (default: local
        # clean_store); it receives the whole frees list in ONE call so a
        # transport-backed cleaner (the cluster controller routing frees to
        # whichever host owns each store) can batch names per CLEAN frame
        # instead of paying one RPC round per store.
        # `stats` (optional) is a live TransportStats the driver keeps
        # aggregated across its barriers (e.g. PartitionedGenerator's
        # exchange_stats); when provided, every phase record also carries a
        # `wire_`-prefixed delta of it — per-phase WIRE bytes next to the
        # per-phase disk bytes, which is what lets benchmarks and tests
        # assert "the recompute shuffle moved zero exchange bytes" per phase.
        self.workdir = workdir
        self.ledger = ledger
        self.checkpoint = checkpoint
        self._cleaner = cleaner
        self._stats = stats
        # Checkpoint GC: run_phase(frees=[...]) names stores whose LAST
        # consumer is that phase; once the phase is done (and, when
        # checkpointing, its manifest is durably on disk) they are dropped,
        # bounding the workdir to ~the live frontier of the pipeline instead
        # of every intermediate ever written.  keep_all=True is the debugging
        # escape hatch that retains everything.
        self.keep_all = keep_all
        self.records: List[PhaseRecord] = []
        self._state_path = os.path.join(workdir, state_name)
        self._config_key = config_key
        self._completed: Dict[str, Dict] = {}
        # Cascade intermediate stores are merge-private scratch: a crash mid
        # merge leaves them behind, and they are never part of any phase's
        # checkpointed manifest — sweep them before resuming so a resumed run
        # starts from exactly the stores the manifests describe.  Partial
        # exchange frames (`.part`, a receive killed mid-frame) are the same
        # kind of stray for the socket transport — swept with them.  (Pooled
        # cascade stores — `__pcas_l` — are NOT swept: those are checkpointed
        # phase outputs, not kernel scratch.)
        if sweep:
            clean_cascade_stores(workdir)
            sweep_partial_frames(workdir)
        if checkpoint and os.path.exists(self._state_path):
            try:
                with open(self._state_path) as f:
                    state = json.load(f)
            except (json.JSONDecodeError, OSError):
                # A torn/corrupt state file is exactly the crash this feature
                # recovers from — fall back to recomputing everything.
                state = {}
            # A checkpoint taken under a different config describes a
            # DIFFERENT graph — resuming from it would be silent corruption
            # (e.g. same workdir, new seed).  Invalidate wholesale.
            if config_key is not None and state.get("__config__") != config_key:
                state = {}
            self._completed = {k: v for k, v in state.items() if k != "__config__"}

    def run_phase(
        self,
        name: str,
        fn: Callable[[], object],
        save: Optional[Callable[[object], Dict]] = None,
        load: Optional[Callable[[Dict], object]] = None,
        frees: Sequence[str] = (),
    ):
        """`frees` names stores this phase is the LAST consumer of; they are
        removed once the phase completes — strictly AFTER the checkpoint
        write, so a crash between completion and checkpoint still leaves the
        rerun its inputs.  A resumed phase re-applies its frees (idempotent),
        covering a crash between checkpoint write and GC."""
        if self.checkpoint and load is not None and name in self._completed:
            result = load(self._completed[name])
            self.records.append(PhaseRecord(name, "resumed", 0.0,
                                            {k: 0 for k in self.ledger.as_dict()
                                             } | {k: 0 for k in self._wire_dict()}))
            self._apply_frees(frees)
            return result
        snap = self.ledger.snapshot()
        wire_snap = self._wire_dict()
        t_wall = time.time()
        t0 = time.perf_counter()
        result = fn()
        seconds = time.perf_counter() - t0
        delta = self.ledger.delta_since(snap)
        delta.update({k: v - wire_snap[k]
                      for k, v in self._wire_dict().items()})
        self.records.append(PhaseRecord(name, "done", seconds, delta))
        # Phase spans are emitted on the DONE path only: a resumed phase did
        # no work in this run, so it contributes no span — which is exactly
        # what makes a kill+resume trace free of duplicate phase spans.
        tracer = get_tracer()
        if tracer.enabled:
            tracer.event(name, "phase", t_wall, seconds,
                         args={k: v for k, v in delta.items() if v} or None)
        # Every phase also refreshes the process-wide unified snapshot (the
        # ledger/stats here are cumulative, so latest-wins is correct) —
        # this is what benchmarks/run.py harvests into BENCH json.
        GLOBAL_METRICS.update(
            "orchestrator", unified_snapshot(ledger=self.ledger,
                                             stats=self._stats))
        if self.checkpoint and save is not None:
            self._completed[name] = save(result)
            state = dict(self._completed)
            if self._config_key is not None:
                state["__config__"] = self._config_key
            tmp = self._state_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(state, f)
            os.replace(tmp, self._state_path)  # atomic: never a torn state file
        self._apply_frees(frees)
        return result

    def _wire_dict(self) -> Dict[str, int]:
        if self._stats is None:
            return {}
        return {f"wire_{k}": v
                for k, v in dataclasses.asdict(self._stats).items()}

    def completed(self, name: str) -> bool:
        """Whether a checkpointed run of phase `name` exists (the cluster
        HostRunner peeks before submitting work to its local pool)."""
        return self.checkpoint and name in self._completed

    def _apply_frees(self, frees: Sequence[str]) -> None:
        if self.keep_all or not frees:
            return
        if self._cleaner is not None:
            self._cleaner(list(frees))
            return
        for name in frees:
            clean_store(self.workdir, name)

    def delta(self, name: str) -> Dict[str, int]:
        """Ledger delta of the most recent run of phase `name`."""
        for rec in reversed(self.records):
            if rec.name == name:
                return rec.ledger_delta
        raise KeyError(name)

    def report(self) -> List[Dict]:
        return [
            {"phase": r.name, "status": r.status, "seconds": round(r.seconds, 4),
             **r.ledger_delta}
            for r in self.records
        ]


# ---------------------------------------------------------------------------
# PartitionedGenerator: nb workers, one vertex range each
# ---------------------------------------------------------------------------

def _traced_kernel(name: str, fn):
    """Span instrumentation for one registered kernel.  Wrapping at
    _KERNELS registration covers every dispatch path with one change —
    the inline driver (StreamingGenerator._run_kernels_inline), the
    process pool, and the cluster HostRunner all resolve kernels through
    this dict.  The span carries the kernel's bucket and its private
    ledger's nonzero counter deltas; with tracing disabled the cost is one
    attribute check.  The `traced_kernel` attribute is the CI lint's
    checkable witness (trace.lint_kernel_coverage)."""

    @functools.wraps(fn)
    def wrapper(pcfg, workdir, *args, ledger=None, gauge=None,
                transport=None):
        tracer = get_tracer()
        if not tracer.enabled:
            return fn(pcfg, workdir, *args, ledger=ledger, gauge=gauge,
                      transport=transport)
        snap = ledger.snapshot() if ledger is not None else None
        t_wall = time.time()
        t0 = time.perf_counter()
        out = fn(pcfg, workdir, *args, ledger=ledger, gauge=gauge,
                 transport=transport)
        span_args: Dict = {}
        if args and isinstance(args[0], int):
            span_args["bucket"] = args[0]
        if snap is not None:
            span_args.update({k: v for k, v in
                              ledger.delta_since(snap).items() if v})
        tracer.event(name, "kernel", t_wall, time.perf_counter() - t0,
                     args=span_args or None)
        return out

    wrapper.traced_kernel = name
    return wrapper


_KERNELS = {
    "init_pv": init_pv_bucket,
    "shuffle_round": shuffle_bucket_round,
    "pv_feistel": materialize_pv_bucket,
    "generate": generate_bucket_edges,
    "relabel_scatter": relabel_scatter_bucket,
    "relabel_apply": relabel_apply_bucket,
    "relabel_sort": relabel_sort_bucket,
    "relabel_join": relabel_join_bucket,
    "relabel_recompute": relabel_recompute_bucket,
    "gen_relabel_recompute": gen_relabel_recompute_bucket,
    "redistribute": redistribute_bucket,
    "csr_sorted": csr_bucket_sorted,
    "csr_sort": csr_sort_bucket,
    "cascade_merge": cascade_merge_bucket,
    "csr_emit": csr_emit_bucket,
    "csr_scatter": csr_bucket_scatter,
    "walk_init": walk_init_bucket,
    "walk_hop": walk_hop_bucket,
    "walk_hop_fused": walk_hop_fused_bucket,
    "walk_hop_sort": walk_hop_sort_bucket,
    "walk_hop_join": walk_hop_join_bucket,
    "walk_hist_scatter": walk_hist_scatter_bucket,
    "walk_hist_gather": walk_hist_gather_bucket,
}
_KERNELS = {name: _traced_kernel(name, fn) for name, fn in _KERNELS.items()}


# Process-local transport reuse: pool workers persist across barriers, so a
# socket transport (and its per-peer TCP connections) is built once per
# (workdir, peers) and rebound to each task's private ledger/gauge instead
# of paying connect/teardown on every kernel invocation — O(phases * nb)
# churn otherwise.  Evicted (and closed) if a kernel dies, so a poisoned
# connection never leaks into the next task.
_TRANSPORT_CACHE: Dict[Tuple, Transport] = {}


def _run_kernel(task):
    """Worker entry point: run one bucket kernel with a private ledger/gauge
    and the process-cached transport, and ship the accounting (including
    sender-side exchange stats — transports hold sockets and cannot cross
    the process boundary themselves) back to the parent."""
    kernel, pcfg, workdir, args = task
    # Pool workers are fresh (spawned) processes: the first traced task
    # installs this process's tracer under the task's workdir.  Idempotent,
    # strictly no-op (no directory created) when the job isn't tracing.
    maybe_install_tracer(workdir, enabled=getattr(pcfg, "trace", False))
    ledger = IOLedger()
    # budget_rows lets merge cursors derive refill blocks from the chunk
    # budget (MemoryGauge.cursor_rows) so deep cascades stay under one
    # chunk even when prefetch doubles residency.
    gauge = MemoryGauge(budget_rows=pcfg.chunk_edges)
    # exchange_namespace is part of the identity: two jobs sharing one host
    # workdir must not reuse each other's (differently-namespaced) channels.
    key = (workdir, pcfg.transport, pcfg.peer_addrs,
           getattr(pcfg, "exchange_namespace", None),
           getattr(pcfg, "shard_map_version", 0))
    tr = _TRANSPORT_CACHE.get(key)
    if tr is None:
        tr = _TRANSPORT_CACHE[key] = make_transport(pcfg, workdir, ledger, gauge)
    else:
        tr.rebind(ledger, gauge)
    try:
        out = _KERNELS[kernel](pcfg, workdir, *args, ledger=ledger,
                               gauge=gauge, transport=tr)
    except BaseException:
        _TRANSPORT_CACHE.pop(key, None)
        tr.close()
        raise
    if args and isinstance(args[0], int):
        # Kernel-side skew attribution: bucket kernels take their bucket
        # index as the first positional arg (the store-naming convention's
        # dispatch twin), so the task's whole I/O bill lands in that
        # bucket's per-bucket counters — the rebalancer's load signal.
        ledger.bucket(args[0], ledger.bytes_read + ledger.bytes_written,
                      ledger.rows_written)
    return out, ledger.as_dict(), gauge.peak_rows, dataclasses.asdict(tr.stats)


def task_key(namespace: str, kernel: str, wire_args: Sequence,
             ns: str = "") -> str:
    """The canonical task identity the cluster checkpoints under — shared
    by ClusterController.run_tasks (live dispatch) and phase_task_plan
    (static export) so the two can never drift.  `wire_args` are the
    JSON-safe positional args (WalkCfg already extracted); `ns` is the walk
    config's store prefix, appended only when nonempty so fused multi-corpus
    barriers (same j, same kernel, different seeds) stay distinct while
    every pre-existing key is unchanged."""
    key = f"{namespace}:{kernel}:" + ":".join(str(a) for a in wire_args)
    if ns:
        key += f":{ns}"
    return key


def phase_task_plan(pcfg: PlainCfg, csr_variant: str = "sorted",
                    walks: Sequence[Tuple[int, int, int, str]] = (),
                    gen_namespace: str = "gen",
                    fuse_gen_relabel: bool = False,
                    fuse_walks: bool = False) -> List[Dict]:
    """Static export of the per-phase task-key decomposition a cluster run
    of this config dispatches — the job queue's DAG source: the scheduler
    calls this ONCE at submit time to know every barrier, every task key
    inside it, and the dependency edges between barriers, without running
    anything.  Returns ordered [{"phase", "kernel", "keys", "deps"}];
    `deps` name earlier phases (barriers), keys match task_key()/run_tasks
    exactly.  Driver-side cleans are not tasks and do not appear.  Walk
    corpora (one (num_walkers, length, seed, out_name) tuple each) chain
    after the CSR phase and are mutually independent — unless `fuse_walks`,
    in which case all of them (equal lengths required) advance through ONE
    walk_hop_fused barrier per hop, the shape walk_corpus_fused dispatches.
    pooled_cascade plans are data-dependent (cascade level counts come from
    sort output) and raise ValueError."""
    if pcfg.pooled_cascade:
        raise ValueError(
            "phase_task_plan: pooled_cascade merge levels are data-dependent "
            "(level count derives from sorted-run counts at runtime) — no "
            "static task plan exists; submit with pooled_cascade=False")
    if csr_variant not in ("sorted", "scatter"):
        raise ValueError(f"csr_variant must be 'sorted' or 'scatter', "
                         f"got {csr_variant!r}")
    nb = pcfg.nb
    plan: List[Dict] = []

    def add(phase, kernel, argss, deps):
        plan.append({
            "phase": phase, "kernel": kernel,
            "keys": [task_key(gen_namespace if not phase.startswith("walk")
                              else deps_ns, kernel, args) for args in argss],
            "deps": list(deps),
        })
        return phase

    deps_ns = gen_namespace
    buckets = [(i,) for i in range(nb)]
    if pcfg.shuffle_variant == "recompute":
        if fuse_gen_relabel:
            last = add("gen_relabel", "gen_relabel_recompute", buckets, [])
        else:
            last = add("generate", "generate", buckets, [])
            last = add("relabel_recompute", "relabel_recompute", buckets,
                       [last])
    else:
        if fuse_gen_relabel:
            raise ValueError("fuse_gen_relabel requires "
                             "shuffle_variant='recompute'")
        if pcfg.perm_family == "feistel":
            last = add("shuffle_init", "pv_feistel", buckets, [])
        else:
            last = add("shuffle_init", "init_pv", buckets, [])
            for r in range(pcfg.rounds):
                last = add(f"shuffle_round_r{r}", "shuffle_round",
                           [(i, r) for i in range(nb)], [last])
        shuffle_done = last
        last = add("generate", "generate", buckets, [])
        for p in (0, 1):
            last = add(f"relabel_scatter_p{p}", "relabel_scatter",
                       [(i, p) for i in range(nb)],
                       [last, shuffle_done] if p == 0 else [last])
            last = add(f"relabel_apply_p{p}", "relabel_apply",
                       [(i, p) for i in range(nb)], [last])
        last = add("redistribute", "redistribute", buckets, [last])
    csr_kernel = "csr_scatter" if csr_variant == "scatter" else "csr_sorted"
    csr_phase = add("csr_scatter" if csr_variant == "scatter" else
                    "csr_sorted", csr_kernel, buckets, [last])
    if fuse_walks and walks:
        lengths = {L for (_, L, _, _) in walks}
        if len(lengths) != 1:
            raise ValueError(f"fuse_walks requires equal lengths, "
                             f"got {sorted(lengths)}")
        (L,) = lengths
        # Matches ClusterGenerator.walk_corpus_fused dispatch exactly: one
        # shared namespace, per-config ns suffixes w{k}_ on init/collect
        # keys, ns-free keys on the fused hop (the WalkCfg list is not a
        # wire arg).
        deps_ns = "walkf:" + ";".join(
            f"{w}:{l}:{s}:{o}" for (w, l, s, o) in walks)
        nss = [f"w{k}_" for k in range(len(walks))]
        per_cfg = [(i, ns) for ns in nss for i in range(nb)]

        def add_fused(phase, kernel, keys, deps):
            plan.append({"phase": phase, "kernel": kernel,
                         "keys": keys, "deps": list(deps)})
            return phase

        last = add_fused(
            "walk_init", "walk_init",
            [task_key(deps_ns, "walk_init", (i,), ns=ns)
             for i, ns in per_cfg], [csr_phase])
        for t in range(L):
            last = add_fused(
                f"walk_hop_{t:04d}", "walk_hop_fused",
                [task_key(deps_ns, "walk_hop_fused", (j, t))
                 for j in range(nb)], [last])
        last = add_fused(
            "walk_hist_scatter", "walk_hist_scatter",
            [task_key(deps_ns, "walk_hist_scatter", (i,), ns=ns)
             for i, ns in per_cfg], [last])
        add_fused(
            "walk_hist_gather", "walk_hist_gather",
            [task_key(deps_ns, "walk_hist_gather", (i,), ns=ns)
             for i, ns in per_cfg], [last])
        return plan
    for (W, L, seed, out_name) in walks:
        deps_ns = f"walk:{W}:{L}:{seed}:{out_name}"
        wtag = deps_ns.replace(":", "_")
        last = add(f"walk_init[{wtag}]", "walk_init", buckets, [csr_phase])
        for t in range(L):
            last = add(f"walk_hop_{t:04d}[{wtag}]", "walk_hop",
                       [(j, t) for j in range(nb)], [last])
        last = add(f"walk_hist_scatter[{wtag}]", "walk_hist_scatter",
                   buckets, [last])
        add(f"walk_hist_gather[{wtag}]", "walk_hist_gather", buckets, [last])
    return plan


class PartitionedGenerator:
    """Multi-process out-of-core generator: the paper's cluster on one host.

    nb workers (a `concurrent.futures` pool over a spawn context — safe with
    an initialized jax parent), each owning vertex range [i*B, (i+1)*B).
    The bucket exchanges that MPI would carry ride the configured Transport:
    the shared filesystem (cfg.transport="fs") or framed TCP to
    ExchangeServers ("socket") — with socket and no explicit peer_addrs, the
    driver starts `exchange_servers` loopback servers and workers rendezvous
    with them; with explicit peer_addrs each address may live on another
    host, which is the multi-host deployment shape.  Phases are
    bulk-synchronous: scatter kernels for every bucket complete (barrier)
    before any join kernel starts, exactly the paper's structure, and a send
    is acked only once durable at the receiver — so the barrier doubles as
    the exchange flush.

    `max_workers=0` runs the same kernels in-process (the sequential
    debugging mode); the stores, and therefore the result, are identical —
    across worker counts AND across transports.

    `checkpoint=True` makes every phase resumable (state in
    <workdir>/phases.json): a killed run — even one killed mid-exchange —
    replays unfinished phases from the senders' still-checkpointed input
    stores, after the pre-senders inbox sweep clears stale runs and partial
    frames.  Unless `keep_all` (default: cfg.keep_phase_stores), each
    phase's stores are dropped once every downstream consumer is
    done/checkpointed, bounding the disk footprint.
    """

    def __init__(self, cfg, workdir: str, max_workers: Optional[int] = None,
                 checkpoint: bool = False, keep_all: Optional[bool] = None,
                 exchange_servers: int = 1):
        pcfg = validate_external_shape(
            cfg if isinstance(cfg, PlainCfg) else plain_config(cfg))
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        maybe_install_tracer(workdir, enabled=pcfg.trace)
        self.ledger = IOLedger()
        self.gauge = MemoryGauge(budget_rows=pcfg.chunk_edges)
        self._servers: List[ExchangeServer] = []
        self.exchange_stats = TransportStats()
        if pcfg.transport == "socket" and pcfg.peer_addrs is None:
            ns = max(1, min(int(exchange_servers), pcfg.nb))
            self._servers = [ExchangeServer(workdir) for _ in range(ns)]
            pcfg = dataclasses.replace(
                pcfg, peer_addrs=tuple(self._servers[j % ns].addr
                                       for j in range(pcfg.nb)))
        self.pcfg = pcfg
        self.transport = make_transport(pcfg, workdir, self.ledger, self.gauge)
        if max_workers is None:
            max_workers = min(self.pcfg.nb, os.cpu_count() or 1)
        self.max_workers = max_workers
        self._pool: Optional[ProcessPoolExecutor] = None
        if keep_all is None:
            keep_all = bool(getattr(cfg, "keep_phase_stores", False))
        self.keep_all = keep_all
        self.orchestrator = PhaseOrchestrator(
            workdir, self.ledger, checkpoint=checkpoint,
            config_key=repr(("partitioned", result_config_key(self.pcfg))),
            keep_all=keep_all, stats=self.exchange_stats)

    def _shutdown_pool(self):
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    def close(self):
        self._shutdown_pool()
        self.transport.close()
        # In-process mode (max_workers=0) populates the worker transport
        # cache in THIS process; drop those entries so their connections
        # don't dangle into stopped servers.
        for key in [k for k in _TRANSPORT_CACHE if k[0] == self.workdir]:
            _TRANSPORT_CACHE.pop(key).close()
        self._drain_servers()
        for srv in self._servers:
            srv.stop()
        self._servers = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _drain_servers(self):
        """Fold receiver-side accounting (disk writes, frame peaks, wire
        bytes) into the driver's ledger/gauge/stats — called at every barrier
        so per-phase ledger deltas include the receive half of the exchange."""
        for srv in self._servers:
            self.exchange_stats.add(srv.drain_accounting(self.ledger, self.gauge))

    # -- the barrier ----------------------------------------------------------
    # Fine-grained phase mode: False here (the outer named phases — shuffle,
    # relabel, ... — are the checkpoint unit, today's behavior); the cluster
    # generator flips it so every clean and every kernel barrier checkpoints
    # separately, which is what makes per-HOST resume sound (see
    # drive_shuffle's docstring).
    _fine_phases = False
    # Corpus shard placement hooks (drive_walks): None = all shards in this
    # driver's workdir, owned by "host 0".  The cluster generator maps each
    # bucket to its owner host's workdir.
    _shard_dir_of = None
    _shard_host_of = None
    # Fuse generate+relabel into gen_relabel_recompute (recompute variant
    # only): the raw-edge store is never written, so the task reads and
    # writes NOTHING locally — the job-queue scheduler marks such tasks
    # stealable and migrates them freely between hosts.
    _fuse_gen_relabel = False

    def _submit(self, kernel: str, tasks: Sequence[Tuple]) -> List:
        """Execution strategy: run bucket-kernel tasks to completion and
        return their (out, ledger dict, peak rows, transport stats) tuples.
        Overridden by the cluster generator to dispatch through HostRunners."""
        if self.max_workers == 0:
            return [_run_kernel(t) for t in tasks]
        if self._pool is None:
            # One persistent pool for the whole run: workers pay their
            # interpreter/import startup once, not once per barrier.
            self._pool = ProcessPoolExecutor(
                max_workers=self.max_workers, mp_context=get_context("spawn"))
        return list(self._pool.map(_run_kernel, tasks))

    def _map(self, kernel: str, argss: Sequence[Tuple]) -> List:
        tasks = [(kernel, self.pcfg, self.workdir, args) for args in argss]
        results = self._submit(kernel, tasks)
        outs = []
        for out, ldict, peak, sdict in results:
            self.ledger.merge(ldict)
            self.gauge.track(peak)
            self.exchange_stats.add(TransportStats(**sdict))
            outs.append(out)
        self._drain_servers()
        return outs

    # -- phase-granularity helpers --------------------------------------------
    def _outer(self, name: str, fn, frees: Sequence[str] = ()):
        """A coarse driver phase.  In fine mode the inner steps checkpoint
        themselves, so only the GC declaration (when any) needs its own
        phase — the frees still run exactly once per completion."""
        if self._fine_phases:
            out = fn()
            if frees:
                self.orchestrator.run_phase(f"{name}_gc", lambda: None,
                                            save=_MARK, load=_SKIP, frees=frees)
            return out
        return self.orchestrator.run_phase(name, fn, save=_MARK, load=_SKIP,
                                           frees=frees)

    def _step(self, name: str, fn):
        """An inner step (one clean or one kernel barrier): checkpointed on
        its own in fine mode, a plain call otherwise."""
        if self._fine_phases:
            return self.orchestrator.run_phase(name, fn, save=_MARK, load=_SKIP)
        return fn()

    def _maybe_rebalance(self, tag: str) -> None:
        """Shard-map rebalance hook, called at phase barriers (before the
        CSR phase and before each walk drive).  A single-host partitioned
        run has one workdir and nothing to move — the cluster generator
        overrides this with the plan/migrate/commit micro-phases."""

    # -- phases ----------------------------------------------------------------
    def _shuffle(self):
        drive_shuffle(self.pcfg, self.workdir, self._map,
                      orchestrator=(self.orchestrator if self._fine_phases
                                    else None),
                      transport=self.transport)

    def _relabel(self):
        nb = self.pcfg.nb
        for p in (0, 1):
            self._step(f"relabel_clean_p{p}",
                       lambda p=p: self.transport.clean_inboxes(
                           [relabel_inbox_name(p, j) for j in range(nb)]))
            self._step(f"relabel_scatter_p{p}",
                       lambda p=p: self._map("relabel_scatter",
                                             [(i, p) for i in range(nb)]))
            self._step(f"relabel_apply_p{p}",
                       lambda p=p: self._map("relabel_apply",
                                             [(i, p) for i in range(nb)]))

    def _relabel_pooled(self):
        """The relabel join with its external sort's cascade merge LEVELS
        dispatched through the worker pool / cluster (the csr pooled-cascade
        treatment applied to relabel, per pass): scatter, then a counts-
        returning sort barrier, then one barrier per cascade level, then the
        sort-merge-join against pv.  Bit-identical to _relabel."""
        nb = self.pcfg.nb
        orch = self.orchestrator
        for p in (0, 1):
            orch.run_phase(
                f"relabel_clean_p{p}",
                lambda p=p: self.transport.clean_inboxes(
                    [relabel_inbox_name(p, j) for j in range(nb)]),
                save=_MARK, load=_SKIP)
            # Scatter is the last consumer of its input edge stores.
            orch.run_phase(
                f"relabel_scatter_p{p}",
                lambda p=p: self._map("relabel_scatter",
                                      [(i, p) for i in range(nb)]),
                save=_MARK, load=_SKIP,
                frees=[edges_store_name(i) if p == 0 else edges_store_name(i, 0)
                       for i in range(nb)])
            counts = orch.run_phase(
                f"relabel_sort_p{p}",
                lambda p=p: [int(c) for c in
                             self._map("relabel_sort",
                                       [(i, p) for i in range(nb)])],
                save=lambda r: {"counts": list(r)},
                load=lambda m: [int(c) for c in m["counts"]],
                frees=[relabel_inbox_name(p, j) for j in range(nb)])
            srcs = pooled_cascade_levels(
                self.pcfg, orch, self._map, {i: counts[i] for i in range(nb)},
                lambda i, p=p: relabel_inbox_name(p, i) + "_sorted",
                f"relabel_p{p}", key=1)
            orch.run_phase(
                f"relabel_join_p{p}",
                lambda p=p, srcs=srcs: self._map(
                    "relabel_join",
                    [(i, p, srcs[i][0], srcs[i][1]) for i in range(nb)]),
                save=_MARK, load=_SKIP,
                frees=[srcs[i][0] for i in range(nb)])

    def _relabel_recompute(self):
        """shuffle_variant='recompute': the single scan+exchange that
        replaces relabel (both passes) AND redistribute — endpoints are
        relabeled by hash evaluation in-stream (see
        relabel_recompute_bucket)."""
        nb = self.pcfg.nb
        self._step("relabel_recompute_clean",
                   lambda: self.transport.clean_inboxes(
                       [owned_store_name(j) for j in range(nb)]))
        return self._step("relabel_recompute_map",
                          lambda: self._map("relabel_recompute",
                                            [(i,) for i in range(nb)]))

    def _gen_relabel_fused(self):
        """shuffle_variant='recompute' with _fuse_gen_relabel: generate and
        relabel in ONE kernel per bucket, regenerating edges from the RNG
        (see gen_relabel_recompute_bucket) — no raw-edge store, no frees."""
        nb = self.pcfg.nb
        self._step("gen_relabel_clean",
                   lambda: self.transport.clean_inboxes(
                       [owned_store_name(j) for j in range(nb)]))
        return self._step("gen_relabel_map",
                          lambda: self._map("gen_relabel_recompute",
                                            [(i,) for i in range(nb)]))

    def _redistribute(self):
        nb = self.pcfg.nb
        self._step("redistribute_clean",
                   lambda: self.transport.clean_inboxes(
                       [owned_store_name(j) for j in range(nb)]))
        return self._step("redistribute_map",
                          lambda: self._map("redistribute",
                                            [(i,) for i in range(nb)]))

    # -- CSR variants -----------------------------------------------------------
    def _csr_dir(self, i: int) -> str:
        """Directory holding bucket i's CSR files (host workdir on a cluster)."""
        return self.workdir

    def _save_csr(self, paths):
        return {"paths": [[os.path.basename(o), os.path.basename(a)]
                          for o, a in paths]}

    def _load_csr(self, m):
        return [(os.path.join(self._csr_dir(i), o),
                 os.path.join(self._csr_dir(i), a))
                for i, (o, a) in enumerate(m["paths"])]

    def _run_csr_sorted_pooled(self, nb: int):
        """§III-B7 CSR with the cascade's intermediate merge levels dispatched
        through the worker pool / cluster (PR 3's "embarrassingly parallel"
        upside): sort pass as one barrier, then one barrier per cascade
        LEVEL whose tasks are the (bucket, group) merges of that level, then
        a streaming emit.  Bit-identical to the inline cascade and to the
        flat merge (stable merge + consecutive groups)."""
        orch = self.orchestrator
        counts = orch.run_phase(
            "csr_sort",
            lambda: [int(c) for c in self._map("csr_sort",
                                               [(i,) for i in range(nb)])],
            save=lambda r: {"counts": list(r)},
            load=lambda m: [int(c) for c in m["counts"]],
            frees=[owned_store_name(j) for j in range(nb)])
        srcs = pooled_cascade_levels(
            self.pcfg, orch, self._map, {i: counts[i] for i in range(nb)},
            sorted_owned_store_name, "csr", key="csr")
        emit_tasks = [(i, srcs[i][0], srcs[i][1]) for i in range(nb)]
        emit_frees = [srcs[i][0] for i in range(nb)]
        return orch.run_phase(
            "csr_emit", lambda: self._map("csr_emit", emit_tasks),
            save=self._save_csr, load=self._load_csr, frees=emit_frees)

    def _run_csr_scatter(self, nb: int):
        """Paper Alg. 10/11 under real process parallelism (the partitioned
        scatter-CSR): same files as 'sorted', random-write I/O ledger."""
        orch = self.orchestrator
        if not self.keep_all and any(orch.completed(p)
                                     for p in ("csr_sorted", "csr_sort",
                                               "csr_emit")):
            # A checkpointed sorted run already freed the redistribute
            # outputs this variant needs — fail with guidance, not with an
            # empty inbox silently producing an empty graph.
            raise ValueError(
                "csr_variant='scatter' needs the redistribute output stores, "
                "but a checkpointed sorted-CSR phase already garbage-"
                "collected them; rerun with keep_phase_stores=True or a "
                "fresh workdir")
        return orch.run_phase(
            "csr_scatter",
            lambda: self._map("csr_scatter", [(i,) for i in range(nb)]),
            save=self._save_csr, load=self._load_csr,
            frees=[owned_store_name(j) for j in range(nb)])

    # -- driver ----------------------------------------------------------------
    def _run_phases(self, csr_variant: str = "sorted") -> List[Tuple[str, str]]:
        """All generation phases through the orchestrator; returns the
        per-bucket (offv_path, adjv_path) list WITHOUT loading the CSR —
        the cluster driver stops here and writes a manifest instead."""
        if csr_variant not in ("sorted", "scatter"):
            raise ValueError(
                f"partitioned csr_variant must be 'sorted' or 'scatter', "
                f"got {csr_variant!r}")
        nb = self.pcfg.nb
        if self.pcfg.shuffle_variant == "recompute":
            # Communication-free path: no shuffle (the permutation is a
            # hash family, not a store), and relabel+redistribute collapse
            # into one scan+exchange.
            if self._fuse_gen_relabel:
                # Further fusion: generate never materializes either — the
                # relabel scan regenerates its input (bit-identical inboxes,
                # zero local state, stealable tasks).
                self._outer("gen_relabel", self._gen_relabel_fused)
            else:
                self.orchestrator.run_phase(
                    "generate",
                    lambda: self._map("generate", [(i,) for i in range(nb)]),
                    save=_MARK, load=_SKIP)
                self._outer("relabel_recompute", self._relabel_recompute,
                            frees=[edges_store_name(i) for i in range(nb)])
        else:
            self._outer("shuffle", self._shuffle)
            self.orchestrator.run_phase(
                "generate",
                lambda: self._map("generate", [(i,) for i in range(nb)]),
                save=_MARK, load=_SKIP)
            # GC declarations: each store list's LAST consumer is the naming
            # phase.  pv buckets are never freed here — they ARE the
            # partitioned driver's permutation output (pv_buckets()).
            if self.pcfg.pooled_cascade:
                self._relabel_pooled()
            else:
                self._outer("relabel", self._relabel,
                            frees=[edges_store_name(i) for i in range(nb)]
                                  + [edges_store_name(i, 0) for i in range(nb)])
            self._outer("redistribute", self._redistribute,
                        frees=[edges_store_name(i, 1) for i in range(nb)])
        # Phase barrier: bucket loads are now known (per-bucket ledger
        # counters) and no exchange is in flight — the one legal point to
        # rewrite the shard map before the CSR phase reads the buckets.
        self._maybe_rebalance("csr")
        if csr_variant == "scatter":
            paths = self._run_csr_scatter(nb)
        elif self.pcfg.pooled_cascade:
            paths = self._run_csr_sorted_pooled(nb)
        else:
            paths = self.orchestrator.run_phase(
                "csr_sorted",
                lambda: self._map("csr_sorted", [(i,) for i in range(nb)]),
                save=self._save_csr, load=self._load_csr,
                frees=[owned_store_name(j) for j in range(nb)])
        # Normalize to driver-resolvable paths (kernel returns are host-local
        # on a cluster; basename + _csr_dir is the shared convention).
        return [(os.path.join(self._csr_dir(i), os.path.basename(o)),
                 os.path.join(self._csr_dir(i), os.path.basename(a)))
                for i, (o, a) in enumerate(paths)]

    def run(self, csr_variant: str = "sorted"):
        """Returns ([(offv, adjv_memmap)] per bucket, aggregate IOLedger)."""
        paths = self._run_phases(csr_variant)
        self._shutdown_pool()
        csr = [load_bucket_csr(offv_path, adjv_path, self.ledger, self.gauge)
               for offv_path, adjv_path in paths]
        return csr, self.ledger

    def pv_buckets(self) -> List[BlockStore]:
        if self.pcfg.shuffle_variant == "recompute":
            raise ValueError(
                "shuffle_variant='recompute' materializes no pv stores — "
                "the permutation is recomputable: evaluate "
                "hostgen.graph_perm_np(seed, ids, n) (or its inverse) "
                "instead of reading bucket files")
        return attach_pv_buckets(self.pcfg, self.workdir, self.ledger, self.gauge)

    def walk_corpus(self, num_walkers: int, length: int, seed: int = 0,
                    out_name: str = "walks.npy",
                    checkpoint: bool = False) -> ShardedWalks:
        """Out-of-core walk corpus [num_walkers, length+1] over this
        generator's CSR bucket files — the walk-frontier exchange running
        through the same worker pool and the same Transport (filesystem
        `{sender}_{seq}` runs or framed TCP) as generation.  Requires run()
        to have completed (the CSR phase writes the bucket files the hops
        join against).  Returns a ShardedWalks view over the per-bucket
        shard files + manifest (the sharded collect: no monolithic corpus
        file exists).  Bit-identical to data/walks.host_walks on the
        assembled CSR, whichever transport carried the frontiers."""
        wcfg = WalkCfg(num_walkers=num_walkers, length=length, seed=seed,
                       out_name=out_name)
        self._maybe_rebalance(f"walk_{out_name}")
        orch = PhaseOrchestrator(self.workdir, self.ledger, checkpoint=checkpoint,
                                 state_name="walk_phases.json",
                                 config_key=repr((result_config_key(self.pcfg), wcfg)),
                                 keep_all=self.keep_all,
                                 stats=self.exchange_stats)
        path = drive_walks(self.pcfg, self.workdir, wcfg, self._map, orch,
                           transport=self.transport,
                           shard_dir_of=self._shard_dir_of,
                           shard_host_of=self._shard_host_of,
                           fine_phases=self._fine_phases)
        return ShardedWalks(path)

    def walk_corpus_fused(self, specs: Sequence[Tuple[int, int, int, str]],
                          checkpoint: bool = False) -> List[ShardedWalks]:
        """Several corpora in one pass: `specs` is a list of
        (num_walkers, length, seed, out_name) tuples — all lengths equal —
        and every hop advances ALL of them through one CSR scan per bucket
        (drive_walks_fused / walk_hop_fused_bucket).  Each returned corpus
        is bit-identical to the corresponding walk_corpus() call; the k
        configs share the offv/adjv read instead of each paying it."""
        wcfgs = [WalkCfg(num_walkers=w, length=l, seed=s, out_name=o,
                         ns=f"w{k}_")
                 for k, (w, l, s, o) in enumerate(specs)]
        self._maybe_rebalance(
            "walkf_" + "_".join(w.out_name for w in wcfgs))
        orch = PhaseOrchestrator(
            self.workdir, self.ledger, checkpoint=checkpoint,
            state_name="walk_fused_phases.json",
            config_key=repr((result_config_key(self.pcfg), tuple(wcfgs))),
            keep_all=self.keep_all, stats=self.exchange_stats)
        paths = drive_walks_fused(self.pcfg, self.workdir, wcfgs, self._map,
                                  orch, transport=self.transport,
                                  shard_dir_of=self._shard_dir_of,
                                  shard_host_of=self._shard_host_of,
                                  fine_phases=self._fine_phases)
        return [ShardedWalks(p) for p in paths]
