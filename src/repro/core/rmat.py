"""Vectorized R-MAT edge generation (paper Alg. 5 / gen_rmat_edge).

The sequential kernel draws one edge at a time by descending `scale` levels of
the adjacency-matrix quadtree.  Our adaptation vectorizes the level walk over
a whole block of edges (the paper's per-core bin of b*f edges) and replaces
the stateful RNG with a *counter-based* hash RNG so that

  * every edge is generated independently from (seed, edge_index, level,
    field) — no sequential RNG state, perfectly parallel across shards,
    cores, and Pallas grid steps;
  * the Pallas TPU kernel (kernels/rmat.py) and this jnp reference produce
    bit-identical streams (tests assert exact equality);
  * regeneration is deterministic: edge i can be re-derived at any time,
    which is what makes checkpoint-free restart of the *generation* phase
    possible (fault tolerance for the data pipeline).

All arithmetic is uint32: thresholds are integer cut points on the 2**32
lattice (core/types.quadrant_thresholds).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .types import GraphConfig, quadrant_thresholds

# splitmix32-style avalanche constants (Stafford / murmur3 finalizer family).
_M1 = jnp.uint32(0x7FEB352D)
_M2 = jnp.uint32(0x846CA68B)
_GOLDEN = 0x9E3779B9


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Full-avalanche 32-bit mixer (murmur3 finalizer variant).

    Bijective on uint32, so distinct counters never collide.
    """
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def counter_uniform_u32(seed: int, index: jnp.ndarray, stream: int) -> jnp.ndarray:
    """One uint32 uniform per counter: h(seed, stream, index).

    `stream` enumerates (level, field) pairs; `index` is the global edge id.
    """
    s = jnp.uint32((seed ^ (stream * _GOLDEN)) & 0xFFFFFFFF)
    return mix32(mix32(jnp.asarray(index, jnp.uint32) + s) ^ s)


@partial(jax.jit, static_argnames=("cfg", "count"))
def rmat_edge_block(cfg: GraphConfig, start: jnp.ndarray, count: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generate `count` R-MAT edges with global ids [start, start+count).

    Returns (src, dst), each int32 of shape (count,).  This is the pure-jnp
    reference; kernels/rmat.py is the Pallas TPU version of the same math.
    """
    t_src, t_dst0, t_dst1 = quadrant_thresholds(cfg)
    idx = jnp.asarray(start, jnp.uint32) + jnp.arange(count, dtype=jnp.uint32)
    src = jnp.zeros((count,), jnp.uint32)
    dst = jnp.zeros((count,), jnp.uint32)
    for level in range(cfg.scale):
        r1 = counter_uniform_u32(cfg.seed, idx, 2 * level)
        r2 = counter_uniform_u32(cfg.seed, idx, 2 * level + 1)
        src_bit = r1 < jnp.uint32(t_src)          # P = c + d  (t < 2**32 since c+d < 1)
        # dst threshold depends on the src bit (conditional quadrant probs)
        t_d = jnp.where(src_bit, jnp.uint32(t_dst1), jnp.uint32(t_dst0))
        dst_bit = r2 < t_d
        src = (src << 1) | src_bit.astype(jnp.uint32)
        dst = (dst << 1) | dst_bit.astype(jnp.uint32)
    return src.astype(cfg.vertex_dtype), dst.astype(cfg.vertex_dtype)


def degree_bias_stat(src: jnp.ndarray, dst: jnp.ndarray, n: int) -> float:
    """Fraction of edge endpoints landing in the lowest n/16 vertex ids.

    R-MAT with (a,b,c,d)=(.57,.19,.19,.05) concentrates mass on small ids —
    the 'bias' the paper de-biases via shuffling (§I).  Used by tests to
    verify (i) raw R-MAT output IS biased and (ii) relabeled output is NOT.
    """
    lo = n // 16
    cnt = jnp.sum(src < lo) + jnp.sum(dst < lo)
    return float(cnt) / float(2 * src.shape[0])
